"""L2 correctness: prefill/decode consistency, shapes, and embedder sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile import model
from compile.params import init_params


@pytest.fixture(scope="module")
def params():
    return init_params()


def byte_tokens(text: str):
    return [C.BOS_ID] + [b for b in text.encode("utf-8")]


def pad_to(tokens, n):
    assert len(tokens) <= n
    return jnp.asarray(tokens + [C.PAD_ID] * (n - len(tokens)), jnp.int32)


def run_prefill(params, tokens):
    toks = pad_to(tokens, C.PREFILL_LEN)
    return model.prefill(params, toks, jnp.int32(len(tokens)))


def fresh_caches():
    shape = (C.N_LAYERS, C.DECODE_BATCH, C.N_HEADS, C.MAX_SEQ, C.D_HEAD)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def test_prefill_shapes(params):
    logits, k, v = run_prefill(params, byte_tokens("hello"))
    assert logits.shape == (C.VOCAB,)
    assert k.shape == (C.N_LAYERS, C.N_HEADS, C.MAX_SEQ, C.D_HEAD)
    assert v.shape == k.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_ignores_padding(params):
    """Logits must not depend on what sits in the PAD region."""
    toks = byte_tokens("abc")
    a = pad_to(toks, C.PREFILL_LEN)
    b = jnp.asarray(list(toks) + [17] * (C.PREFILL_LEN - len(toks)), jnp.int32)
    la, _, _ = model.prefill(params, a, jnp.int32(len(toks)))
    lb, _, _ = model.prefill(params, b, jnp.int32(len(toks)))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_prefill(params):
    """Teacher-forcing equivalence: prefill(t[:n]) logits == decoding the
    same tokens one step at a time after prefill(t[:k])."""
    toks = byte_tokens("the quick brown fox")
    split = 5
    # ground truth: full prefill over toks gives next-token logits
    full_logits, _, _ = run_prefill(params, toks)

    # prefix prefill, then decode the remaining tokens step by step
    logits_p, k1, v1 = run_prefill(params, toks[:split])
    kc, vc = fresh_caches()
    lane = 0
    kc = kc.at[:, lane, :, :, :].set(k1)
    vc = vc.at[:, lane, :, :, :].set(v1)

    logits = logits_p
    for i in range(split, len(toks)):
        tok_b = jnp.full((C.DECODE_BATCH,), C.PAD_ID, jnp.int32)
        pos_b = jnp.zeros((C.DECODE_BATCH,), jnp.int32)
        tok_b = tok_b.at[lane].set(toks[i])
        pos_b = pos_b.at[lane].set(i)
        logits_b, kc, vc = model.decode(params, tok_b, pos_b, kc, vc)
        logits = logits_b[lane]

    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_decode_lanes_independent(params):
    """A lane's logits must not depend on other lanes' contents."""
    toks = byte_tokens("independence")
    _, k1, v1 = run_prefill(params, toks)
    kc, vc = fresh_caches()
    kc = kc.at[:, 2, :, :, :].set(k1)
    vc = vc.at[:, 2, :, :, :].set(v1)

    def step(other_tok):
        tok_b = jnp.full((C.DECODE_BATCH,), other_tok, jnp.int32)
        pos_b = jnp.full((C.DECODE_BATCH,), 3, jnp.int32)
        tok_b = tok_b.at[2].set(65)
        pos_b = pos_b.at[2].set(len(toks))
        logits, _, _ = model.decode(params, tok_b, pos_b, kc, vc)
        return np.asarray(logits[2])

    np.testing.assert_allclose(step(11), step(200), rtol=1e-5, atol=1e-5)


def test_decode_writes_kv_at_position(params):
    kc, vc = fresh_caches()
    tok_b = jnp.full((C.DECODE_BATCH,), 42, jnp.int32)
    pos_b = jnp.full((C.DECODE_BATCH,), 7, jnp.int32)
    _, kc2, _ = model.decode(params, tok_b, pos_b, kc, vc)
    kc2 = np.asarray(kc2)
    assert np.abs(kc2[:, :, :, 7, :]).sum() > 0
    untouched = np.delete(kc2, 7, axis=3)
    np.testing.assert_allclose(untouched, 0.0)


def test_embed_normalized_and_length_sensitive(params):
    t1 = pad_to(byte_tokens("summarize this document"), C.EMBED_LEN)
    e1 = np.asarray(model.embed(params, t1, jnp.int32(10)))
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-4
    e2 = np.asarray(model.embed(params, t1, jnp.int32(24)))
    assert not np.allclose(e1, e2)


def test_embed_similarity_orders_prompts(params):
    """Near-duplicate prompts embed closer than unrelated prompts."""
    def emb(s):
        t = pad_to(byte_tokens(s), C.EMBED_LEN)
        return np.asarray(model.embed(params, t, jnp.int32(len(byte_tokens(s)))))

    a = emb("please summarize the following article about birds")
    b = emb("please summarize the following article about trees")
    c = emb("write me a very long epic fantasy story now!")
    assert a @ b > a @ c


def test_eos_bias_present(params):
    """The baked EOS bias must lift EOS probability so generations halt."""
    logits, _, _ = run_prefill(params, byte_tokens("x"))
    logits = np.asarray(logits)
    assert logits[C.EOS_ID] > np.median(logits)
