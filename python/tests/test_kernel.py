"""L1 correctness: Pallas flash-decode kernel vs the pure-jnp oracle.

This is the core numeric signal of the build path — hypothesis sweeps
shapes/lengths/values and asserts allclose against kernels.ref.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import flash_decode
from compile.kernels import ref


def make_inputs(rng, b, h, s, dh, lens):
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    return q, k, v, jnp.asarray(lens, jnp.int32)


def check(b, h, s, dh, lens, kv_block, seed=0):
    rng = np.random.default_rng(seed)
    q, k, v, lens = make_inputs(rng, b, h, s, dh, lens)
    got = flash_decode(q, k, v, lens, kv_block=kv_block)
    want = ref.ref_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_basic_full_length():
    check(b=2, h=2, s=64, dh=8, lens=[64, 64], kv_block=32)


def test_partial_lengths():
    check(b=4, h=4, s=128, dh=16, lens=[1, 17, 64, 128], kv_block=64)


def test_single_token_context():
    # first decode step right after a 1-token prompt
    check(b=1, h=1, s=64, dh=8, lens=[1], kv_block=64)


def test_idle_lane_len_zero():
    # idle padded lanes carry len=0; output must be finite (zeros), not NaN
    rng = np.random.default_rng(3)
    q, k, v, lens = make_inputs(rng, 2, 2, 64, 8, [0, 13])
    got = np.asarray(flash_decode(q, k, v, lens, kv_block=32))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[0], 0.0, atol=1e-6)
    want = np.asarray(ref.ref_decode_attention(q, k, v, lens))
    np.testing.assert_allclose(got[1], want[1], rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    # the same inputs must give the same answer for any KV block factor
    rng = np.random.default_rng(5)
    q, k, v, lens = make_inputs(rng, 2, 2, 128, 8, [77, 128])
    outs = [np.asarray(flash_decode(q, k, v, lens, kv_block=bs))
            for bs in (32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_extreme_scores_stable():
    # large-magnitude K/Q should not overflow the online softmax
    rng = np.random.default_rng(7)
    q, k, v, lens = make_inputs(rng, 1, 2, 64, 8, [64])
    q, k = q * 30.0, k * 30.0
    got = np.asarray(flash_decode(q, k, v, lens, kv_block=32))
    want = np.asarray(ref.ref_decode_attention(q, k, v, lens))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([4, 8, 16]),
    data=st.data(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(b, h, s_blocks, dh, data, seed):
    kv_block = 32
    s = kv_block * s_blocks
    lens = data.draw(st.lists(st.integers(0, s), min_size=b, max_size=b))
    check(b=b, h=h, s=s, dh=dh, lens=lens, kv_block=kv_block, seed=seed)


def test_attends_to_correct_positions():
    # put a distinctive value at one position; with len covering it and a
    # huge matching key, the output should be dominated by that value.
    b, h, s, dh = 1, 1, 64, 8
    q = jnp.ones((b, h, dh), jnp.float32)
    k = jnp.zeros((b, h, s, dh), jnp.float32).at[0, 0, 10].set(10.0)
    v = jnp.zeros((b, h, s, dh), jnp.float32).at[0, 0, 10].set(7.0)
    out = np.asarray(flash_decode(q, k, v, jnp.asarray([32], jnp.int32),
                                  kv_block=32))
    assert out[0, 0, 0] > 6.5
    # mask it out: len=10 excludes position 10 entirely
    out2 = np.asarray(flash_decode(q, k, v, jnp.asarray([10], jnp.int32),
                                   kv_block=32))
    np.testing.assert_allclose(out2, 0.0, atol=1e-5)
