"""L2: the tiny decoder-only LM served by the rust coordinator.

Three entry points, each AOT-lowered to HLO text by ``aot.py``:

  * ``prefill``  — full causal forward over a padded prompt, emitting the
    next-token logits at the last valid position plus the populated KV cache.
    Compiled at batch 1 (one prompt at a time, vLLM-style non-chunked
    prefill).
  * ``decode``   — one token step for a fixed lane batch, calling the L1
    Pallas flash-decode kernel (kernels.attention) against the KV cache and
    appending this step's K/V in place. This is the request-path hot loop.
  * ``embed``    — mean-pooled, L2-normalized token embedding of a prompt;
    the semantic embedder behind SageSched's history-based predictor.

Everything is a pure function of (params, inputs); ``aot.py`` closes over
deterministic params so the HLO artifacts are self-contained constants.
Sampling (temperature, EOS detection) happens in rust — keeping the
stochastic path out of the compiled graph is what lets the coordinator own
RNG seeds and reproduce runs.
"""

import jax
import jax.numpy as jnp

from . import config as C
from .kernels.attention import flash_decode


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x):
    """[..., H*Dh] -> [..., H, Dh]"""
    return x.reshape(x.shape[:-1] + (C.N_HEADS, C.D_HEAD))


def _merge_heads(x):
    """[..., H, Dh] -> [..., H*Dh]"""
    return x.reshape(x.shape[:-2] + (C.N_HEADS * C.D_HEAD,))


def _ffn(layer, x):
    h = jax.nn.gelu(x @ layer["w1"])
    return h @ layer["w2"]


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, tokens, length):
    """Causal forward over one padded prompt.

    tokens: [P] int32 (PAD-padded), length: [] int32 (valid prefix length)
    returns (logits [V], k_cache [L, H, S, Dh], v_cache [L, H, S, Dh])
    """
    p = tokens.shape[0]
    s = C.MAX_SEQ
    pos = jnp.arange(p)
    x = params["tok_emb"][tokens] + params["pos_emb"][:p]          # [P, D]

    valid = pos < length                                            # [P]
    causal = pos[:, None] >= pos[None, :]                           # [P, P]
    mask = causal & valid[None, :]                                  # [P, P]

    k_caches, v_caches = [], []
    for layer in params["layers"]:
        h = _layer_norm(x, layer["ln1_g"], layer["ln1_b"])
        q = _split_heads(h @ layer["wq"])                           # [P, H, Dh]
        k = _split_heads(h @ layer["wk"])
        v = _split_heads(h @ layer["wv"])
        scores = jnp.einsum("phd,qhd->hpq", q, k) / (C.D_HEAD ** 0.5)
        scores = jnp.where(mask[None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("hpq,qhd->phd", w, v)                      # [P, H, Dh]
        x = x + _merge_heads(att) @ layer["wo"]
        x = x + _ffn(layer, _layer_norm(x, layer["ln2_g"], layer["ln2_b"]))

        # pad K/V out to cache capacity S, layout [H, S, Dh]
        k_pad = jnp.zeros((C.N_HEADS, s, C.D_HEAD), jnp.float32)
        v_pad = jnp.zeros((C.N_HEADS, s, C.D_HEAD), jnp.float32)
        k_caches.append(k_pad.at[:, :p, :].set(k.transpose(1, 0, 2)))
        v_caches.append(v_pad.at[:, :p, :].set(v.transpose(1, 0, 2)))

    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits_all = x @ params["tok_emb"].T + params["eos_bias"]       # [P, V]
    last = jnp.clip(length - 1, 0, p - 1)
    logits = logits_all[last]                                       # [V]
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode(params, tokens, positions, k_cache, v_cache):
    """One decode step over B lanes.

    tokens:    [B] int32 — previously sampled token per lane
    positions: [B] int32 — index this token occupies (== current seq len - 1)
    k_cache:   [L, B, H, S, Dh]; v_cache same — caches *before* this step
    returns (logits [B, V], k_cache', v_cache') with this step's K/V written
    at ``positions``. Idle lanes should carry position 0 and PAD tokens;
    their outputs are ignored by the coordinator.
    """
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]    # [B, D]
    lens = positions + 1                                            # [B]

    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = _layer_norm(x, layer["ln1_g"], layer["ln1_b"])
        q = _split_heads(h @ layer["wq"])                           # [B, H, Dh]
        k = _split_heads(h @ layer["wk"])
        v = _split_heads(h @ layer["wv"])

        # write this step's K/V at `positions` (per-lane dynamic update)
        def write(cache, upd):
            # cache [B, H, S, Dh], upd [B, H, Dh]
            def one(c, u, p):
                return jax.lax.dynamic_update_slice(
                    c, u[:, None, :], (0, p, 0))
            return jax.vmap(one)(cache, upd, positions)

        kc = write(k_cache[li], k)
        vc = write(v_cache[li], v)
        new_k.append(kc)
        new_v.append(vc)

        att = flash_decode(q, kc, vc, lens)                         # [B, H, Dh]
        x = x + _merge_heads(att) @ layer["wo"]
        x = x + _ffn(layer, _layer_norm(x, layer["ln2_g"], layer["ln2_b"]))

    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["tok_emb"].T + params["eos_bias"]           # [B, V]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# embed (predictor-side semantic embedding)
# ---------------------------------------------------------------------------

def embed(params, tokens, length):
    """Mean-pooled, L2-normalized prompt embedding. tokens: [E], length: []"""
    e = tokens.shape[0]
    emb = params["tok_emb"][tokens]                                 # [E, D]
    pos = jnp.arange(e)
    w = (pos < length).astype(jnp.float32)[:, None]
    mean = jnp.sum(emb * w, axis=0) / jnp.maximum(jnp.sum(w), 1.0)
    norm = jnp.sqrt(jnp.sum(mean * mean)) + 1e-8
    return mean / norm
