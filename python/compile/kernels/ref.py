"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its reference here to float tolerance (checked by
``python/tests/test_kernel.py`` with hypothesis sweeps over shapes/values).
"""

import jax.numpy as jnp


def ref_decode_attention(q, k_cache, v_cache, lens):
    """Reference for kernels.attention.flash_decode.

    q:       [B, H, Dh]
    k_cache: [B, H, S, Dh]
    v_cache: [B, H, S, Dh]
    lens:    [B] int32 — valid KV length per sequence
    returns  [B, H, Dh]
    """
    b, h, dh = q.shape
    s = k_cache.shape[2]
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale
    idx = jnp.arange(s)[None, None, :]
    mask = idx < lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    # softmax with fully-masked-row safety (idle lanes with len == 0)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    w = p / denom
    return jnp.einsum("bhs,bhsd->bhd", w, v_cache)
