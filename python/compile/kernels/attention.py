"""L1: Pallas flash-decode attention kernel.

One decode step of multi-head attention against a (padded, fixed-capacity)
KV cache. This is the compute hot-spot of the serving path: per step, per
sequence, it streams the whole KV cache once — exactly the memory-bound
behaviour SageSched's cost model (C = O^2/2 + I*O) integrates over a
request's lifetime.

Hardware adaptation (paper targets CUDA GPUs): instead of one threadblock
per (batch, head) with shared-memory tiles, we give Pallas a grid over
(batch, head) and express the HBM->VMEM schedule with BlockSpecs: the
kernel instance sees its own q row and the full [S, Dh] K/V planes for its
(b, h), and walks them in VMEM-sized KV_BLOCK chunks with an online-softmax
(flash-decoding) accumulator. On a real TPU the chunk loop becomes the
MXU-feeding inner loop; on CPU we must run interpret=True (Mosaic
custom-calls cannot execute on the CPU PJRT plugin).

VMEM budget per instance (S=256, Dh=16, f32):
  K plane 16 KiB + V plane 16 KiB + q/acc/stats < 1 KiB  => ~33 KiB,
comfortably under the ~16 MiB/core VMEM of contemporary TPUs; the design
scales to S=8k (1 MiB/plane) before block-level double buffering of the
K/V planes themselves becomes necessary.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import config as C


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, kv_block: int):
    """Kernel body for one (batch, head) grid instance.

    len_ref: [1]      int32  — valid KV length for this sequence (<= S)
    q_ref:   [Dh]     f32    — this step's query row (batch/head dims squeezed)
    k_ref:   [S, Dh]  f32    — cached keys (padded with garbage past len)
    v_ref:   [S, Dh]  f32    — cached values
    o_ref:   [Dh]     f32    — attention output
    """
    seq_len = len_ref[0]
    q = q_ref[:]  # [Dh]
    scale = jnp.float32(1.0 / (q.shape[-1] ** 0.5))

    s_total = k_ref.shape[0]
    n_blocks = s_total // kv_block

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        start = i * kv_block
        k_blk = k_ref[pl.ds(start, kv_block), :]          # [BS, Dh]
        v_blk = v_ref[pl.ds(start, kv_block), :]          # [BS, Dh]
        scores = (k_blk @ q) * scale                       # [BS]
        idx = start + jax.lax.iota(jnp.int32, kv_block)
        scores = jnp.where(idx < seq_len, scores, -jnp.inf)
        m_cur = jnp.maximum(m_prev, jnp.max(scores))
        # guard the all-masked-block case: exp(-inf - -inf) -> use safe max
        m_safe = jnp.where(jnp.isneginf(m_cur), 0.0, m_cur)
        p = jnp.exp(scores - m_safe)                       # [BS]
        p = jnp.where(idx < seq_len, p, 0.0)
        corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_cur = l_prev * corr + jnp.sum(p)
        acc_cur = acc_prev * corr + p @ v_blk              # [Dh]
        return m_cur, l_cur, acc_cur

    m0 = jnp.float32(-jnp.inf)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros_like(q)
    _, l_fin, acc_fin = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    # seq_len >= 1 always holds on the decode path (the current token's KV
    # is written before attention), but stay safe for padded idle lanes.
    denom = jnp.where(l_fin > 0.0, l_fin, 1.0)
    o_ref[:] = acc_fin / denom


def flash_decode(q, k_cache, v_cache, lens, *, kv_block: int = C.KV_BLOCK,
                 interpret: bool = True):
    """Batched flash-decode attention.

    q:       [B, H, Dh]    current-step queries
    k_cache: [B, H, S, Dh] padded key cache
    v_cache: [B, H, S, Dh] padded value cache
    lens:    [B] int32     valid lengths (including the current position)
    returns  [B, H, Dh]
    """
    b, h, dh = q.shape
    s = k_cache.shape[2]
    assert s % kv_block == 0, (s, kv_block)
    assert k_cache.shape == (b, h, s, dh) and v_cache.shape == (b, h, s, dh)

    kernel = functools.partial(_flash_decode_kernel, kv_block=kv_block)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),                  # lens[b]
            pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),  # q[b, h]
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
