"""L1: Pallas kernels for the serving hot-spot (+ pure-jnp oracles)."""
