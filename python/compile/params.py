"""Deterministic parameter initialization for the tiny decoder-only LM.

Weights are generated from a fixed PRNG seed and *baked into the HLO text as
constants* by ``aot.py`` — the rust side never handles a weights file, which
keeps the artifact path identical to the reference round-trip
(/opt/xla-example/load_hlo).
"""

import jax
import jax.numpy as jnp

from . import config as C


def init_params(seed: int = C.SEED):
    """Build the parameter pytree. Scales follow standard transformer init."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + 8 * C.N_LAYERS)
    it = iter(range(len(ks)))

    def normal(key, shape, scale):
        return (scale * jax.random.normal(key, shape)).astype(jnp.float32)

    d, h, dh, dff = C.D_MODEL, C.N_HEADS, C.D_HEAD, C.D_FF
    params = {
        # token embedding is tied with the unembedding projection
        "tok_emb": normal(ks[next(it)], (C.VOCAB, d), 0.02),
        "pos_emb": normal(ks[next(it)], (C.MAX_SEQ, d), 0.01),
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "layers": [],
    }
    # bias the EOS logit upward so random weights still terminate generations
    # at plausible lengths (output-length uncertainty is the point).
    params["eos_bias"] = jnp.zeros((C.VOCAB,), jnp.float32).at[C.EOS_ID].set(1.5)

    for _ in range(C.N_LAYERS):
        layer = {
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "wq": normal(ks[next(it)], (d, h * dh), d ** -0.5),
            "wk": normal(ks[next(it)], (d, h * dh), d ** -0.5),
            "wv": normal(ks[next(it)], (d, h * dh), d ** -0.5),
            "wo": normal(ks[next(it)], (h * dh, d), (h * dh) ** -0.5),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "w1": normal(ks[next(it)], (d, dff), d ** -0.5),
            "w2": normal(ks[next(it)], (dff, d), dff ** -0.5),
        }
        params["layers"].append(layer)
    return params
