"""Shared model/artifact configuration for the SageSched tiny-LLM stack.

The rust runtime reads the same values from ``artifacts/meta.json`` (written
by ``aot.py``); keep this file the single source of truth on the python side.

The model is deliberately tiny (~115k params): the point of the real-model
path is to prove the three-layer stack composes (Pallas kernel -> jax model
-> HLO text -> rust/PJRT) and to produce *genuinely stochastic* output
lengths via temperature sampling to EOS — not to serve a production LLM.
"""

# --- tokenizer (byte-level; mirrored by rust/src/tokenizer/) ---
BYTE_VOCAB = 256
BOS_ID = 256
EOS_ID = 257
PAD_ID = 258
VOCAB = 259

# --- architecture ---
D_MODEL = 64
N_LAYERS = 2
N_HEADS = 4
D_HEAD = D_MODEL // N_HEADS
D_FF = 256

# --- compiled shapes ---
MAX_SEQ = 256      # KV-cache capacity per sequence (S)
PREFILL_LEN = 64   # fixed prompt pad length for the prefill executable (P)
DECODE_BATCH = 8   # fixed lane count for the decode executable (B)
EMBED_LEN = 64     # fixed pad length for the embedder executable

# --- pallas kernel tiling ---
KV_BLOCK = 64      # flash-decode KV block size (S must be a multiple)

SEED = 0

META = dict(
    vocab=VOCAB, bos_id=BOS_ID, eos_id=EOS_ID, pad_id=PAD_ID,
    d_model=D_MODEL, n_layers=N_LAYERS, n_heads=N_HEADS, d_head=D_HEAD,
    d_ff=D_FF, max_seq=MAX_SEQ, prefill_len=PREFILL_LEN,
    decode_batch=DECODE_BATCH, embed_len=EMBED_LEN, kv_block=KV_BLOCK,
    seed=SEED,
)
