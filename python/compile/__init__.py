"""Build-time python package: L1 Pallas kernels + L2 jax model + AOT export.

Never imported at runtime — `make artifacts` runs once, the rust binary is
self-contained afterwards.
"""
