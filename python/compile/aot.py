"""AOT export: lower the L2 model to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes:
  artifacts/prefill.hlo.txt   (tokens[P], length[])            -> (logits, k, v)
  artifacts/decode.hlo.txt    (tokens[B], pos[B], k, v)        -> (logits, k, v)
  artifacts/embed.hlo.txt     (tokens[E], length[])            -> (emb,)
  artifacts/meta.json         shape/vocab metadata for the rust loader
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as C
from . import model
from .params import init_params


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the model weights are
    baked into the graph as constants, and the default printer elides any
    literal bigger than a few elements as ``constant({...})`` — which the
    rust-side text parser would reject. f32 literals print with 9
    significant digits, enough to round-trip bit-exactly.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_all(params):
    """Lower the three entry points at their compiled shapes."""
    i32 = jnp.int32
    tok_p = jax.ShapeDtypeStruct((C.PREFILL_LEN,), i32)
    tok_e = jax.ShapeDtypeStruct((C.EMBED_LEN,), i32)
    scalar = jax.ShapeDtypeStruct((), i32)
    tok_b = jax.ShapeDtypeStruct((C.DECODE_BATCH,), i32)
    pos_b = jax.ShapeDtypeStruct((C.DECODE_BATCH,), i32)
    cache = jax.ShapeDtypeStruct(
        (C.N_LAYERS, C.DECODE_BATCH, C.N_HEADS, C.MAX_SEQ, C.D_HEAD),
        jnp.float32,
    )

    prefill = functools.partial(model.prefill, params)
    decode = functools.partial(model.decode, params)
    embed = functools.partial(model.embed, params)

    return {
        "prefill": jax.jit(prefill).lower(tok_p, scalar),
        # donate the KV caches: the emitted input_output_alias lets PJRT
        # update them in place instead of materializing fresh 1 MB outputs
        # each step (§Perf L2)
        "decode": jax.jit(decode, donate_argnums=(2, 3)).lower(
            tok_b, pos_b, cache, cache
        ),
        "embed": jax.jit(embed).lower(tok_e, scalar),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    params = init_params()
    for name, lowered in lower_all(params).items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")

    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(C.META, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
