//! END-TO-END DRIVER: serve real batched requests through the whole stack.
//!
//! This is the proof that all three layers compose: prompts go over HTTP to
//! the rust coordinator, which schedules them with SageSched (history
//! predictor + resource-bound cost + bucketed Gittins), batches them onto
//! the PJRT-compiled tiny LM (jax L2 + Pallas flash-decode L1, AOT-lowered
//! to HLO text by `make artifacts`), samples real tokens at temperature
//! 0.6, and streams back genuinely stochastic-length completions. Python is
//! not running anywhere.
//!
//! ```text
//! make artifacts   # once
//! cargo run --release --example real_model_serving -- --requests 24 --concurrency 6
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use sagesched::config::{ExperimentConfig, PreemptMode};
use sagesched::engine::RealEngine;
use sagesched::runtime::Runtime;
use sagesched::serve::Coordinator;
use sagesched::util::cli::Args;
use sagesched::util::json::Json;
use sagesched::util::stats::Summary;

const PROMPTS: [&str; 8] = [
    "tell me a short story about glaciers",
    "summarize the following article about enzymes and proteins",
    "write a long detailed document about violins",
    "let's chat about planets, what's up?",
    "explain the rules of auctions briefly",
    "compose a ballad about fjords and turbines",
    "what are theorems and lemmas good for?",
    "draft an essay on markets and contracts",
];

fn post_generate(addr: std::net::SocketAddr, prompt: &str) -> anyhow::Result<Json> {
    let body = Json::obj(vec![("prompt", Json::str(prompt))]).to_string();
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST /v1/generate HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let json_start = response.find("\r\n\r\n").map(|i| i + 4).unwrap_or(0);
    Json::parse(&response[json_start..]).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.str_or("artifacts", "artifacts");
    let n_requests = args.usize_or("requests", 24);
    let concurrency = args.usize_or("concurrency", 6);

    if !Runtime::artifacts_present(&artifacts) {
        eprintln!("artifacts not found under `{artifacts}` — run `make artifacts` first");
        std::process::exit(2);
    }

    // --- bring up the serving stack -------------------------------------
    let rt = Runtime::load(&artifacts)?;
    println!(
        "loaded model: {} layers, {} heads, vocab {}, {} decode lanes",
        rt.meta().n_layers,
        rt.meta().n_heads,
        rt.meta().vocab,
        rt.meta().decode_batch
    );
    let cfg = ExperimentConfig::default();
    let engine = RealEngine::new(rt, cfg.seed);
    let policy = sagesched::sched::make_policy(&cfg);
    let predictor = sagesched::predictor::make_predictor(
        cfg.predictor,
        engine.runtime().meta().d_model,
        cfg.history_capacity,
        cfg.similarity_threshold,
        cfg.seed,
    );
    let cost = sagesched::cost::make_cost_model(cfg.cost_model);
    let coord = Coordinator::new(engine, policy, predictor, cost, PreemptMode::Recompute);
    let handle = sagesched::server::serve("127.0.0.1:0", coord)?;
    let addr = handle.addr;
    println!("serving on http://{addr} with policy sagesched\n");

    // --- fire batched client load ----------------------------------------
    let t0 = Instant::now();
    let next = Arc::new(AtomicUsize::new(0));
    let mut workers = Vec::new();
    let results = Arc::new(std::sync::Mutex::new(Vec::<(String, f64, f64, f64, String)>::new()));
    for _ in 0..concurrency {
        let next = next.clone();
        let results = results.clone();
        workers.push(std::thread::spawn(move || loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= n_requests {
                break;
            }
            let prompt = PROMPTS[i % PROMPTS.len()];
            let sent = Instant::now();
            match post_generate(addr, prompt) {
                Ok(j) => {
                    let wall = sent.elapsed().as_secs_f64();
                    let out = j.f64_or("output_tokens", 0.0);
                    let ttft = j.f64_or("ttft_s", f64::NAN);
                    let ttlt = j.f64_or("ttlt_s", f64::NAN);
                    let text: String =
                        j.str_or("text", "").chars().take(24).collect();
                    results.lock().unwrap().push((
                        prompt.chars().take(28).collect(),
                        out,
                        ttft,
                        ttlt.max(wall.min(ttlt + 1.0)),
                        text,
                    ));
                }
                Err(e) => eprintln!("request {i} failed: {e}"),
            }
        }));
    }
    for w in workers {
        let _ = w.join();
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // --- report -----------------------------------------------------------
    let results = results.lock().unwrap();
    println!("| prompt | out tokens | TTFT (s) | TTLT (s) |");
    println!("|---|---|---|---|");
    for (p, o, ft, lt, _) in results.iter().take(12) {
        println!("| {p} | {o:.0} | {ft:.3} | {lt:.3} |");
    }
    if results.len() > 12 {
        println!("| ... ({} more) | | | |", results.len() - 12);
    }
    let ttlts: Vec<f64> = results.iter().map(|r| r.3).collect();
    let ttfts: Vec<f64> = results.iter().map(|r| r.2).collect();
    let toks: f64 = results.iter().map(|r| r.1).sum();
    let lt = Summary::of(&ttlts);
    let ft = Summary::of(&ttfts);
    println!("\ncompleted {}/{} requests in {elapsed:.2}s", results.len(), n_requests);
    println!("throughput     : {:.2} req/s | {:.1} tokens/s", results.len() as f64 / elapsed, toks / elapsed);
    println!("TTLT mean/p99  : {:.3} / {:.3} s", lt.mean, lt.p99);
    println!("TTFT mean/p99  : {:.3} / {:.3} s", ft.mean, ft.p99);
    let lens: Vec<f64> = results.iter().map(|r| r.1).collect();
    let ls = Summary::of(&lens);
    println!(
        "output lengths : min {:.0} / median {:.0} / max {:.0}  (stochastic: temperature 0.6)",
        ls.min, ls.p50, ls.max
    );

    assert!(results.len() == n_requests, "all requests must complete");
    handle.stop();
    Ok(())
}
