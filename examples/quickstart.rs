//! Quickstart: run one simulated serving experiment with SageSched and
//! print the report — the 20-line introduction to the public API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use sagesched::prelude::*;

fn main() -> anyhow::Result<()> {
    // Default config = the paper's defaults: SageSched policy (Gittins +
    // 200-token bucket refresh), semantic-aware history predictor
    // (threshold 0.8, 10k FIFO window), resource-bound cost model
    // (C = O²/2 + I·O), mixed ShareGPT/Alpaca/Write workload at 8 RPS on
    // the A40-Llama3.1-8B profile.
    let mut cfg = ExperimentConfig::default();
    cfg.workload.n_requests = 600;

    let report = run_experiment(&cfg)?;

    println!("policy        : {}", report.policy);
    println!("predictor     : {}", report.predictor);
    println!("cost model    : {}", report.cost_model);
    println!("requests      : {}", report.measured);
    println!("mean TTLT     : {:.2} s", report.ttlt.mean);
    println!("p99  TTLT     : {:.2} s", report.ttlt.p99);
    println!("mean TTFT     : {:.3} s", report.ttft.mean);
    println!("mean TPOT     : {:.1} ms/token", report.tpot.mean * 1e3);
    println!("throughput    : {:.2} req/s", report.throughput);
    println!("preemptions   : {}", report.preemptions);
    println!("GPU util est. : {:.0}%", report.mean_utilization * 100.0);

    // compare against the production default (FCFS) on the same workload
    cfg.policy = PolicyKind::Fcfs;
    let fcfs = run_experiment(&cfg)?;
    let gain = (fcfs.ttlt.mean - report.ttlt.mean) / fcfs.ttlt.mean * 100.0;
    println!(
        "\nvs FCFS       : {:.2} s mean TTLT  ->  SageSched is {gain:.1}% better",
        fcfs.ttlt.mean
    );
    Ok(())
}
