//! Mixed-workload scheduler shoot-out: the paper's six schedulers over the
//! mixed ShareGPT/Alpaca/Write trace, at a configurable load.
//!
//! ```text
//! cargo run --release --example mixed_workload -- --rps 8 --n 1200 --engine h800-qwen32b
//! ```

use sagesched::config::{EngineProfile, PolicyKind, PredictorKind};
use sagesched::metrics::RunReport;
use sagesched::prelude::*;
use sagesched::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rps = args.f64_or("rps", 8.0);
    let n = args.usize_or("n", 1200);
    let engine = EngineProfile::by_name(&args.str_or("engine", "h800-qwen32b"))
        .expect("unknown engine profile");
    let seeds: Vec<u64> = (0..args.u64_or("seeds", 2)).collect();

    println!(
        "# mixed workload: {} @ {rps} rps, {n} requests, {} seed(s)\n",
        engine.name,
        seeds.len()
    );
    println!("{}", RunReport::markdown_header());

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for policy in PolicyKind::PAPER_BASELINES {
        let mut ttlt = 0.0;
        let mut last = None;
        for &seed in &seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.engine = engine.clone();
            cfg.policy = policy;
            // each baseline uses the predictor its paper describes
            cfg.predictor = match policy {
                PolicyKind::Ssjf => PredictorKind::Proxy,
                _ => PredictorKind::History,
            };
            cfg.workload.rps = rps;
            cfg.workload.n_requests = n;
            cfg.seed = seed;
            let report = run_experiment(&cfg)?;
            ttlt += report.ttlt.mean;
            last = Some(report);
        }
        let report = last.unwrap();
        println!("{}", report.markdown_row());
        rows.push((policy.name().to_string(), ttlt / seeds.len() as f64, report.ttft.mean));
    }

    let sage = rows.iter().find(|(n, _, _)| n == "sagesched").unwrap().1;
    let best_other = rows
        .iter()
        .filter(|(n, _, _)| n != "sagesched")
        .map(|(_, t, _)| *t)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nSageSched vs best baseline: {:+.1}% mean TTLT",
        (best_other - sage) / best_other * 100.0
    );
    Ok(())
}
