//! Cluster-scale serving: N simulated GPU nodes behind a least-loaded
//! router, plus the fig12 shared-predictor overhead measurement.
//!
//! ```text
//! cargo run --release --example cluster_sim -- --nodes 8 --rps 8
//! ```

use sagesched::cluster::{run_cluster_experiment, ClusterSim};
use sagesched::prelude::*;
use sagesched::util::cli::Args;
use sagesched::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.usize_or("nodes", 8);
    let mut cfg = ExperimentConfig::default();
    cfg.workload.rps = args.f64_or("rps", 8.0);
    cfg.workload.n_requests = args.usize_or("n-per-node", 400);

    println!("# {nodes}-node cluster, {} rps/node\n", cfg.workload.rps);
    let reports = run_cluster_experiment(&cfg, nodes)?;
    println!("| node | requests | mean TTLT | p99 TTLT | mean TTFT |");
    println!("|---|---|---|---|---|");
    for (i, r) in reports.iter().enumerate() {
        println!(
            "| {i} | {} | {:.2} | {:.2} | {:.3} |",
            r.measured, r.ttlt.mean, r.ttlt.p99, r.ttft.mean
        );
    }
    let ttlts: Vec<f64> = reports.iter().map(|r| r.ttlt.mean).collect();
    println!(
        "\ncluster mean TTLT {:.2}s (node spread {:.2}..{:.2})",
        mean(&ttlts),
        ttlts.iter().cloned().fold(f64::INFINITY, f64::min),
        ttlts.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );

    // shared predictor/scheduler overhead at this scale (fig12)
    let sim = ClusterSim::new(cfg);
    let o = sim.measure(nodes);
    println!(
        "\nper-request overhead at {nodes} nodes: predict {:.2} ms + sched {:.2} ms = {:.2} ms",
        o.predict_latency * 1e3,
        o.sched_latency * 1e3,
        o.total_latency * 1e3
    );
    Ok(())
}
