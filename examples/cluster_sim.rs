//! Cluster-scale serving: an event-driven N-replica simulation comparing
//! the pluggable routers on one seeded workload — optionally under bursty
//! (MMPP) or diurnal arrivals, mid-run replica outages, and elastic
//! autoscaling — plus the fig12 shared-predictor overhead measurement.
//!
//! ```text
//! cargo run --release --example cluster_sim -- --replicas 8 --rps 24 --n 800
//! cargo run --release --example cluster_sim -- --replicas 4 --speeds 1.0,0.5
//! cargo run --release --example cluster_sim -- --arrival mmpp --fail 0@8+6
//! cargo run --release --example cluster_sim -- --autoscale uncertainty
//! cargo run --release --example cluster_sim -- --autoscale step --scale-steps 5@8,20@2
//! ```

use sagesched::cluster::{run_router_experiment, ClusterSim};
use sagesched::config::{ArrivalKind, FailureEvent};
use sagesched::prelude::*;
use sagesched::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.replicas = args.usize_or("replicas", 8);
    cfg.workload.rps = args.f64_or("rps", 24.0);
    cfg.workload.n_requests = args.usize_or("n", 800);
    if let Some(s) = args.get("speeds") {
        let speeds: Result<Vec<f64>, _> =
            s.split(',').map(|x| x.trim().parse::<f64>()).collect();
        let speeds = speeds.map_err(|_| anyhow::anyhow!("--speeds: bad entry in {s:?}"))?;
        if speeds.iter().any(|&v| v <= 0.0) {
            return Err(anyhow::anyhow!("--speeds entries must be positive, got {s}"));
        }
        cfg.cluster.speeds = speeds;
    }
    if let Some(a) = args.get("arrival") {
        cfg.workload.arrival.kind = ArrivalKind::from_name(a)
            .ok_or_else(|| anyhow::anyhow!("unknown --arrival {a}"))?;
    }
    if let Some(f) = args.get("fail") {
        // replica@start+duration, comma-separated (same grammar as the CLI)
        cfg.cluster.failures =
            FailureEvent::parse_list(f).map_err(|e| anyhow::anyhow!("--fail: {e}"))?;
    }
    if let Some(a) = args.get("autoscale") {
        cfg.cluster.autoscale.kind = AutoscaleKind::from_name(a)
            .ok_or_else(|| anyhow::anyhow!("unknown --autoscale {a}"))?;
    }
    if let Some(s) = args.get("scale-steps") {
        // time@target, comma-separated (same grammar as the CLI)
        cfg.cluster.autoscale.steps = ScaleStep::parse_list(s)
            .map_err(|e| anyhow::anyhow!("--scale-steps: {e}"))?;
    }
    cfg.cluster
        .autoscale
        .validate()
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    println!(
        "# {}-replica cluster, {} requests @ {} rps cluster-wide ({} arrivals, {} outages, autoscale {})\n",
        cfg.cluster.replicas,
        cfg.workload.n_requests,
        cfg.workload.rps,
        cfg.workload.arrival.kind.name(),
        cfg.cluster.failures.len(),
        cfg.cluster.autoscale.kind.name()
    );
    println!("{}", ClusterReport::markdown_header());
    let mut best: Option<ClusterReport> = None;
    for router in RouterKind::ALL {
        let report = run_router_experiment(&cfg, router)?;
        println!("{}", report.markdown_row());
        if best
            .as_ref()
            .map(|b| report.aggregate.ttlt.mean < b.aggregate.ttlt.mean)
            .unwrap_or(true)
        {
            best = Some(report);
        }
    }
    let best = best.expect("at least one router ran");
    println!(
        "\nbest router: {} (mean TTLT {:.2}s, imbalance {:.2}, goodput {:.1}%, \
         {} re-routed, {} drained, {} stolen, {} steals skipped, \
         {:.0} replica-s, {:.3} goodput/replica-s)",
        best.router,
        best.aggregate.ttlt.mean,
        best.imbalance,
        best.aggregate.goodput() * 100.0,
        best.re_routed,
        best.drained,
        best.stolen,
        best.steals_skipped,
        best.total_replica_seconds(),
        best.goodput_per_replica_second
    );
    println!("\n## {} per-replica", best.router);
    println!("| replica | routed | completed | mean TTLT | p99 TTLT | downtime (s) | replica-s |");
    println!("|---|---|---|---|---|---|---|");
    for (i, r) in best.per_replica.iter().enumerate() {
        println!(
            "| {i} | {} | {} | {:.2} | {:.2} | {:.1} | {:.1} |",
            best.routed[i],
            r.measured,
            r.ttlt.mean,
            r.ttlt.p99,
            best.downtime[i],
            best.replica_seconds[i]
        );
    }
    if !best.scaling_events.is_empty() {
        println!("\n## scaling timeline ({})", best.router);
        println!("| t (s) | replica | event |");
        println!("|---|---|---|");
        for e in &best.scaling_events {
            println!("| {:.2} | {} | {} |", e.at, e.replica, e.action.name());
        }
    }

    // shared predictor/scheduler overhead at this scale (fig12)
    let nodes = cfg.cluster.replicas;
    let sim = ClusterSim::new(cfg);
    let o = sim.measure(nodes);
    println!(
        "\nper-request overhead at {nodes} nodes: predict {:.2} ms + sched {:.2} ms = {:.2} ms",
        o.predict_latency * 1e3,
        o.sched_latency * 1e3,
        o.total_latency * 1e3
    );
    Ok(())
}
