//! Offline stub of the `xla` (PJRT) crate.
//!
//! The container this workspace builds in has no PJRT shared library and no
//! network access, so the real `xla` crate cannot be fetched or linked. This
//! stub keeps the whole `runtime`/`engine::real` stack *compiling* with the
//! exact API surface those modules use, while making the execution entry
//! points (`PjRtClient::cpu`, `compile`, `execute`) return a descriptive
//! error. Everything downstream is already artifact-gated: `Runtime::load`
//! fails fast with this stub's error, and the artifact-gated tests and
//! examples skip or report gracefully.
//!
//! Host-side `Literal` containers are implemented for real (byte storage +
//! shape bookkeeping) so pure data-marshaling code paths stay honest.

use std::fmt;

/// Stub error type; converts into `anyhow::Error` at call sites via `?`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable in this offline build (xla stub)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the workspace marshals (F32 buffers, S32 token ids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Sealed host-native element trait for typed Literal construction/readout.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn to_le_bytes4(self) -> [u8; 4];
    fn from_le_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn to_le_bytes4(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le_bytes4(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-resident tensor of one element type.
#[derive(Debug, Clone)]
pub struct Literal {
    elem: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes4());
        }
        Literal { elem: T::ELEMENT_TYPE, dims: vec![values.len()], data }
    }

    fn elem_count(&self) -> usize {
        self.data.len() / self.elem.byte_size()
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elem_count() {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.elem_count()
            )));
        }
        Ok(Literal {
            elem: self.elem,
            dims: dims.iter().map(|&d| d as usize).collect(),
            data: self.data.clone(),
        })
    }

    /// Build a literal from a shape and raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        elem: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * elem.byte_size() != data.len() {
            return Err(Error(format!(
                "shape {dims:?} wants {} bytes, got {}",
                n * elem.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { elem, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Read the literal out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.elem {
            return Err(Error(format!(
                "element type mismatch: literal is {:?}",
                self.elem
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le_bytes4([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Copy raw contents into a host vector (resizing it to fit).
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut Vec<T>) -> Result<()> {
        let v = self.to_vec::<T>()?;
        dst.clear();
        dst.extend_from_slice(&v);
        Ok(())
    }

    /// Destructure a 1-tuple result (only produced by real executions).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    /// Destructure a 3-tuple result (only produced by real executions).
    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple3"))
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.elem
    }
}

impl From<i32> for Literal {
    fn from(v: i32) -> Literal {
        Literal {
            elem: ElementType::S32,
            dims: Vec::new(),
            data: v.to_le_bytes().to_vec(),
        }
    }
}

/// Parsed HLO module (opaque; the stub cannot actually parse HLO text).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("HLO file {path} not found")));
        }
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper (opaque).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. `cpu()` is the first call every loader makes; it fails fast
/// here so artifact-gated paths degrade before touching anything else.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn untyped_construction_checks_size() {
        let bytes = [0u8; 8];
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &bytes,
        )
        .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![0.0, 0.0]);
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes
        )
        .is_err());
    }

    #[test]
    fn copy_raw_to_fills_vec() {
        let l = Literal::vec1(&[5i32, 6]);
        let mut dst: Vec<i32> = Vec::new();
        l.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, vec![5, 6]);
    }

    #[test]
    fn execution_paths_fail_fast() {
        assert!(PjRtClient::cpu().is_err());
        let l = Literal::from(3);
        assert!(l.to_tuple1().is_err());
        assert!(l.to_tuple3().is_err());
    }
}
