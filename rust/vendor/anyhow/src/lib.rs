//! Offline shim of the `anyhow` crate: the subset of its API this workspace
//! uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`), implemented on a
//! plain message chain so the build needs no registry access. Semantics match
//! upstream for these entry points: `?` converts any `std::error::Error`,
//! `.context(..)` / `.with_context(..)` wrap `Result` and `Option`, and the
//! `Debug` impl prints the cause chain (what `fn main() -> Result<()>` shows).

use std::fmt;

/// A dynamically-typed error with a human-readable cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur)
        })
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.cause.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.cause.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`; that is
// what allows the blanket `From` below without overlapping `From<T> for T`
// (same design as upstream anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("chain has at least one entry")
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion into [`crate::Error`]; implemented for every
    /// `std::error::Error` *and* for `crate::Error` itself so `.context()`
    /// also works on already-wrapped `anyhow::Result` values (mirroring
    /// upstream anyhow's `ext::StdError` design).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to the error variant of a `Result` or to a `None`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: private::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_debug_chain() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("missing thing"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while reading").unwrap_err();
        assert_eq!(e.to_string(), "while reading");
        assert_eq!(e.root_cause().to_string(), "missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn context_on_already_wrapped_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause().to_string(), "inner");
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        let s = String::from("plain");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "plain");
        fn bails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 7");
    }
}
