//! SLO-class integration tests: per-class conservation across every router
//! with autoscaling and a failure in play, seeded determinism of class
//! assignment, the acceptance assert that class-aware serving improves
//! Interactive SLO attainment under overload without giving up total
//! goodput, a starvation guard (Batch still completes — and not merely in
//! the drain tail — under sustained Interactive pressure), the
//! failure-during-provisioning lifecycle regression, and the golden
//! byte-identical-ClusterReport-JSON determinism check.

use std::collections::BTreeSet;

use sagesched::autoscale::ScaleAction;
use sagesched::cluster::{run_router_experiment, EventCluster, ReplicaState};
use sagesched::config::{
    ArrivalKind, AutoscaleKind, ExperimentConfig, FailureEvent, PolicyKind,
    RouterKind, ScaleStep,
};
use sagesched::metrics::ClusterReport;
use sagesched::slo::SloClass;
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

fn by_class(ids: impl Iterator<Item = SloClass>) -> [u64; 3] {
    let mut out = [0u64; 3];
    for c in ids {
        out[c.index()] += 1;
    }
    out
}

#[test]
fn class_assignment_is_seeded_and_respects_the_mix() {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.n_requests = 600;
    let a = WorkloadGen::new(cfg.workload.clone(), 5).generate();
    let b = WorkloadGen::new(cfg.workload.clone(), 5).generate();
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert_eq!(x.slo, y.slo, "same seed must stamp identical classes");
        assert_eq!(x.arrival, y.arrival);
        assert_eq!(x.input_len, y.input_len);
    }
    let c = WorkloadGen::new(cfg.workload.clone(), 6).generate();
    let differs = a.requests.iter().zip(&c.requests).any(|(x, y)| x.slo != y.slo);
    assert!(differs, "different seeds must stamp differently");
    // the default 0.25/0.5/0.25 mix shows up within loose bounds
    let counts = by_class(a.requests.iter().map(|r| r.slo));
    let frac = |k: usize| counts[k] as f64 / 600.0;
    assert!((frac(0) - 0.25).abs() < 0.10, "interactive frac {}", frac(0));
    assert!((frac(1) - 0.50).abs() < 0.10, "standard frac {}", frac(1));
    assert!((frac(2) - 0.25).abs() < 0.10, "batch frac {}", frac(2));
    // a degenerate mix stamps exactly one class
    cfg.workload.slo_mix = vec![(SloClass::Batch, 1.0)];
    let d = WorkloadGen::new(cfg.workload.clone(), 5).generate();
    assert!(d.requests.iter().all(|r| r.slo == SloClass::Batch));
    // and never perturbs the arrival/sampling streams
    for (x, y) in a.requests.iter().zip(&d.requests) {
        assert_eq!(x.arrival, y.arrival, "slo mix must not shift arrivals");
        assert_eq!(x.input_len, y.input_len);
        assert_eq!(x.true_output_len, y.true_output_len);
    }
}

#[test]
fn per_class_conservation_across_routers_autoscaling_and_failure() {
    // class-aware serving with admission pressure, a scripted scale-out/in,
    // and a mid-run outage: for every router, every class's submissions
    // must be accounted for exactly — completed, rejected, or timed out —
    // with no cluster bookkeeping left behind
    let mut cfg = cluster_cfg(2, 200, 30.0);
    cfg.slo.class_aware = true;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.max_queue = 32;
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![
        ScaleStep { at: 1.5, target: 4 },
        ScaleStep { at: 4.5, target: 2 },
    ];
    cfg.cluster.autoscale.provision_delay = 0.5;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.failures = vec![FailureEvent { replica: 1, at: 2.5, duration: 1.5 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted = by_class(workload.requests.iter().map(|r| r.slo));
    assert!(submitted.iter().all(|&n| n > 0), "mix must cover all classes");
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        let outcomes = cluster.merged_outcomes();
        let completed = by_class(outcomes.iter().map(|o| o.slo));
        let rejected = cluster.rejected_by_class();
        let aborted = cluster.aborted_by_class();
        for class in SloClass::ALL {
            let k = class.index();
            assert_eq!(
                completed[k] + rejected[k] + aborted[k],
                submitted[k],
                "{router:?} lost {} requests",
                class.name()
            );
        }
        // the per-class split sums to the totals exactly once
        assert_eq!(rejected.iter().sum::<u64>(), cluster.rejected());
        assert_eq!(aborted.iter().sum::<u64>(), cluster.aborted());
        let ids: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids.len(), outcomes.len(), "{router:?} duplicated completions");
        assert_eq!(cluster.in_flight_count(), 0, "{router:?} leaked in-flight");
        assert!(cluster.total_backlog() < 1e-6, "{router:?} leaked backlog");
        assert!(
            cluster.weighted_backlog() < 1e-6,
            "{router:?} leaked weighted backlog"
        );
        // the report's per-class view agrees with the raw counters
        let report = cluster.report(0.0);
        for class in SloClass::ALL {
            let s = &report.aggregate.slo[class.name()];
            assert_eq!(s.completed, completed[class.index()], "{router:?}");
            assert_eq!(s.rejected, rejected[class.index()], "{router:?}");
            assert_eq!(s.aborted, aborted[class.index()], "{router:?}");
            assert_eq!(s.submitted(), submitted[class.index()], "{router:?}");
        }
        let wg = report.aggregate.slo_weighted_goodput();
        assert!((0.0..=1.0).contains(&wg), "{router:?} weighted goodput {wg}");
    }
}

#[test]
fn class_aware_serving_improves_interactive_attainment_under_overload() {
    // the acceptance scenario: a 2-replica cluster under ~3x sustained
    // overload, same seeded workload, class-blind vs class-aware. The
    // class-aware run must improve Interactive attainment (strictly, and
    // by a real margin) without giving up total goodput.
    let blind = cluster_cfg(2, 400, 24.0);
    let mut aware = blind.clone();
    aware.slo.class_aware = true;
    let b = run_router_experiment(&blind, RouterKind::QuantileCost).unwrap();
    let a = run_router_experiment(&aware, RouterKind::QuantileCost).unwrap();
    // both runs are lossless here (no admission bound, no timeout), so the
    // goodput guard is exact; the attainment gap is the point
    assert_eq!(b.aggregate.completed, 400, "blind run lossy");
    assert_eq!(a.aggregate.completed, 400, "aware run lossy");
    assert!(
        a.aggregate.goodput() >= b.aggregate.goodput() - 0.02,
        "class-aware gave up goodput: {} vs {}",
        a.aggregate.goodput(),
        b.aggregate.goodput()
    );
    let b_int = &b.aggregate.slo["interactive"];
    let a_int = &a.aggregate.slo["interactive"];
    assert!(b_int.submitted() > 0 && a_int.submitted() > 0);
    assert!(
        a_int.attainment() > b_int.attainment() + 0.05,
        "interactive attainment: aware {} !>> blind {}",
        a_int.attainment(),
        b_int.attainment()
    );
    // the latency story behind the attainment gap points the same way
    assert!(
        a_int.ttlt.mean < b_int.ttlt.mean,
        "aware interactive TTLT {} !< blind {}",
        a_int.ttlt.mean,
        b_int.ttlt.mean
    );
    // and the weighted headline metric improves with it
    assert!(
        a.aggregate.slo_weighted_goodput() > b.aggregate.slo_weighted_goodput(),
        "slo-weighted goodput: aware {} !> blind {}",
        a.aggregate.slo_weighted_goodput(),
        b.aggregate.slo_weighted_goodput()
    );
}

#[test]
fn batch_still_completes_under_sustained_interactive_load() {
    // starvation guard: interactive traffic alone exceeds cluster capacity
    // for the whole run; batch requests (with a deliberately shortened
    // deadline so aging engages in-run) must all complete, and not merely
    // in the drain tail after interactive pressure ends
    let mut cfg = cluster_cfg(2, 200, 14.0);
    cfg.slo.class_aware = true;
    cfg.workload.slo_mix =
        vec![(SloClass::Interactive, 0.85), (SloClass::Batch, 0.15)];
    cfg.slo.specs.spec_mut(SloClass::Batch).ttft_target = 4.0;
    cfg.slo.specs.spec_mut(SloClass::Batch).ttlt_target = 12.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted = by_class(workload.requests.iter().map(|r| r.slo));
    assert!(submitted[SloClass::Batch.index()] > 0);
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let outcomes = cluster.merged_outcomes();
    let completed = by_class(outcomes.iter().map(|o| o.slo));
    assert_eq!(
        completed[SloClass::Batch.index()],
        submitted[SloClass::Batch.index()],
        "batch starved"
    );
    assert_eq!(cluster.aborted(), 0);
    assert_eq!(cluster.rejected(), 0);
    // aging really interleaves batch with the interactive stream: some
    // batch request finishes before the last interactive does
    let first_batch_done = outcomes
        .iter()
        .filter(|o| o.slo == SloClass::Batch)
        .map(|o| o.completion)
        .fold(f64::INFINITY, f64::min);
    let last_interactive_done = outcomes
        .iter()
        .filter(|o| o.slo == SloClass::Interactive)
        .map(|o| o.completion)
        .fold(0.0, f64::max);
    assert!(
        first_batch_done < last_interactive_done,
        "batch only ran in the drain tail: first batch {first_batch_done} \
         vs last interactive {last_interactive_done}"
    );
}

#[test]
fn failure_during_provisioning_conserves_and_keeps_timeline_consistent() {
    // regression: an outage hitting a replica that autoscaling has spawned
    // but that has not yet joined the routable set. The replica must go
    // down, then *resume* provisioning at recovery — an outage must never
    // hand the cluster capacity before the provisioning delay elapses —
    // and come up exactly at its originally scheduled spawn-ready instant.
    // Conservation must be exact and the timeline must read
    // provision -> fail -> recover -> up.
    let mut cfg = cluster_cfg(2, 200, 30.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 1.0, target: 3 }];
    cfg.cluster.autoscale.provision_delay = 3.0; // ready at t=4 ...
    cfg.cluster.autoscale.interval = 1.0;
    // ... but the outage hits at t=2, while still provisioning
    cfg.cluster.failures = vec![FailureEvent { replica: 2, at: 2.0, duration: 1.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 200, "lost requests");
    assert_eq!(cluster.in_flight_count(), 0);
    let events: Vec<(f64, ScaleAction)> = cluster
        .scaling_events
        .iter()
        .filter(|e| e.replica == 2)
        .map(|e| (e.at, e.action))
        .collect();
    assert_eq!(
        events,
        vec![
            (1.0, ScaleAction::Provision),
            (2.0, ScaleAction::Fail),
            (3.0, ScaleAction::Recover),
            (4.0, ScaleAction::Up),
        ],
        "inconsistent lifecycle timeline for the provisioning-failed replica"
    );
    // recovered into the routable set and actually served
    assert_eq!(cluster.replicas[2].state, ReplicaState::Active);
    assert!(cluster.routed[2] > 0, "recovered replica never routed to");
    let report = cluster.report(0.0);
    assert!(
        (report.downtime[2] - 1.0).abs() < 1e-9,
        "downtime {} != outage duration 1.0",
        report.downtime[2]
    );
}

#[test]
fn failure_on_never_provisioned_replica_is_a_hard_error() {
    // with autoscaling on, outage targets beyond the initial fleet are
    // legal *if* the scaler has spawned them by fire time; one that never
    // exists must fail loudly at that instant, not silently no-op
    let mut cfg = cluster_cfg(2, 40, 20.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 1.0, target: 2 }]; // never grows
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.failures = vec![FailureEvent { replica: 7, at: 0.5, duration: 1.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    let err = cluster.run(workload.requests).unwrap_err();
    assert!(err.to_string().contains("replica 7"), "got: {err}");
}

/// Serialize a cluster report with the wallclock-measured overhead fields
/// zeroed: they are the only nondeterministic numbers in the report, and
/// the point of the golden test is that *everything else* is byte-stable.
fn deterministic_json(mut r: ClusterReport) -> String {
    r.aggregate.predict_overhead = 0.0;
    r.aggregate.sched_overhead = 0.0;
    for pr in &mut r.per_replica {
        pr.predict_overhead = 0.0;
        pr.sched_overhead = 0.0;
    }
    r.to_json().to_string()
}

#[test]
fn golden_cluster_report_json_is_byte_identical_across_runs() {
    // the full surface at once — class-aware serving, heterogeneous fleet,
    // MMPP bursts, uncertainty-aware autoscaling, an outage, admission
    // pressure — twice at one seed: the serialized ClusterReport must match
    // byte for byte (this is what catches HashMap-iteration-order creep
    // before it corrupts an A/B comparison)
    let mut cfg = cluster_cfg(3, 160, 24.0);
    cfg.slo.class_aware = true;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5];
    cfg.max_queue = 24;
    cfg.request_timeout = 30.0;
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 2.0, duration: 1.5 }];
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 6;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 1.0;
    let a = run_router_experiment(&cfg, RouterKind::QuantileCost).unwrap();
    let b = run_router_experiment(&cfg, RouterKind::QuantileCost).unwrap();
    let ja = deterministic_json(a);
    let jb = deterministic_json(b);
    assert_eq!(ja, jb, "ClusterReport JSON differs between identical runs");
}
