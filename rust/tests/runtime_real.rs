//! Artifact-gated tests over the real PJRT runtime and engine.
//!
//! These run only when `artifacts/` exists (`make artifacts`); otherwise
//! each test is a no-op pass so `cargo test` stays green on a fresh clone.
//! The numeric teacher-forcing consistency check mirrors
//! `python/tests/test_model.py::test_decode_matches_prefill` — but through
//! the compiled HLO artifacts and the rust runtime, proving the AOT bridge
//! preserves semantics end to end.

use sagesched::config::{DatasetKind, ExperimentConfig, PreemptMode};
use sagesched::core::Request;
use sagesched::embedding::{Embedder, Embedding};
use sagesched::engine::{Engine, LaneState, RealEngine};
use sagesched::runtime::{HloEmbedder, Runtime};
use sagesched::serve::Coordinator;

const DIR: &str = "artifacts";

fn runtime() -> Option<Runtime> {
    if !Runtime::artifacts_present(DIR) {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(DIR).expect("artifacts load"))
}

fn req(id: u64, prompt: &str) -> Request {
    Request {
        id,
        prompt: prompt.to_string(),
        input_len: prompt.len() as u32 + 1,
        true_output_len: u32::MAX,
        arrival: 0.0,
        dataset: DatasetKind::ShareGpt,
        topic: 0,
        embedding: Embedding::normalize(vec![1.0; 64]),
        true_dist: None,
        slo: sagesched::slo::SloClass::Standard,
        prefix_key: Vec::new(),
    }
}

#[test]
fn loads_and_reports_meta() {
    let Some(rt) = runtime() else { return };
    let m = rt.meta();
    assert_eq!(m.vocab, 259);
    assert_eq!(m.d_head * m.n_heads, m.d_model);
    assert!(m.max_seq >= m.prefill_len);
}

#[test]
fn prefill_shapes_and_finiteness() {
    let Some(rt) = runtime() else { return };
    let toks = sagesched::tokenizer::encode("hello world");
    let out = rt.run_prefill(&toks).unwrap();
    assert_eq!(out.logits.len(), rt.meta().vocab);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    let lane = rt.meta().n_layers * rt.meta().lane_elems();
    assert_eq!(out.k.len(), lane);
    assert_eq!(out.v.len(), lane);
    // prompt KV must be non-trivial
    assert!(out.k.iter().map(|x| x.abs()).sum::<f32>() > 0.0);
}

#[test]
fn embed_normalized_and_discriminative() {
    let Some(rt) = runtime() else { return };
    let mut e = HloEmbedder { rt: &rt };
    let a = e.embed("please summarize this article about birds");
    let b = e.embed("please summarize this article about crows");
    let c = e.embed("write a long poem");
    let norm: f32 = a.0.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3);
    assert!(a.cosine(&b) > a.cosine(&c), "semantic ordering violated");
}

#[test]
fn decode_teacher_forcing_matches_prefill() {
    // prefill(t[..k]) + decode(t[k..]) must equal prefill(t) — through the
    // compiled artifacts (the same invariant python tests check pre-AOT)
    let Some(rt) = runtime() else { return };
    let text = "the quick brown fox";
    let toks = sagesched::tokenizer::encode(text);
    let split = 4usize;

    let full = rt.run_prefill(&toks).unwrap();

    let prefix = rt.run_prefill(&toks[..split]).unwrap();
    let m = rt.meta().clone();
    let mut k = vec![0.0f32; m.cache_elems()];
    let mut v = vec![0.0f32; m.cache_elems()];
    // install prefix KV into lane 0
    let lane_elems = m.lane_elems();
    let layer_stride = m.decode_batch * lane_elems;
    for l in 0..m.n_layers {
        let src = l * lane_elems..(l + 1) * lane_elems;
        let dst = l * layer_stride;
        k[dst..dst + lane_elems].copy_from_slice(&prefix.k[src.clone()]);
        v[dst..dst + lane_elems].copy_from_slice(&prefix.v[src]);
    }
    let mut logits = prefix.logits.clone();
    for (j, &tok) in toks[split..].iter().enumerate() {
        let mut t = vec![m.pad_id as i32; m.decode_batch];
        let mut p = vec![0i32; m.decode_batch];
        t[0] = tok as i32;
        p[0] = (split + j) as i32;
        let out = rt.run_decode(&t, &p, &k, &v).unwrap();
        k = out.k;
        v = out.v;
        logits = out.logits[..m.vocab].to_vec();
    }
    let max_diff = logits
        .iter()
        .zip(&full.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "teacher forcing deviates: max diff {max_diff}");
}

#[test]
fn engine_generates_stochastic_lengths() {
    let Some(rt) = runtime() else { return };
    let mut eng = RealEngine::new(rt, 9);
    let mut lens = Vec::new();
    for t in 0..8 {
        let r = req(t, "tell me something interesting about cellos");
        let pr = eng.prefill(&r).unwrap();
        let mut generated = 1;
        if !pr.finished {
            let mut lanes = vec![LaneState::new(&r, 1)];
            while !lanes[0].finished && lanes[0].generated < 150 {
                eng.decode_step(&mut lanes, 0).unwrap();
            }
            generated = lanes[0].generated;
        }
        eng.evict(r.id);
        lens.push(generated);
    }
    assert!(lens.iter().all(|&l| l >= 1));
    let distinct: std::collections::BTreeSet<u32> = lens.iter().copied().collect();
    assert!(distinct.len() > 1, "lengths must vary: {lens:?}");
}

#[test]
fn engine_batches_multiple_lanes() {
    let Some(rt) = runtime() else { return };
    let mut eng = RealEngine::new(rt, 10);
    let reqs: Vec<Request> = (0..3).map(|i| req(i, "batched decoding test")).collect();
    let mut lanes = Vec::new();
    for r in &reqs {
        let pr = eng.prefill(r).unwrap();
        if !pr.finished {
            lanes.push(LaneState::new(r, 1));
        }
    }
    if lanes.is_empty() {
        return; // all finished at prefill — fine
    }
    eng.decode_step(&mut lanes, 0).unwrap();
    for l in &lanes {
        assert_eq!(l.generated, 2);
        assert!(l.emitted);
    }
}

#[test]
fn preempt_resume_preserves_generated_prefix() {
    let Some(rt) = runtime() else { return };
    let mut eng = RealEngine::new(rt, 11);
    let r = req(1, "write about gardens");
    let pr = eng.prefill(&r).unwrap();
    if pr.finished {
        return;
    }
    let mut lanes = vec![LaneState::new(&r, 1)];
    for _ in 0..4 {
        if lanes[0].finished {
            return;
        }
        eng.decode_step(&mut lanes, 0).unwrap();
    }
    let text_before = eng.output_text(1).unwrap();
    let gen_before = lanes[0].generated;
    // preempt (recompute mode), then resume via prefill
    eng.preempt_release(1);
    assert_eq!(eng.output_text(1).unwrap(), text_before);
    let _ = eng.prefill(&r).unwrap();
    assert_eq!(
        eng.output_text(1).unwrap(),
        text_before,
        "replay must preserve the sampled prefix"
    );
    let mut lanes2 = vec![LaneState::new(&r, gen_before)];
    if !lanes2[0].finished {
        eng.decode_step(&mut lanes2, 0).unwrap();
        assert_eq!(lanes2[0].generated, gen_before + 1);
    }
}

#[test]
fn coordinator_serves_real_engine_end_to_end() {
    let Some(rt) = runtime() else { return };
    let cfg = ExperimentConfig::default();
    let engine = RealEngine::new(rt, 12);
    let policy = sagesched::sched::make_policy(&cfg);
    let predictor = sagesched::predictor::make_predictor(
        cfg.predictor,
        engine.runtime().meta().d_model,
        cfg.history_capacity,
        cfg.similarity_threshold,
        cfg.seed,
    );
    let cost = sagesched::cost::make_cost_model(cfg.cost_model);
    let mut coord =
        Coordinator::new(engine, policy, predictor, cost, PreemptMode::Recompute);
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = req(i, "serve me a completion please");
            r.arrival = i as f64 * 0.01;
            r
        })
        .collect();
    coord.run_workload(reqs).unwrap();
    assert_eq!(coord.outcomes().len(), 6);
    for o in coord.outcomes() {
        assert!(o.output_len >= 1);
        assert!(o.ttlt() >= 0.0);
    }
}
