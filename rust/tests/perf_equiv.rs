//! Differential equivalence suite for the incremental router indexes: the
//! fast path (`use_indexes = true`, the default) must produce the *exact*
//! dispatch sequence and the byte-identical `ClusterReport` of the retained
//! full-rescan oracle (`use_indexes = false` — the pre-index algorithms,
//! kept verbatim), for every router, across a matrix of scenarios that
//! together exercise every index mutation path: failures, autoscaling,
//! work stealing, disaggregated pools, sessions, drift, and all of them at
//! once. In debug builds every indexed dispatch additionally cross-checks
//! itself against an inline rescan (`debug_assert`s inside
//! `ClusterCtx::index_route`), so these tests double as property tests of
//! the heap invariants; the release-mode CI job reruns them with the
//! asserts compiled out, which is what certifies the fast path itself.

use sagesched::cluster::EventCluster;
use sagesched::config::{
    ArrivalKind, AutoscaleKind, ExperimentConfig, FailureDomain, FailureEvent,
    PolicyKind, PoolRole, RouterKind,
};
use sagesched::metrics::ClusterReport;
use sagesched::util::rng::Rng;
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

/// Same zeroing convention as the golden test in `tests/slo.rs`: the
/// wallclock overhead fields are the only nondeterministic numbers.
fn deterministic_json(mut r: ClusterReport) -> String {
    r.aggregate.predict_overhead = 0.0;
    r.aggregate.sched_overhead = 0.0;
    for pr in &mut r.per_replica {
        pr.predict_overhead = 0.0;
        pr.sched_overhead = 0.0;
    }
    r.to_json().to_string()
}

/// One full run returning the dispatch trace (request id, replica) in
/// dispatch order plus the deterministic report JSON.
fn run_once(
    cfg: &ExperimentConfig,
    router: RouterKind,
    use_indexes: bool,
) -> (Vec<(u64, usize)>, String) {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, router);
    cluster.use_indexes = use_indexes;
    cluster.trace_dispatch = true;
    cluster.prewarm();
    cluster.run(workload.requests).unwrap();
    let trace = std::mem::take(&mut cluster.dispatch_trace);
    let report = deterministic_json(cluster.report(cfg.warmup_fraction));
    (trace, report)
}

/// Assert indexed == oracle on both the dispatch sequence and the report,
/// for every router, under one scenario config.
fn assert_equivalent(name: &str, cfg: &ExperimentConfig) {
    for router in RouterKind::ALL {
        let (fast_trace, fast_report) = run_once(cfg, router, true);
        let (slow_trace, slow_report) = run_once(cfg, router, false);
        assert!(
            !fast_trace.is_empty(),
            "{name}/{router:?}: empty dispatch trace — scenario dispatched \
             nothing, the comparison is vacuous"
        );
        if let Some(k) =
            (0..fast_trace.len().min(slow_trace.len()))
                .find(|&k| fast_trace[k] != slow_trace[k])
        {
            panic!(
                "{name}/{router:?}: dispatch {k} diverged — indexed {:?} vs \
                 oracle {:?}",
                fast_trace[k], slow_trace[k]
            );
        }
        assert_eq!(
            fast_trace.len(),
            slow_trace.len(),
            "{name}/{router:?}: dispatch counts diverged"
        );
        assert_eq!(
            fast_report, slow_report,
            "{name}/{router:?}: reports diverged despite identical dispatches"
        );
    }
}

fn baseline() -> ExperimentConfig {
    cluster_cfg(5, 220, 30.0)
}

#[test]
fn baseline_matches_oracle() {
    assert_equivalent("baseline", &baseline());
}

#[test]
fn failures_match_oracle() {
    // crashes exercise sync-on-fail, the pooled redispatch storm (fresh
    // `keep_on == None` placements through the fast path), and recovery
    let mut cfg = baseline();
    cfg.cluster.failures = vec![
        FailureEvent { replica: 1, at: 2.0, duration: 1.5 },
        FailureEvent { replica: 3, at: 4.0, duration: 2.0 },
    ];
    assert_equivalent("failures", &cfg);
}

#[test]
fn domain_outage_matches_oracle() {
    // a whole domain leaves and rejoins the index scope in one event
    let mut cfg = baseline();
    cfg.cluster.failure_domains = vec![FailureDomain {
        name: "rack0".to_string(),
        replicas: vec![0, 1],
    }];
    cfg.cluster.domain_failures =
        vec![sagesched::config::DomainFailureEvent {
            domain: 0,
            at: 2.0,
            duration: 1.5,
        }];
    assert_equivalent("domain-outage", &cfg);
}

#[test]
fn autoscale_matches_oracle() {
    // spawn/drain/retire churn the roster and the heaps; drains route
    // with `keep_on` (rescan path) while fresh traffic stays indexed
    let mut cfg = baseline();
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 8;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 0.5;
    assert_equivalent("autoscale", &cfg);
}

#[test]
fn stealing_matches_oracle() {
    // bursty arrivals onto a heterogeneous fleet with free steals: the
    // idle-thief count gate must agree with the oracle's quiescent rescan
    let mut cfg = baseline();
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.arrival.burst_factor = 5.0;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5, 0.5, 0.5];
    cfg.cluster.steal_transfer_per_token = 0.0;
    assert_equivalent("stealing", &cfg);
}

#[test]
fn disagg_matches_oracle() {
    // the index scope narrows to the prefill pool; fabric handoffs into
    // decode stay on the rescan path, gated by `fabric_dirty`
    let mut cfg = cluster_cfg(6, 220, 30.0);
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    assert_equivalent("disagg", &cfg);
}

#[test]
fn sessions_match_oracle() {
    // multi-turn traffic; CacheAffinity declares Rescan and must still
    // agree with itself under the toggle (sanity that the toggle is inert
    // for rescan-only routers)
    let mut cfg = baseline();
    cfg.workload.sessions.enabled = true;
    cfg.workload.sessions.prefix_share = 0.7;
    assert_equivalent("sessions", &cfg);
}

#[test]
fn drift_matches_oracle() {
    // mid-run mix shift changes predicted costs, stressing score updates
    let mut cfg = baseline();
    cfg.workload.drift.at_fraction = 0.5;
    assert_equivalent("drift", &cfg);
}

#[test]
fn kitchen_sink_matches_oracle() {
    // everything at once: the scenario most likely to interleave index
    // mutations in an order no single-feature test reaches
    let mut cfg = cluster_cfg(6, 260, 36.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.sessions.enabled = true;
    cfg.workload.drift.at_fraction = 0.5;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5];
    cfg.cluster.failures =
        vec![FailureEvent { replica: 2, at: 2.0, duration: 1.5 }];
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 3;
    cfg.cluster.autoscale.max_replicas = 9;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 0.5;
    assert_equivalent("kitchen-sink", &cfg);
}

#[test]
fn class_aware_wrapper_matches_oracle() {
    // the seventh router: the class-aware wrapper forwards Batch traffic
    // to the inner fast path and forces Interactive onto the rescan
    let mut cfg = baseline();
    cfg.slo.class_aware = true;
    assert_equivalent("class-aware", &cfg);
}

#[test]
fn random_scenarios_match_oracle() {
    // proptest-style: seeded random small scenarios interleave ctx deltas
    // (failures, scaling, stealing, sessions) in orders the hand-written
    // matrix does not; each must still match the oracle exactly
    let mut rng = Rng::new(0xEC_5EED);
    for case in 0..6u64 {
        let mut cfg = cluster_cfg(
            2 + rng.below(4) as usize,
            (120 + rng.below(80) as usize) & !1,
            18.0 + rng.below(18) as f64,
        );
        cfg.seed = 100 + case;
        if rng.below(2) == 1 {
            cfg.workload.arrival.kind = ArrivalKind::Mmpp;
        }
        if rng.below(2) == 1 {
            cfg.workload.sessions.enabled = true;
        }
        if rng.below(2) == 1 {
            let r = rng.below(cfg.cluster.replicas as u64) as usize;
            cfg.cluster.failures = vec![FailureEvent {
                replica: r,
                at: 1.0 + rng.f64() * 2.0,
                duration: 0.5 + rng.f64(),
            }];
        }
        if rng.below(2) == 1 {
            cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
            cfg.cluster.autoscale.min_replicas = 2;
            cfg.cluster.autoscale.max_replicas = cfg.cluster.replicas + 3;
            cfg.cluster.autoscale.work_per_replica = 5.0e5;
            cfg.cluster.autoscale.cooldown = 2.0;
            cfg.cluster.autoscale.interval = 1.0;
            cfg.cluster.autoscale.provision_delay = 0.5;
        }
        // two routers per case keeps the runtime bounded; rotate so all
        // six appear across the six cases
        let i = (case as usize) % RouterKind::ALL.len();
        let j = (i + 3) % RouterKind::ALL.len();
        for router in [RouterKind::ALL[i], RouterKind::ALL[j]] {
            let (ft, fr) = run_once(&cfg, router, true);
            let (st, sr) = run_once(&cfg, router, false);
            assert_eq!(ft, st, "case {case}/{router:?}: traces diverged");
            assert_eq!(fr, sr, "case {case}/{router:?}: reports diverged");
        }
    }
}
