//! Differential equivalence suite for the incremental router indexes: the
//! fast path (`use_indexes = true`, the default) must produce the *exact*
//! dispatch sequence and the byte-identical `ClusterReport` of the retained
//! full-rescan oracle (`use_indexes = false` — the pre-index algorithms,
//! kept verbatim), for every router, across a matrix of scenarios that
//! together exercise every index mutation path: failures, autoscaling,
//! work stealing, disaggregated pools, sessions, drift, and all of them at
//! once. In debug builds every indexed dispatch additionally cross-checks
//! itself against an inline rescan (`debug_assert`s inside
//! `ClusterCtx::index_route`), so these tests double as property tests of
//! the heap invariants; the release-mode CI job reruns them with the
//! asserts compiled out, which is what certifies the fast path itself.

use sagesched::cluster::EventCluster;
use sagesched::config::{
    ArrivalKind, AutoscaleKind, ExperimentConfig, FailureDomain, FailureEvent,
    PolicyKind, PoolRole, RouterKind, ScaleStep,
};
use sagesched::metrics::{ClusterReport, FastPathStats};
use sagesched::util::rng::Rng;
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

/// Same zeroing convention as the golden test in `tests/slo.rs`: the
/// wallclock overhead fields are the only nondeterministic numbers. The
/// fast-path accounting block is stripped too — it is the one section
/// *designed* to differ between the indexed run and the all-rescan oracle;
/// everything else must stay byte-identical.
fn deterministic_json(mut r: ClusterReport) -> String {
    r.aggregate.predict_overhead = 0.0;
    r.aggregate.sched_overhead = 0.0;
    for pr in &mut r.per_replica {
        pr.predict_overhead = 0.0;
        pr.sched_overhead = 0.0;
    }
    r.fastpath = FastPathStats::default();
    r.to_json().to_string()
}

/// One full run returning the dispatch trace (request id, replica) in
/// dispatch order plus the deterministic report JSON.
fn run_once(
    cfg: &ExperimentConfig,
    router: RouterKind,
    use_indexes: bool,
) -> (Vec<(u64, usize)>, String) {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, router);
    cluster.use_indexes = use_indexes;
    cluster.trace_dispatch = true;
    cluster.prewarm();
    cluster.run(workload.requests).unwrap();
    let trace = std::mem::take(&mut cluster.dispatch_trace);
    let report = deterministic_json(cluster.report(cfg.warmup_fraction));
    (trace, report)
}

/// Assert indexed == oracle on both the dispatch sequence and the report,
/// for every router, under one scenario config.
fn assert_equivalent(name: &str, cfg: &ExperimentConfig) {
    for router in RouterKind::ALL {
        let (fast_trace, fast_report) = run_once(cfg, router, true);
        let (slow_trace, slow_report) = run_once(cfg, router, false);
        assert!(
            !fast_trace.is_empty(),
            "{name}/{router:?}: empty dispatch trace — scenario dispatched \
             nothing, the comparison is vacuous"
        );
        if let Some(k) =
            (0..fast_trace.len().min(slow_trace.len()))
                .find(|&k| fast_trace[k] != slow_trace[k])
        {
            panic!(
                "{name}/{router:?}: dispatch {k} diverged — indexed {:?} vs \
                 oracle {:?}",
                fast_trace[k], slow_trace[k]
            );
        }
        assert_eq!(
            fast_trace.len(),
            slow_trace.len(),
            "{name}/{router:?}: dispatch counts diverged"
        );
        assert_eq!(
            fast_report, slow_report,
            "{name}/{router:?}: reports diverged despite identical dispatches"
        );
    }
}

fn baseline() -> ExperimentConfig {
    cluster_cfg(5, 220, 30.0)
}

#[test]
fn baseline_matches_oracle() {
    assert_equivalent("baseline", &baseline());
}

#[test]
fn failures_match_oracle() {
    // crashes exercise sync-on-fail, the pooled redispatch storm (fresh
    // `keep_on == None` placements through the fast path), and recovery
    let mut cfg = baseline();
    cfg.cluster.failures = vec![
        FailureEvent { replica: 1, at: 2.0, duration: 1.5 },
        FailureEvent { replica: 3, at: 4.0, duration: 2.0 },
    ];
    assert_equivalent("failures", &cfg);
}

#[test]
fn domain_outage_matches_oracle() {
    // a whole domain leaves and rejoins the index scope in one event
    let mut cfg = baseline();
    cfg.cluster.failure_domains = vec![FailureDomain {
        name: "rack0".to_string(),
        replicas: vec![0, 1],
    }];
    cfg.cluster.domain_failures =
        vec![sagesched::config::DomainFailureEvent {
            domain: 0,
            at: 2.0,
            duration: 1.5,
        }];
    assert_equivalent("domain-outage", &cfg);
}

#[test]
fn autoscale_matches_oracle() {
    // spawn/drain/retire churn the roster and the heaps; drains route
    // with `keep_on` (rescan path) while fresh traffic stays indexed
    let mut cfg = baseline();
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 8;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 0.5;
    assert_equivalent("autoscale", &cfg);
}

#[test]
fn stealing_matches_oracle() {
    // bursty arrivals onto a heterogeneous fleet with free steals: the
    // idle-thief count gate must agree with the oracle's quiescent rescan
    let mut cfg = baseline();
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.arrival.burst_factor = 5.0;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5, 0.5, 0.5];
    cfg.cluster.steal_transfer_per_token = 0.0;
    assert_equivalent("stealing", &cfg);
}

#[test]
fn disagg_matches_oracle() {
    // the intake scope narrows to the prefill pool; fabric handoffs into
    // decode dispatch from the decode-scope index twin
    let mut cfg = cluster_cfg(6, 220, 30.0);
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    assert_equivalent("disagg", &cfg);
}

#[test]
fn sessions_match_oracle() {
    // multi-turn traffic; CacheAffinity dispatches through the shortlist +
    // dominance-bound fast path and must agree with the oracle exactly,
    // fallbacks included
    let mut cfg = baseline();
    cfg.workload.sessions.enabled = true;
    cfg.workload.sessions.prefix_share = 0.7;
    assert_equivalent("sessions", &cfg);
}

#[test]
fn affinity_shortlist_matches_oracle() {
    // session-heavy traffic with a deliberately tiny shortlist: warm sites
    // pile up on few replicas, so the dominance bound is exercised right at
    // its failure edge — both the accept and the counted-fallback branches
    // must reproduce the oracle's argmin exactly
    let mut cfg = cluster_cfg(6, 260, 34.0);
    cfg.workload.sessions.enabled = true;
    cfg.workload.sessions.prefix_share = 0.8;
    cfg.cluster.shortlist_k = 1;
    assert_equivalent("affinity-shortlist-k1", &cfg);
    cfg.cluster.shortlist_k = 3;
    assert_equivalent("affinity-shortlist-k3", &cfg);
}

#[test]
fn class_aware_interactive_disagg_matches_oracle() {
    // class-aware Interactive under disaggregation: the tight-quantile /
    // headroom index pair answers Interactive dispatch on the prefill
    // intake scope and on decode-side delivery, including the
    // eligible-empty <=> headroom-count-zero fallback
    let mut cfg = cluster_cfg(6, 240, 32.0);
    cfg.slo.class_aware = true;
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    assert_equivalent("class-aware-disagg", &cfg);
}

#[test]
fn congested_decode_delivery_matches_oracle() {
    // a starved fabric (one slow link) queues handoffs and delivers them in
    // bursts onto a small decode pool — the decode-scope fast path sees
    // back-to-back deliveries with KV filling up, so the fit-filter
    // vacuousness gate flips mid-run
    let mut cfg = cluster_cfg(6, 240, 34.0);
    cfg.cluster.pools = vec![
        PoolRole::Prefill,
        PoolRole::Prefill,
        PoolRole::Prefill,
        PoolRole::Decode,
    ];
    cfg.cluster.transfer_links = 1;
    cfg.cluster.transfer_bandwidth = 4_000.0;
    cfg.workload.sessions.enabled = true;
    assert_equivalent("congested-decode", &cfg);
}

#[test]
fn migration_heavy_scale_in_matches_oracle() {
    // scripted scale-in with cheap KV migration: drains re-admit queued
    // work (Drain scope) and ship partials (Migration scope) through the
    // per-pool indexed path, against the oracle's per-move rescan
    let mut cfg = cluster_cfg(6, 260, 34.0);
    cfg.cluster.migration_kv_per_token = 0.001;
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![
        ScaleStep { at: 2.0, target: 3 },
        ScaleStep { at: 5.0, target: 6 },
        ScaleStep { at: 7.0, target: 2 },
    ];
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 8;
    cfg.cluster.autoscale.provision_delay = 0.5;
    cfg.cluster.autoscale.cooldown = 0.5;
    cfg.cluster.autoscale.interval = 0.5;
    cfg.workload.sessions.enabled = true;
    assert_equivalent("migration-heavy", &cfg);
}

#[test]
fn drift_matches_oracle() {
    // mid-run mix shift changes predicted costs, stressing score updates
    let mut cfg = baseline();
    cfg.workload.drift.at_fraction = 0.5;
    assert_equivalent("drift", &cfg);
}

#[test]
fn kitchen_sink_matches_oracle() {
    // everything at once: the scenario most likely to interleave index
    // mutations in an order no single-feature test reaches
    let mut cfg = cluster_cfg(6, 260, 36.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.sessions.enabled = true;
    cfg.workload.drift.at_fraction = 0.5;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5];
    cfg.cluster.failures =
        vec![FailureEvent { replica: 2, at: 2.0, duration: 1.5 }];
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 3;
    cfg.cluster.autoscale.max_replicas = 9;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 0.5;
    assert_equivalent("kitchen-sink", &cfg);
}

#[test]
fn class_aware_wrapper_matches_oracle() {
    // the seventh router: the class-aware wrapper forwards Batch traffic
    // to the inner fast path and answers Interactive from the
    // tight-quantile/headroom index pair
    let mut cfg = baseline();
    cfg.slo.class_aware = true;
    assert_equivalent("class-aware", &cfg);
}

#[test]
fn random_scenarios_match_oracle() {
    // proptest-style: seeded random small scenarios interleave ctx deltas
    // (failures, scaling, stealing, sessions) in orders the hand-written
    // matrix does not; each must still match the oracle exactly
    let mut rng = Rng::new(0xEC_5EED);
    for case in 0..6u64 {
        let mut cfg = cluster_cfg(
            2 + rng.below(4) as usize,
            (120 + rng.below(80) as usize) & !1,
            18.0 + rng.below(18) as f64,
        );
        cfg.seed = 100 + case;
        if rng.below(2) == 1 {
            cfg.workload.arrival.kind = ArrivalKind::Mmpp;
        }
        if rng.below(2) == 1 {
            cfg.workload.sessions.enabled = true;
        }
        if rng.below(2) == 1 {
            let r = rng.below(cfg.cluster.replicas as u64) as usize;
            cfg.cluster.failures = vec![FailureEvent {
                replica: r,
                at: 1.0 + rng.f64() * 2.0,
                duration: 0.5 + rng.f64(),
            }];
        }
        if rng.below(2) == 1 {
            cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
            cfg.cluster.autoscale.min_replicas = 2;
            cfg.cluster.autoscale.max_replicas = cfg.cluster.replicas + 3;
            cfg.cluster.autoscale.work_per_replica = 5.0e5;
            cfg.cluster.autoscale.cooldown = 2.0;
            cfg.cluster.autoscale.interval = 1.0;
            cfg.cluster.autoscale.provision_delay = 0.5;
        }
        // two routers per case keeps the runtime bounded; rotate so all
        // six appear across the six cases
        let i = (case as usize) % RouterKind::ALL.len();
        let j = (i + 3) % RouterKind::ALL.len();
        for router in [RouterKind::ALL[i], RouterKind::ALL[j]] {
            let (ft, fr) = run_once(&cfg, router, true);
            let (st, sr) = run_once(&cfg, router, false);
            assert_eq!(ft, st, "case {case}/{router:?}: traces diverged");
            assert_eq!(fr, sr, "case {case}/{router:?}: reports diverged");
        }
    }
}
