//! Correlated failure domains + migration-cost-aware scale-in integration
//! tests: conservation of a domain outage across every router, the
//! one-event semantics of a domain failure (all members transition at the
//! same instant), provisioning members caught in a domain outage, the
//! directional claim that correlated outages hurt the Interactive tier
//! more than independent failures at equal total downtime, and the
//! acceptance assert that migration-cost-aware scale-in does not lose to
//! drain-only on goodput per replica-second.

use std::collections::BTreeSet;

use sagesched::autoscale::ScaleAction;
use sagesched::cluster::{run_router_experiment, EventCluster, ReplicaState};
use sagesched::config::{
    AutoscaleKind, DomainFailureEvent, ExperimentConfig, FailureDomain, PolicyKind,
    RouterKind, ScaleStep,
};
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

fn domain(name: &str, replicas: &[usize]) -> FailureDomain {
    FailureDomain { name: name.to_string(), replicas: replicas.to_vec() }
}

#[test]
fn domain_outage_conserves_requests_across_all_routers() {
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.cluster.failure_domains = vec![domain("rack0", &[1, 2])];
    cfg.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: 2.0, duration: 2.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        assert_eq!(cluster.rejected(), 0, "{router:?} rejected under domain outage");
        assert_eq!(cluster.aborted(), 0, "{router:?} aborted under domain outage");
        let outcomes = cluster.merged_outcomes();
        assert_eq!(outcomes.len(), 160, "{router:?} lost or duplicated work");
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(completed, submitted, "{router:?} completion set mismatch");
        assert_eq!(cluster.in_flight_count(), 0, "{router:?} leaked in-flight");
        assert!(
            cluster.total_backlog() < 1e-6,
            "{router:?} backlog leak: {}",
            cluster.total_backlog()
        );
        assert_eq!(cluster.domain_outages, 1, "{router:?} domain outage count");
    }
}

#[test]
fn domain_outage_downs_all_members_at_one_instant() {
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.cluster.failure_domains = vec![domain("rack0", &[1, 2])];
    cfg.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: 2.0, duration: 1.5 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    for member in [1usize, 2] {
        let fails: Vec<f64> = cluster
            .scaling_events
            .iter()
            .filter(|e| e.replica == member && e.action == ScaleAction::Fail)
            .map(|e| e.at)
            .collect();
        assert_eq!(fails, vec![2.0], "member {member} fail instants");
        let recovers: Vec<f64> = cluster
            .scaling_events
            .iter()
            .filter(|e| e.replica == member && e.action == ScaleAction::Recover)
            .map(|e| e.at)
            .collect();
        assert_eq!(recovers, vec![3.5], "member {member} recover instants");
    }
    let report = cluster.report(0.0);
    assert!((report.downtime[1] - 1.5).abs() < 1e-9, "member 1 downtime");
    assert!((report.downtime[2] - 1.5).abs() < 1e-9, "member 2 downtime");
    assert_eq!(report.downtime[0], 0.0);
    assert_eq!(report.domain_outages, 1);
    // the storm re-dispatched the members' live work at the outage instant
    assert!(cluster.re_routed > 0, "no re-dispatch storm observed");
}

#[test]
fn domain_outage_hits_provisioning_members_without_advancing_capacity() {
    // replica 4 is spawned at t=1 with a 2 s provisioning delay (ready at
    // t=3). A domain outage covering it during provisioning must delay
    // nothing if it ends before the delay would (recovery resumes
    // provisioning; the pending spawn-ready still fires at t=3) — an
    // outage can only delay capacity, never advance it.
    let mut cfg = cluster_cfg(4, 200, 25.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 1.0, target: 5 }];
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 2.0;
    cfg.cluster.failure_domains = vec![domain("rack-new", &[4])];
    cfg.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: 1.5, duration: 0.5 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let actions: Vec<(ScaleAction, f64)> = cluster
        .scaling_events
        .iter()
        .filter(|e| e.replica == 4)
        .map(|e| (e.action, e.at))
        .collect();
    assert_eq!(
        actions,
        vec![
            (ScaleAction::Provision, 1.0),
            (ScaleAction::Fail, 1.5),
            (ScaleAction::Recover, 2.0),
            (ScaleAction::Up, 3.0),
        ],
        "provisioning member lifecycle through a domain outage"
    );
    assert_eq!(cluster.replicas[4].state, ReplicaState::Active);
    assert_eq!(cluster.completed(), 200);
}

#[test]
fn correlated_outage_degrades_interactive_more_than_independent_failures() {
    // equal total downtime (3 replica-seconds) delivered two ways: three
    // replicas down for 1 s each in disjoint windows (capacity never below
    // 3/4), vs one rack taking all three down together (capacity 1/4 for a
    // full second plus one pooled re-dispatch storm). The correlated shape
    // must not be *better* for the Interactive tier — that is the point of
    // modeling failure domains at all.
    let base = cluster_cfg(4, 240, 30.0);

    let mut independent = base.clone();
    independent.cluster.failures = vec![
        sagesched::config::FailureEvent { replica: 1, at: 2.0, duration: 1.0 },
        sagesched::config::FailureEvent { replica: 2, at: 3.5, duration: 1.0 },
        sagesched::config::FailureEvent { replica: 3, at: 5.0, duration: 1.0 },
    ];
    let ind = run_router_experiment(&independent, RouterKind::LeastLoaded).unwrap();

    let mut correlated = base.clone();
    correlated.cluster.failure_domains = vec![domain("rack0", &[1, 2, 3])];
    correlated.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: 3.5, duration: 1.0 }];
    let cor = run_router_experiment(&correlated, RouterKind::LeastLoaded).unwrap();

    for (label, r) in [("independent", &ind), ("correlated", &cor)] {
        let n = 240;
        let accounted = r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, n, "{label}: {accounted} accounted of {n}");
    }
    let att = |r: &sagesched::metrics::ClusterReport| {
        r.aggregate
            .slo
            .get("interactive")
            .map(|s| s.attainment())
            .unwrap_or(0.0)
    };
    assert!(
        att(&cor) <= att(&ind) + 1e-9,
        "correlated outage must not beat independent failures on interactive \
         attainment: correlated {:.4} vs independent {:.4}",
        att(&cor),
        att(&ind)
    );
}

#[test]
fn migration_aware_scale_in_does_not_lose_to_drain_only() {
    // a heterogeneous fleet scales 3 -> 2 mid-run. Drain-only keeps the
    // victim alive until its partially-generated requests finish (billed
    // replica-seconds all the while); migration-cost-aware scale-in ships
    // that work to the survivors when the KV transfer is predicted cheaper
    // than waiting, so the victim retires earlier at equal completions —
    // goodput per replica-second must not get worse.
    let mut base = cluster_cfg(3, 120, 30.0);
    base.cluster.speeds = vec![1.0, 1.0, 0.3];
    base.cluster.autoscale.kind = AutoscaleKind::Step;
    base.cluster.autoscale.steps = vec![ScaleStep { at: 2.0, target: 2 }];
    base.cluster.autoscale.interval = 1.0;

    let drain_only = run_router_experiment(&base, RouterKind::CostAware).unwrap();

    let mut migr_cfg = base.clone();
    migr_cfg.cluster.migration_kv_per_token = 0.05; // cheap interconnect
    migr_cfg.cluster.migration_quantile = 0.9;
    let migrating = run_router_experiment(&migr_cfg, RouterKind::CostAware).unwrap();

    for (label, r) in [("drain-only", &drain_only), ("migration", &migrating)] {
        let accounted = r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, 120, "{label}: {accounted} accounted of 120");
    }
    assert_eq!(drain_only.migrated, 0, "drain-only must not migrate partials");
    assert!(
        migrating.migrated > 0,
        "migration-aware scale-in never migrated a partially-generated request"
    );
    assert!(
        migrating.goodput_per_replica_second >= drain_only.goodput_per_replica_second,
        "migration-aware scale-in lost on goodput/replica-second: {} < {}",
        migrating.goodput_per_replica_second,
        drain_only.goodput_per_replica_second
    );
}

#[test]
fn migrated_requests_complete_exactly_once_with_prefix_preserved() {
    // conservation under migration: every request completes exactly once,
    // and the migrated ones did not restart TTFT accounting (first tokens
    // precede the scale-in instant for requests already running by then)
    let mut cfg = cluster_cfg(3, 120, 30.0);
    cfg.cluster.speeds = vec![1.0, 1.0, 0.3];
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 2.0, target: 2 }];
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.migration_kv_per_token = 0.05;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::CostAware);
    cluster.run(workload.requests).unwrap();
    let outcomes = cluster.merged_outcomes();
    let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
    assert_eq!(completed, submitted, "migration lost or duplicated work");
    assert_eq!(cluster.in_flight_count(), 0);
    assert!(cluster.total_backlog() < 1e-6);
    assert!(cluster.migrated > 0, "scenario produced no migrations");
    for o in &outcomes {
        assert!(
            o.first_token <= o.completion,
            "request {}: first token after completion",
            o.id
        );
        assert!(o.first_token >= o.arrival, "request {}: TTFT negative", o.id);
    }
}
