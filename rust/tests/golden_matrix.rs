//! Golden byte-identical `ClusterReport` matrix: the single golden scenario
//! in `tests/slo.rs` extended across the feature surface — colocated,
//! disaggregated pools, sessions, drift, and a domain outage.
//!
//! No literal report bytes are checked in: pinning the full JSON would
//! freeze float formatting (and this repo's offline CI regenerates nothing),
//! so "golden" here means two independent properties that together give the
//! same guarantee:
//!
//!   1. *run-twice*: the same config serialized twice must match byte for
//!      byte — any HashMap-iteration-order or uninitialized-state creep
//!      shows up as a diff;
//!   2. *oracle*: the indexed fast path must serialize byte-identically to
//!      the retained full-rescan oracle (`use_indexes = false`), which IS
//!      the pre-index report — so a pass certifies the before/after
//!      byte-equality the raw-speed campaign promised.

use sagesched::cluster::EventCluster;
use sagesched::config::{
    ArrivalKind, AutoscaleKind, DomainFailureEvent, ExperimentConfig,
    FailureDomain, FailureEvent, PolicyKind, PoolRole, RouterKind,
};
use sagesched::metrics::{ClusterReport, FastPathStats};
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0;
    cfg.cluster.replicas = replicas;
    cfg
}

/// `strip_fastpath` drops the per-scope fast-path accounting block — the
/// one report section designed to differ between the indexed run and the
/// all-rescan oracle. Same-mode comparisons keep it (its determinism is
/// part of the guarantee); cross-mode ones strip it.
fn deterministic_json(mut r: ClusterReport, strip_fastpath: bool) -> String {
    r.aggregate.predict_overhead = 0.0;
    r.aggregate.sched_overhead = 0.0;
    for pr in &mut r.per_replica {
        pr.predict_overhead = 0.0;
        pr.sched_overhead = 0.0;
    }
    if strip_fastpath {
        r.fastpath = FastPathStats::default();
    }
    r.to_json().to_string()
}

fn run_report(cfg: &ExperimentConfig, use_indexes: bool) -> ClusterReport {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, RouterKind::QuantileCost);
    cluster.use_indexes = use_indexes;
    cluster.prewarm();
    cluster.run(workload.requests).unwrap();
    cluster.report(cfg.warmup_fraction)
}

/// The two golden properties for one scenario.
fn assert_golden(name: &str, cfg: &ExperimentConfig) {
    let a = run_report(cfg, true);
    let b = run_report(cfg, true);
    assert_eq!(
        deterministic_json(a.clone(), false),
        deterministic_json(b, false),
        "{name}: indexed report differs between identical runs"
    );
    let oracle = run_report(cfg, false);
    assert_eq!(
        deterministic_json(a, true),
        deterministic_json(oracle, true),
        "{name}: indexed report differs from the full-rescan oracle"
    );
}

/// The `tests/slo.rs` golden scenario verbatim: class-aware serving,
/// heterogeneous fleet, MMPP bursts, uncertainty-aware autoscaling, an
/// outage, admission pressure.
fn golden_base() -> ExperimentConfig {
    let mut cfg = cluster_cfg(3, 160, 24.0);
    cfg.slo.class_aware = true;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5];
    cfg.max_queue = 24;
    cfg.request_timeout = 30.0;
    cfg.cluster.failures =
        vec![FailureEvent { replica: 0, at: 2.0, duration: 1.5 }];
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 6;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 1.0;
    cfg
}

#[test]
fn golden_colocated() {
    assert_golden("colocated", &golden_base());
}

#[test]
fn golden_disagg() {
    // autoscale stays off here: pool roles cycle over the initial fleet
    // and the scenario pins an even prefill/decode split
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.slo.class_aware = true;
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.max_queue = 24;
    cfg.request_timeout = 30.0;
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    cfg.cluster.failures =
        vec![FailureEvent { replica: 0, at: 2.0, duration: 1.5 }];
    assert_golden("disagg", &cfg);
}

#[test]
fn golden_sessions() {
    let mut cfg = golden_base();
    cfg.workload.sessions.enabled = true;
    cfg.workload.sessions.prefix_share = 0.7;
    assert_golden("sessions", &cfg);
}

#[test]
fn golden_drift() {
    let mut cfg = golden_base();
    cfg.workload.drift.at_fraction = 0.5;
    assert_golden("drift", &cfg);
}

#[test]
fn golden_domain_outage() {
    let mut cfg = golden_base();
    cfg.cluster.failures.clear();
    cfg.cluster.failure_domains = vec![FailureDomain {
        name: "rack0".to_string(),
        replicas: vec![0, 1],
    }];
    cfg.cluster.domain_failures =
        vec![DomainFailureEvent { domain: 0, at: 2.0, duration: 1.5 }];
    assert_golden("domain-outage", &cfg);
}
