//! Behavioral tests: the qualitative claims each scheduler/feature makes
//! must hold on controlled workloads (these are the invariants the paper's
//! narrative depends on, separated from exact figures).

use sagesched::config::{
    DatasetKind, ExperimentConfig, PolicyKind, PredictorKind, WorkloadConfig,
};
use sagesched::core::Phase;
use sagesched::cost::{CostModel, ResourceBoundCost};
use sagesched::distribution::LengthDist;
use sagesched::sched::{Policy, ReqView, SageSchedPolicy};
use sagesched::serve::run_experiment;

fn cfg(policy: PolicyKind, rps: f64, n: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.policy = policy;
    c.predictor = PredictorKind::Oracle;
    c.workload.rps = rps;
    c.workload.n_requests = n;
    c.warmup_fraction = 0.0;
    c
}

/// mean TTLT averaged over 2 seeds
fn ttlt(mut c: ExperimentConfig) -> f64 {
    let mut acc = 0.0;
    for seed in [0, 1] {
        c.seed = seed;
        acc += run_experiment(&c).unwrap().ttlt.mean;
    }
    acc / 2.0
}

#[test]
fn fastserve_improves_ttft_over_fcfs() {
    // FastServe's MLFQ always admits fresh arrivals at top priority — its
    // defining TTFT advantage (paper fig7 discussion)
    let mut fcfs_ttft = 0.0;
    let mut fs_ttft = 0.0;
    for seed in [0, 1] {
        let mut c = cfg(PolicyKind::Fcfs, 10.0, 600);
        c.seed = seed;
        fcfs_ttft += run_experiment(&c).unwrap().ttft.mean;
        let mut c = cfg(PolicyKind::FastServe, 10.0, 600);
        c.seed = seed;
        fs_ttft += run_experiment(&c).unwrap().ttft.mean;
    }
    assert!(
        fs_ttft < fcfs_ttft,
        "fastserve TTFT {fs_ttft} !< fcfs {fcfs_ttft}"
    );
}

#[test]
fn predictive_policies_beat_fcfs_under_contention() {
    let fcfs = ttlt(cfg(PolicyKind::Fcfs, 10.0, 800));
    for policy in [PolicyKind::Ssjf, PolicyKind::Trail, PolicyKind::SageSched] {
        let t = ttlt(cfg(policy, 10.0, 800));
        assert!(t < fcfs, "{policy:?} {t} !< fcfs {fcfs}");
    }
}

#[test]
fn load_monotonicity() {
    // higher arrival rate must not reduce mean TTLT (10% slack absorbs
    // seed-level noise in the 2-seed average without changing the claim)
    let lo = ttlt(cfg(PolicyKind::SageSched, 4.0, 500));
    let mid = ttlt(cfg(PolicyKind::SageSched, 8.0, 500));
    let hi = ttlt(cfg(PolicyKind::SageSched, 12.0, 500));
    assert!(lo <= mid * 1.10, "lo {lo} vs mid {mid}");
    assert!(mid <= hi * 1.10, "mid {mid} vs hi {hi}");
}

#[test]
fn no_contention_means_policies_agree() {
    // at very light load every policy serves immediately: TTLT within 5%
    // (queueing is rare but not impossible at rps=1, so not exactly equal)
    let mut vals = Vec::new();
    for policy in [PolicyKind::Fcfs, PolicyKind::Ssjf, PolicyKind::SageSched] {
        vals.push(ttlt(cfg(policy, 1.0, 300)));
    }
    let max = vals.iter().cloned().fold(f64::MIN, f64::max);
    let min = vals.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        (max - min) / min < 0.05,
        "policies disagree at light load: {vals:?}"
    );
}

#[test]
fn alpaca_gains_most_from_hybrid_cost() {
    // the paper's fig8 story: long-input datasets are where output-length-
    // only scheduling mis-prices requests most. Compare SageSched's
    // resource-bound cost vs output-len cost on Alpaca: the hybrid model
    // must not be worse.
    let mut base = cfg(PolicyKind::SageSched, 10.0, 600);
    base.workload = WorkloadConfig::single(DatasetKind::Alpaca);
    base.workload.rps = 10.0;
    base.workload.n_requests = 600;
    let hybrid = ttlt(base.clone());
    let mut ol = base.clone();
    ol.cost_model = sagesched::config::CostModelKind::OutputLen;
    let output_only = ttlt(ol);
    assert!(
        hybrid <= output_only * 1.10,
        "hybrid {hybrid} should not lose to output-only {output_only} on alpaca"
    );
}

#[test]
fn finish_guard_reduces_wasted_preemptions() {
    // with the IO-aware finish guard, requests about to drain are not
    // swapped out; total preemptions should not increase
    let mut with_guard = cfg(PolicyKind::SageSched, 11.0, 600);
    with_guard.preempt_finish_guard = 24;
    let mut without = with_guard.clone();
    without.preempt_finish_guard = 0;
    without.preempt_hysteresis = 0.0;
    let mut p_with = 0;
    let mut p_without = 0;
    for seed in [0, 1] {
        let mut a = with_guard.clone();
        a.seed = seed;
        p_with += run_experiment(&a).unwrap().preemptions;
        let mut b = without.clone();
        b.seed = seed;
        p_without += run_experiment(&b).unwrap().preemptions;
    }
    assert!(
        p_with <= p_without,
        "guarded preemptions {p_with} !<= unguarded {p_without}"
    );
}

#[test]
fn sagesched_robust_to_noise_relative_to_mean_policy() {
    // fig11: noise degrades the Gittins-based policy less than Mean
    let mut sage_clean = cfg(PolicyKind::SageSched, 10.0, 700);
    sage_clean.predictor = PredictorKind::History;
    let mut sage_noisy = sage_clean.clone();
    sage_noisy.noise_mix = 0.2;
    let mut mean_clean = sage_clean.clone();
    mean_clean.policy = PolicyKind::MeanCost;
    let mut mean_noisy = mean_clean.clone();
    mean_noisy.noise_mix = 0.2;
    let sage_deg = ttlt(sage_noisy) / ttlt(sage_clean);
    let mean_deg = ttlt(mean_noisy) / ttlt(mean_clean);
    assert!(
        sage_deg < mean_deg * 1.1,
        "sagesched degradation {sage_deg} vs mean {mean_deg}"
    );
}

#[test]
fn gittins_refresh_beats_static_gittins() {
    // fig11's other half: runtime refresh must help (bimodal workload)
    let refresh = ttlt(cfg(PolicyKind::SageSched, 10.0, 800));
    let static_g = ttlt(cfg(PolicyKind::GittinsStatic, 10.0, 800));
    assert!(
        refresh < static_g,
        "refresh {refresh} !< static {static_g}"
    );
}

#[test]
fn oracle_srpt_bounds_predictive_policies() {
    // no prediction-based policy should beat full-information SRPT by a
    // meaningful margin (sanity on the information hierarchy)
    let oracle = ttlt(cfg(PolicyKind::OracleSrpt, 10.0, 800));
    for policy in [PolicyKind::Ssjf, PolicyKind::Trail, PolicyKind::SageSched] {
        let t = ttlt(cfg(policy, 10.0, 800));
        assert!(
            t > oracle * 0.85,
            "{policy:?} {t} implausibly beats oracle {oracle}"
        );
    }
}

#[test]
fn throughput_approaches_offered_load_when_stable() {
    let mut c = cfg(PolicyKind::SageSched, 4.0, 600);
    c.warmup_fraction = 0.1;
    let r = run_experiment(&c).unwrap();
    assert!(
        r.throughput > 3.0,
        "throughput {} too far below offered 4 rps",
        r.throughput
    );
}

#[test]
fn oracle_srpt_never_underperforms_fcfs_on_fixed_seeds() {
    // deterministic seeded regression: full-information preemptive SRPT
    // must not lose to FCFS on mean TTLT for these exact seeded workloads
    for seed in [0u64, 1, 2] {
        let mut fcfs = cfg(PolicyKind::Fcfs, 12.0, 400);
        fcfs.seed = seed;
        let mut srpt = cfg(PolicyKind::OracleSrpt, 12.0, 400);
        srpt.seed = seed;
        let f = run_experiment(&fcfs).unwrap().ttlt.mean;
        let s = run_experiment(&srpt).unwrap().ttlt.mean;
        assert!(
            s <= f * 1.001,
            "seed {seed}: oracle-srpt {s} underperforms fcfs {f}"
        );
    }
}

#[test]
fn sagesched_priorities_finite_and_refresh_across_buckets() {
    // the SageSched policy must (a) always emit finite priorities and
    // (b) recompute its Gittins index when a request crosses a bucket
    // boundary and its cheap branch dies off
    let mut policy = SageSchedPolicy::new(10);
    let cm = ResourceBoundCost;
    let req = sagesched::core::Request {
        id: 1,
        prompt: String::new(),
        input_len: 12,
        true_output_len: 500,
        arrival: 0.0,
        dataset: DatasetKind::ShareGpt,
        topic: 0,
        embedding: sagesched::embedding::Embedding::normalize(vec![1.0]),
        true_dist: None,
        slo: sagesched::slo::SloClass::Standard,
        prefix_key: Vec::new(),
    };
    let lengths = LengthDist::from_weighted(&[(20.0, 0.7), (500.0, 0.3)]);
    let cost_dist = cm.cost_dist(req.input_len, &lengths);
    let mut priorities = Vec::new();
    for generated in [0u32, 5, 15, 30, 60, 120, 240] {
        let view = ReqView {
            req: &req,
            phase: Phase::Running,
            generated,
            pred_lengths: &lengths,
            cost_dist: &cost_dist,
            point_pred: lengths.mean(),
            rank_pred: lengths.mean(),
            consumed_cost: cm.consumed(req.input_len, generated),
            now: generated as f64,
        };
        let p = policy.priority(&view);
        assert!(p.is_finite(), "priority at generated={generated} not finite");
        priorities.push(p);
    }
    // crossing past the short mode (20 tokens) must refresh the index:
    // the priority at 30+ generated tokens reflects the surviving long
    // branch and exceeds the admission-time index
    assert!(
        priorities[3] > priorities[0],
        "no refresh across buckets: {priorities:?}"
    );
    assert!(policy.refreshes >= 2, "expected multiple Gittins refreshes");
}

#[test]
fn h800_profile_is_slower_per_request_than_a40() {
    // bigger model ⇒ higher per-token latency at identical light load
    let mut a40 = cfg(PolicyKind::Fcfs, 1.0, 200);
    a40.engine = sagesched::config::EngineProfile::a40_llama8b();
    let mut h800 = a40.clone();
    h800.engine = sagesched::config::EngineProfile::h800_qwen32b();
    assert!(ttlt(h800) > ttlt(a40));
}
