//! Property-based tests (hand-rolled generator harness — proptest is not
//! available offline). Each property runs against a few hundred randomized
//! cases with seeds printed on failure.

use sagesched::cost::make_cost_model;
use sagesched::config::CostModelKind;
use sagesched::distribution::LengthDist;
use sagesched::embedding::{Embedding, FlatIndex};
use sagesched::gittins::{gittins_index, gittins_index_at_age};
use sagesched::kvcache::KvManager;
use sagesched::util::json::Json;
use sagesched::util::rng::Rng;
use sagesched::util::stats::{normal_cdf, normal_quantile, normal_quantile_clamped};

/// Run `f` over `cases` seeded inputs; panics include the failing seed.
fn for_all(cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9e3779b9) ^ 0xabcd);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(">>> property failed at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_dist(rng: &mut Rng) -> LengthDist {
    let n = 1 + rng.below(40) as usize;
    let samples: Vec<f64> = (0..n.max(2))
        .map(|_| {
            let mu = rng.range_f64(2.0, 7.0);
            let sigma = rng.range_f64(0.1, 1.2);
            rng.lognormal(mu, sigma).max(0.5)
        })
        .collect();
    LengthDist::from_samples(&samples)
}

// ---------------------------------------------------------------------------
// distribution invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_dist_probabilities_normalized_and_sorted() {
    for_all(300, |rng| {
        let d = random_dist(rng);
        let sum: f64 = d.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "probs sum {sum}");
        for w in d.support().windows(2) {
            assert!(w[1] > w[0], "support not strictly increasing");
        }
    });
}

#[test]
fn prop_cdf_monotone_and_quantile_consistent() {
    for_all(200, |rng| {
        let d = random_dist(rng);
        let mut prev = 0.0;
        for &v in d.support() {
            let c = d.cdf(v);
            assert!(c >= prev - 1e-12);
            prev = c;
        }
        assert!((d.cdf(d.max()) - 1.0).abs() < 1e-9);
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            let x = d.quantile(q);
            assert!(d.cdf(x) >= q - 1e-9, "cdf(quantile({q})) too small");
        }
    });
}

#[test]
fn prop_conditional_excess_preserves_mass_and_shifts() {
    for_all(300, |rng| {
        let d = random_dist(rng);
        let age = rng.range_f64(0.0, d.max() * 1.2);
        match d.conditional_excess(age) {
            Some(c) => {
                let sum: f64 = c.probs().iter().sum();
                assert!((sum - 1.0).abs() < 1e-9);
                assert!(c.min() > 0.0);
                // E[X - a | X > a] >= E[X] - a always
                assert!(c.mean() >= d.mean() - age - 1e-6);
            }
            None => assert!(age >= d.max() - 1e-12),
        }
    });
}

#[test]
fn prop_compress_preserves_mean_and_bounds() {
    for_all(200, |rng| {
        let d = random_dist(rng);
        let k = 1 + rng.below(16) as usize;
        let c = d.compress(k);
        assert!(c.len() <= k + 1);
        assert!((c.mean() - d.mean()).abs() <= d.mean() * 0.25 + 1e-9);
        assert!(c.min() >= d.min() - 1e-9);
        assert!(c.max() <= d.max() + 1e-9);
    });
}

#[test]
fn prop_mix_mean_is_convex_combination() {
    for_all(200, |rng| {
        let a = random_dist(rng);
        let b = random_dist(rng);
        let w = rng.f64();
        let m = a.mix(&b, w);
        let want = a.mean() * (1.0 - w) + b.mean() * w;
        assert!((m.mean() - want).abs() < 1e-6 * want.max(1.0));
    });
}

// ---------------------------------------------------------------------------
// gittins invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_gittins_bounded_by_min_and_mean() {
    for_all(400, |rng| {
        let d = random_dist(rng);
        let g = gittins_index(&d);
        assert!(g >= d.min() - 1e-9, "index below min support");
        assert!(g <= d.mean() + 1e-9, "index above mean");
        assert!(g.is_finite());
    });
}

#[test]
fn prop_gittins_scale_equivariant() {
    for_all(200, |rng| {
        let d = random_dist(rng);
        let k = rng.range_f64(0.1, 50.0);
        let scaled = d.map_monotonic(|x| x * k);
        let g1 = gittins_index(&d) * k;
        let g2 = gittins_index(&scaled);
        assert!((g1 - g2).abs() < 1e-6 * g1.max(1.0), "{g1} vs {g2}");
    });
}

#[test]
fn prop_gittins_two_point_exact_values() {
    // closed-form checks for the two-point distribution:
    //  - before the short mode: G = min((lo-a)/p, mean-a-ish bound)
    //  - after the short mode dies: remaining is a point mass at hi-a
    for_all(200, |rng| {
        let lo = rng.range_f64(1.0, 50.0);
        let hi = lo + rng.range_f64(10.0, 500.0);
        let p = rng.range_f64(0.05, 0.95);
        let d = LengthDist::from_weighted(&[(lo, p), (hi, 1.0 - p)]);
        // age within (lo, hi): conditional is a point mass at hi - a
        let a = lo + (hi - lo) * rng.range_f64(0.05, 0.9);
        let g = gittins_index_at_age(&d, a);
        assert!((g - (hi - a)).abs() < 1e-6 * hi, "point-mass tail: {g} vs {}", hi - a);
        // age within (0, lo): index is exactly min((lo-a)/p, E[X]-a)
        let a2 = lo * rng.f64() * 0.99;
        let g2 = gittins_index_at_age(&d, a2);
        let mean_rem = d.mean() - a2;
        let want = ((lo - a2) / p).min(mean_rem);
        assert!((g2 - want).abs() < 1e-6 * want.max(1.0), "{g2} vs {want}");
    });
}

#[test]
fn prop_gittins_point_mass_equals_value() {
    for_all(100, |rng| {
        let v = rng.range_f64(0.1, 1e6);
        let d = LengthDist::point(v);
        assert!((gittins_index(&d) - v).abs() < 1e-9 * v.max(1.0));
    });
}

// ---------------------------------------------------------------------------
// cost model invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_cost_models_monotone_and_consistent() {
    for_all(200, |rng| {
        for kind in [
            CostModelKind::ResourceBound,
            CostModelKind::OutputLen,
            CostModelKind::OverallLen,
        ] {
            let m = make_cost_model(kind);
            let i = rng.below(3000) as u32;
            let o1 = rng.range_f64(1.0, 2000.0);
            let o2 = o1 + rng.range_f64(0.5, 500.0);
            assert!(m.cost(i, o2) > m.cost(i, o1), "{kind:?} not monotone");
            let g = rng.below(500) as u32;
            assert!((m.consumed(i, g) - m.cost(i, g as f64)).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_cost_dist_transform_is_order_preserving() {
    for_all(150, |rng| {
        let d = random_dist(rng);
        let m = make_cost_model(CostModelKind::ResourceBound);
        let i = rng.below(2000) as u32;
        let cd = m.cost_dist(i, &d);
        assert_eq!(cd.len(), d.len());
        assert_eq!(cd.probs(), d.probs());
        for w in cd.support().windows(2) {
            assert!(w[1] > w[0]);
        }
    });
}

// ---------------------------------------------------------------------------
// kv manager: conservation + capacity under random op sequences
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_manager_never_exceeds_capacity() {
    for_all(150, |rng| {
        let blocks = 4 + rng.below(60) as usize;
        let bt = 1 + rng.below(32) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let mut live: Vec<u64> = Vec::new();
        let mut swapped: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..300 {
            match rng.below(5) {
                0 => {
                    let tokens = 1 + rng.below((blocks * bt) as u64 / 2) as usize;
                    if kv.can_allocate(tokens) {
                        kv.grow_to(next_id, tokens);
                        live.push(next_id);
                        next_id += 1;
                    }
                }
                1 => {
                    if let Some(&id) = live.first() {
                        let cur = kv.tokens_of(id);
                        if kv.can_grow_to(id, cur + 1) {
                            assert!(kv.grow_to(id, cur + 1));
                        }
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let idx = rng.below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        kv.release(id);
                    }
                }
                3 => {
                    if !live.is_empty() {
                        let idx = rng.below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        kv.swap_out(id);
                        swapped.push(id);
                    }
                }
                _ => {
                    if !swapped.is_empty() {
                        let idx = rng.below(swapped.len() as u64) as usize;
                        let id = swapped[idx];
                        if kv.swap_in(id).is_some() {
                            swapped.swap_remove(idx);
                            live.push(id);
                        }
                    }
                }
            }
            // invariants
            assert!(kv.used_blocks() <= kv.total_blocks());
            assert_eq!(kv.used_blocks() + kv.free_blocks(), kv.total_blocks());
            let frag = kv.fragmentation();
            assert!((0.0..=1.0).contains(&frag));
        }
        for id in live.drain(..).chain(swapped.drain(..)) {
            kv.release(id);
        }
        assert_eq!(kv.free_blocks(), kv.total_blocks(), "blocks leaked");
    });
}

#[test]
fn prop_kv_swap_roundtrip_preserves_token_counts() {
    // swap_out reports the tokens moved; swap_in must move exactly the same
    // number back, and the sequence's token count must survive the trip
    for_all(200, |rng| {
        let bt = 1 + rng.below(32) as usize;
        let blocks = 8 + rng.below(50) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let tokens = 1 + rng.below((blocks * bt) as u64) as usize;
        assert!(kv.grow_to(7, tokens));
        assert_eq!(kv.tokens_of(7), tokens);
        let used_before = kv.used_blocks();

        let moved_out = kv.swap_out(7);
        assert_eq!(moved_out, tokens, "swap_out token count");
        assert_eq!(kv.used_blocks(), 0, "swap-out must free all GPU blocks");
        assert_eq!(kv.tokens_of(7), tokens, "token count remembered");

        let moved_in = kv.swap_in(7).expect("blocks are free");
        assert_eq!(moved_in, tokens, "swap_in token count");
        assert_eq!(kv.used_blocks(), used_before, "block footprint restored");
        assert_eq!(kv.tokens_of(7), tokens);
        assert_eq!(kv.swap_out_events, 1);
        assert_eq!(kv.swap_in_events, 1);
    });
}

#[test]
fn prop_kv_release_and_drop_return_all_blocks() {
    // whatever mix of GPU-resident and swapped sequences exists,
    // release/drop_seq over all of them must return the pool to full
    for_all(200, |rng| {
        let bt = 1 + rng.below(16) as usize;
        let blocks = 16 + rng.below(64) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let mut ids: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..40 {
            let tokens = 1 + rng.below((blocks * bt / 4).max(1) as u64) as usize;
            if kv.can_allocate(tokens) {
                assert!(kv.grow_to(next, tokens));
                // a third of sequences get swapped out
                if rng.below(3) == 0 {
                    kv.swap_out(next);
                }
                ids.push(next);
                next += 1;
            }
        }
        for (i, id) in ids.drain(..).enumerate() {
            if i % 2 == 0 {
                kv.release(id);
            } else {
                kv.drop_seq(id);
            }
        }
        assert_eq!(kv.free_blocks(), kv.total_blocks(), "blocks leaked");
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.resident_tokens(), 0);
    });
}

#[test]
fn prop_kv_fragmentation_bounded_and_exact_when_aligned() {
    for_all(200, |rng| {
        let bt = 2 + rng.below(31) as usize;
        let mut kv = KvManager::new(64 * bt, bt);
        // block-aligned allocations have zero internal fragmentation
        assert!(kv.grow_to(1, bt));
        assert!(kv.grow_to(2, 3 * bt));
        assert_eq!(kv.fragmentation(), 0.0);
        // misaligned growth keeps fragmentation within (0, 1)
        let extra = 1 + rng.below((bt - 1) as u64) as usize;
        assert!(kv.grow_to(3, bt + extra));
        let frag = kv.fragmentation();
        assert!(frag > 0.0 && frag < 1.0, "fragmentation {frag} out of range");
        // swapped sequences leave the fragmentation accounting
        kv.swap_out(3);
        assert_eq!(kv.fragmentation(), 0.0);
        // empty pool reports zero, never NaN
        kv.release(1);
        kv.release(2);
        kv.release(3);
        assert_eq!(kv.fragmentation(), 0.0);
    });
}

#[test]
fn prop_kv_capacity_never_exceeded_under_growth_pressure() {
    // grow a shifting population one token at a time forever: used blocks
    // must never pass total, and failed growth must change nothing
    for_all(100, |rng| {
        let bt = 1 + rng.below(8) as usize;
        let blocks = 4 + rng.below(12) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let mut ids: Vec<u64> = (0..4).collect();
        for id in &ids {
            kv.grow_to(*id, 1);
        }
        for step in 0..500 {
            let id = ids[rng.below(ids.len() as u64) as usize];
            let want = kv.tokens_of(id) + 1 + rng.below(3) as usize;
            let before_used = kv.used_blocks();
            let before_tokens = kv.tokens_of(id);
            let fits = kv.can_grow_to(id, want);
            let ok = kv.grow_to(id, want);
            assert_eq!(ok, fits, "grow_to must agree with can_grow_to");
            if !ok {
                assert_eq!(kv.used_blocks(), before_used, "failed grow mutated state");
                assert_eq!(kv.tokens_of(id), before_tokens);
                // make room and retire the oldest sequence
                let victim = ids.remove(0);
                kv.release(victim);
                let fresh = 100 + step as u64;
                kv.grow_to(fresh, 1);
                ids.push(fresh);
            }
            assert!(kv.used_blocks() <= kv.total_blocks());
            assert_eq!(kv.used_blocks() + kv.free_blocks(), kv.total_blocks());
        }
    });
}

#[test]
fn prop_kv_shared_prefix_conservation_under_random_ops() {
    // random sessions over a small pool of colliding chains: every op
    // sequence preserves full block conservation (each block owned by
    // exactly one of free/LRU-warm/referenced, refcounts exact), the
    // read-only probe never mutates and always agrees with the allocation
    // it predicts, and releasing everything returns the whole pool (warm
    // retained blocks count as reclaimable free space)
    for_all(120, |rng| {
        let bt = 2 + rng.below(14) as usize;
        let blocks = 24 + rng.below(40) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let chains: Vec<Vec<u64>> = (0..4u64)
            .map(|c| (0..6u64).map(|i| c * 1000 + i + 1).collect())
            .collect();
        let mut live: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..250 {
            match rng.below(4) {
                0 => {
                    let chain = &chains[rng.below(4) as usize];
                    let keys = rng.below(7) as usize;
                    let tokens = 1 + rng.below((blocks * bt / 4) as u64) as usize;
                    // the probe is read-only and must predict the hit exactly
                    let predicted = kv.cached_prefix_tokens(&chain[..keys], tokens - 1);
                    if let Some(out) = kv.allocate_with_prefix(next, &chain[..keys], tokens)
                    {
                        assert_eq!(
                            out.cached_tokens, predicted,
                            "probe disagrees with allocation"
                        );
                        live.push(next);
                    }
                    next += 1;
                }
                1 => {
                    if let Some(&id) = live.last() {
                        let cur = kv.tokens_of(id);
                        if kv.can_grow_to(id, cur + 1) {
                            assert!(kv.grow_to(id, cur + 1));
                        }
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let idx = rng.below(live.len() as u64) as usize;
                        kv.release(live.swap_remove(idx));
                    }
                }
                _ => {
                    let chain = &chains[rng.below(4) as usize];
                    let used = kv.used_blocks();
                    let warm = kv.warm_blocks();
                    let _ = kv.cached_prefix_tokens(chain, blocks * bt);
                    assert_eq!(kv.used_blocks(), used, "probe mutated usage");
                    assert_eq!(kv.warm_blocks(), warm, "probe mutated the LRU");
                }
            }
            kv.assert_conserved();
            assert_eq!(kv.used_blocks() + kv.free_blocks(), kv.total_blocks());
        }
        for id in live.drain(..) {
            kv.release(id);
        }
        kv.assert_conserved();
        assert_eq!(kv.used_blocks(), 0, "live blocks leaked");
        assert_eq!(kv.free_blocks(), kv.total_blocks());
        assert_eq!(kv.resident_tokens(), 0);
    });
}

#[test]
fn prop_kv_shared_blocks_survive_release_while_readers_live() {
    // freeing-shared-with-live-readers must be impossible: releasing one
    // reader of a shared prefix never frees (or warms) blocks the other
    // reader still holds, and the last release retains the prefix warm
    // without counting it as used
    for_all(200, |rng| {
        let bt = 2 + rng.below(30) as usize;
        let mut kv = KvManager::new(32 * bt, bt);
        let prefix_blocks = 1 + rng.below(6) as usize;
        let chain: Vec<u64> = (0..prefix_blocks as u64).map(|i| 0xfeed + i).collect();
        // prompt covers the whole chain plus a private in-block tail
        let tail = 1 + rng.below(bt as u64) as usize;
        let tokens = prefix_blocks * bt + tail + 1;
        let need = tokens.div_ceil(bt);
        let o1 = kv.allocate_with_prefix(1, &chain, tokens).unwrap();
        assert_eq!(o1.cached_tokens, 0, "cold start cannot hit");
        let o2 = kv.allocate_with_prefix(2, &chain, tokens).unwrap();
        assert_eq!(o2.cached_blocks, prefix_blocks, "second reader shares the prefix");
        assert_eq!(kv.used_blocks(), need + (need - prefix_blocks));
        kv.release(1);
        // only reader 1's private tail went away; the shared blocks stay
        // referenced (not warm) and still probe hot for reader 2's session
        assert_eq!(kv.used_blocks(), need, "shared prefix freed under a live reader");
        assert_eq!(kv.warm_blocks(), 0);
        assert_eq!(kv.cached_prefix_tokens(&chain, tokens), prefix_blocks * bt);
        kv.assert_conserved();
        kv.release(2);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.free_blocks(), kv.total_blocks());
        assert_eq!(kv.warm_blocks(), prefix_blocks);
        kv.assert_conserved();
    });
}

#[test]
fn prop_kv_swap_with_shared_prefixes_conserves_occupancy() {
    // swap-out moves only private blocks to host while indexed blocks stay
    // resident; random interleavings of allocate/swap-out/swap-in/release
    // over a shared chain keep every conservation invariant, and draining
    // the population returns both GPU blocks and host occupancy to zero
    for_all(100, |rng| {
        let bt = 2 + rng.below(12) as usize;
        let blocks = 24 + rng.below(40) as usize;
        let mut kv = KvManager::new(blocks * bt, bt);
        let chain: Vec<u64> = (1..=8u64).collect();
        let mut gpu: Vec<u64> = Vec::new();
        let mut host: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..200 {
            match rng.below(4) {
                0 => {
                    let tokens = 1 + rng.below((blocks * bt / 6) as u64) as usize;
                    let keys = rng.below(9) as usize;
                    if kv.allocate_with_prefix(next, &chain[..keys], tokens).is_some() {
                        gpu.push(next);
                    }
                    next += 1;
                }
                1 => {
                    if !gpu.is_empty() {
                        let idx = rng.below(gpu.len() as u64) as usize;
                        let id = gpu.swap_remove(idx);
                        let moved = kv.swap_out(id);
                        assert!(moved <= kv.tokens_of(id));
                        host.push(id);
                    }
                }
                2 => {
                    if !host.is_empty() {
                        let idx = rng.below(host.len() as u64) as usize;
                        let id = host[idx];
                        if kv.swap_in(id).is_some() {
                            host.swap_remove(idx);
                            gpu.push(id);
                        } else if rng.below(2) == 0 {
                            // kept prefix evicted or pool full: the caller
                            // falls back to drop + recompute
                            kv.drop_seq(id);
                            host.swap_remove(idx);
                        }
                    }
                }
                _ => {
                    if !gpu.is_empty() {
                        let idx = rng.below(gpu.len() as u64) as usize;
                        kv.release(gpu.swap_remove(idx));
                    } else if !host.is_empty() {
                        let idx = rng.below(host.len() as u64) as usize;
                        kv.release(host.swap_remove(idx));
                    }
                }
            }
            kv.assert_conserved();
            assert_eq!(kv.used_blocks() + kv.free_blocks(), kv.total_blocks());
        }
        for id in gpu.drain(..).chain(host.drain(..)) {
            kv.release(id);
        }
        kv.assert_conserved();
        assert_eq!(kv.used_blocks(), 0, "live blocks leaked");
        assert_eq!(kv.swapped_tokens, 0, "host occupancy leaked");
        assert_eq!(kv.resident_tokens(), 0);
    });
}

// ---------------------------------------------------------------------------
// flat index vs brute force
// ---------------------------------------------------------------------------

#[test]
fn prop_flat_index_matches_bruteforce() {
    for_all(60, |rng| {
        let dim = 8 + rng.below(48) as usize;
        let n = 1 + rng.below(200) as usize;
        let mut index: FlatIndex<usize> = FlatIndex::new(dim, n + 10);
        let mut reference: Vec<Embedding> = Vec::new();
        for i in 0..n {
            let e = Embedding::random_unit(dim, rng);
            index.insert(e.clone(), i);
            reference.push(e);
        }
        let q = Embedding::random_unit(dim, rng);
        let th = rng.range_f64(-0.2, 0.9) as f32;
        let mut got: Vec<usize> =
            index.search_threshold(&q, th).iter().map(|(_, &p)| p).collect();
        got.sort_unstable();
        let mut want: Vec<usize> = reference
            .iter()
            .enumerate()
            .filter(|(_, e)| q.cosine(e) >= th)
            .map(|(i, _)| i)
            .collect();
        want.sort_unstable();
        assert_eq!(got, want);
    });
}

// ---------------------------------------------------------------------------
// json roundtrip on random values
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::num((rng.normal() * 1e3).round()),
        3 => {
            let len = rng.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::str(s)
        }
        4 => Json::arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1))),
        _ => Json::obj(
            (0..rng.below(5))
                .map(|i| {
                    let key = format!("k{i}");
                    (key, random_json(rng, depth - 1))
                })
                .collect::<Vec<_>>()
                .iter()
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    for_all(300, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("reparse {text}: {e}"));
        assert_eq!(parsed, v, "roundtrip mismatch for {text}");
    });
}

// ---------------------------------------------------------------------------
// normal_quantile — it now gates routing (quantile-cost router), autoscaling
// (uncertainty-aware provisioning), and SLO deadline slack, so its shape is
// pinned by properties, not just spot values
// ---------------------------------------------------------------------------

#[test]
fn prop_normal_quantile_strictly_monotone_in_p() {
    for_all(200, |rng| {
        let p1 = rng.range_f64(1e-6, 1.0 - 2e-6);
        let p2 = rng.range_f64(1e-6, 1.0 - 2e-6);
        let (lo, hi) = if p1 < p2 { (p1, p2) } else { (p2, p1) };
        if hi - lo < 1e-12 {
            return;
        }
        let (zlo, zhi) = (normal_quantile(lo), normal_quantile(hi));
        assert!(
            zlo < zhi,
            "not strictly monotone: Phi^-1({lo})={zlo} !< Phi^-1({hi})={zhi}"
        );
        assert!(zlo.is_finite() && zhi.is_finite());
    });
}

#[test]
fn prop_normal_quantile_symmetric_around_the_median() {
    // Phi^-1(p) = -Phi^-1(1-p); the approximation uses different rational
    // branches for the tails and the center, so symmetry across the branch
    // boundary (p = 0.02425) is a real property, not an identity
    for_all(300, |rng| {
        let p = rng.range_f64(1e-6, 0.5);
        let lo = normal_quantile(p);
        let hi = normal_quantile(1.0 - p);
        assert!(
            (lo + hi).abs() < 2e-6,
            "asymmetric at p={p}: {lo} vs {hi}"
        );
        assert!(lo <= 0.0, "sub-median quantile must be non-positive at p={p}");
    });
    assert!(normal_quantile(0.5).abs() < 1e-9);
}

#[test]
fn prop_normal_quantile_inverse_consistent_with_cdf() {
    // Phi(Phi^-1(p)) = p on a dense grid spanning both tail branches and
    // the central branch (tolerance covers the CDF approximation's 1.5e-7
    // absolute error, far below any decision threshold built on these)
    for i in 1..1000 {
        let p = i as f64 / 1000.0;
        let z = normal_quantile(p);
        let back = normal_cdf(z);
        assert!(
            (back - p).abs() < 1e-5,
            "Phi(Phi^-1({p})) = {back}, off by {}",
            (back - p).abs()
        );
    }
    // and the reverse composition on a z grid
    for i in -40..=40 {
        let z = i as f64 / 10.0;
        let p = normal_cdf(z);
        if p > 1e-4 && p < 1.0 - 1e-4 {
            let back = normal_quantile(p);
            assert!(
                (back - z).abs() < 1e-3,
                "Phi^-1(Phi({z})) = {back}, off by {}",
                (back - z).abs()
            );
        }
    }
}

#[test]
fn prop_normal_quantile_clamped_is_total_and_agrees_inside_range() {
    // the clamped variant must never panic, even on garbage, and must be
    // exactly the raw function on the interior it passes through
    for p in [-1.0, 0.0, 1.0, 2.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let z = normal_quantile_clamped(p);
        assert!(z.is_finite(), "clamped must be finite at p={p}, got {z}");
    }
    for_all(200, |rng| {
        let p = rng.range_f64(0.001, 0.999);
        assert_eq!(normal_quantile_clamped(p), normal_quantile(p));
    });
    // out-of-range inputs saturate at the clamp boundaries
    assert_eq!(normal_quantile_clamped(1.0), normal_quantile(0.999));
    assert_eq!(normal_quantile_clamped(0.0), normal_quantile(0.001));
}

// ---------------------------------------------------------------------------
// cluster event-kernel invariants
// ---------------------------------------------------------------------------

use sagesched::cluster::{EventPayload, EventQueue};

fn random_payload(rng: &mut Rng) -> EventPayload {
    // arrivals are excluded only because they carry a full Request; their
    // ordering goes through exactly the same (time, class, seq) key
    match rng.below(4) {
        0 => EventPayload::SpawnReady { replica: rng.below(8) as usize },
        1 => EventPayload::Recover { replica: rng.below(8) as usize },
        2 => EventPayload::Fail { replica: rng.below(8) as usize },
        _ => EventPayload::Decision,
    }
}

#[test]
fn prop_kernel_equal_timestamp_events_pop_in_insertion_order() {
    for_all(200, |rng| {
        let mut q = EventQueue::new();
        // several bursts of same-class events at a handful of shared
        // timestamps: within each (time, class) group, pops must come back
        // in exactly the push order (seq strictly increasing)
        let n = 3 + rng.below(40) as usize;
        for _ in 0..n {
            let at = rng.below(4) as f64; // few distinct times -> many ties
            q.push(at, EventPayload::Decision);
        }
        let mut prev: Option<(f64, u64)> = None;
        while let Some(ev) = q.pop() {
            if let Some((pat, pseq)) = prev {
                assert!(ev.at >= pat, "time order violated: {} after {pat}", ev.at);
                if ev.at == pat {
                    assert!(
                        ev.seq > pseq,
                        "equal-time events reordered: seq {} after {pseq}",
                        ev.seq
                    );
                }
            }
            prev = Some((ev.at, ev.seq));
        }
    });
}

#[test]
fn prop_kernel_interleaved_push_pop_never_reorders() {
    // model-based: a sorted reference list must agree with the queue under
    // arbitrary interleavings of pushes and pops
    for_all(200, |rng| {
        let mut q = EventQueue::new();
        let mut model: Vec<(f64, u8, u64)> = Vec::new();
        let mut seq = 0u64;
        for _ in 0..60 {
            if rng.below(3) < 2 || model.is_empty() {
                let at = rng.below(5) as f64 + if rng.below(2) == 0 { 0.5 } else { 0.0 };
                let payload = random_payload(rng);
                let class = payload.class();
                q.push(at, payload);
                model.push((at, class, seq));
                seq += 1;
            } else {
                let min = *model
                    .iter()
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                    .unwrap();
                model.retain(|e| *e != min);
                let ev = q.pop().expect("model says queue is non-empty");
                assert_eq!(
                    (ev.at, ev.class, ev.seq),
                    min,
                    "queue disagreed with the sorted model"
                );
            }
        }
        // drain: the remainder must come out exactly in model order
        let mut rest = model;
        rest.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for want in rest {
            let ev = q.pop().expect("queue drained early");
            assert_eq!((ev.at, ev.class, ev.seq), want);
        }
        assert!(q.pop().is_none(), "queue held events the model did not");
        assert!(q.is_empty());
    });
}

#[test]
fn prop_kernel_class_ranks_order_capacity_before_decisions() {
    // at one shared instant: spawn-ready and recoveries (capacity arrives)
    // fire before failures (capacity leaves), which fire before autoscaler
    // decisions — regardless of push order
    for_all(100, |rng| {
        let mut q = EventQueue::new();
        let mut payloads = vec![
            EventPayload::Decision,
            EventPayload::Fail { replica: 0 },
            EventPayload::Recover { replica: 1 },
            EventPayload::SpawnReady { replica: 2 },
        ];
        rng.shuffle(&mut payloads);
        for p in payloads {
            q.push(7.0, p);
        }
        let classes: Vec<u8> = std::iter::from_fn(|| q.pop().map(|e| e.class)).collect();
        let mut sorted = classes.clone();
        sorted.sort_unstable();
        assert_eq!(classes, sorted, "class ranks must order equal-time events");
        assert_eq!(classes, vec![0, 1, 2, 3]);
    });
}

// ---------------------------------------------------------------------------
// Kendall-tau estimator — it now scores every predictor's ordering quality
// in RunReport/ClusterReport, so its range, sign, and windowing are pinned
// by properties rather than the unit spot checks alone
// ---------------------------------------------------------------------------

use sagesched::util::stats::KendallTau;

#[test]
fn prop_kendall_tau_perfect_ordering_scores_one() {
    // any strictly increasing monotone transform of the actuals is a
    // perfect ranking: tau must be exactly 1 regardless of the values
    for_all(200, |rng| {
        let n = 2 + rng.below(60) as usize;
        let scale = rng.range_f64(0.5, 2.0);
        let mut t = KendallTau::new(n);
        let mut prev = 0.0;
        for _ in 0..n {
            prev += rng.range_f64(0.1, 10.0);
            let pred = prev * scale + prev * prev * 0.01;
            t.push(pred, prev);
        }
        assert!((t.tau() - 1.0).abs() < 1e-12, "tau {} != 1", t.tau());
    });
}

#[test]
fn prop_kendall_tau_inverted_ordering_scores_minus_one() {
    for_all(200, |rng| {
        let n = 2 + rng.below(60) as usize;
        let mut t = KendallTau::new(n);
        let mut prev = 0.0;
        for _ in 0..n {
            prev += rng.range_f64(0.1, 10.0);
            t.push(-prev, prev);
        }
        assert!((t.tau() + 1.0).abs() < 1e-12, "tau {} != -1", t.tau());
    });
}

#[test]
fn prop_kendall_tau_bounded_and_antisymmetric() {
    // |tau| <= 1 on arbitrary data, and negating the predictions negates
    // tau exactly (ties are excluded from both numerator and denominator)
    for_all(200, |rng| {
        let n = 2 + rng.below(80) as usize;
        let pairs: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.normal(), (rng.below(20) + 1) as f64))
            .collect();
        let mut t = KendallTau::new(n);
        let mut neg = KendallTau::new(n);
        for &(p, a) in &pairs {
            t.push(p, a);
            neg.push(-p, a);
        }
        let tau = t.tau();
        assert!((-1.0..=1.0).contains(&tau), "tau {tau} out of range");
        assert!((tau + neg.tau()).abs() < 1e-12, "not antisymmetric");
    });
}

#[test]
fn prop_kendall_tau_independent_predictions_near_zero() {
    // with 200 pairs the null std of tau is ~0.047; |tau| < 0.35 is a
    // > 7-sigma bound, safe for every fixed seed the harness generates
    for_all(60, |rng| {
        let mut t = KendallTau::new(256);
        for _ in 0..200 {
            t.push(rng.normal(), rng.normal());
        }
        let tau = t.tau();
        assert!(tau.abs() < 0.35, "independent data scored tau {tau}");
    });
}

#[test]
fn prop_kendall_tau_window_forgets_old_regime() {
    // fill the window with an inverted regime, then push one full window
    // of perfectly-ranked pairs: the estimate must recover to exactly 1,
    // i.e. the stale regime is fully evicted (windowed decay)
    for_all(100, |rng| {
        let cap = 2 + rng.below(40) as usize;
        let mut t = KendallTau::new(cap);
        let mut x = 0.0;
        for _ in 0..cap {
            x += rng.range_f64(0.1, 5.0);
            t.push(-x, x);
        }
        assert!((t.tau() + 1.0).abs() < 1e-12);
        for _ in 0..cap {
            x += rng.range_f64(0.1, 5.0);
            t.push(x, x);
        }
        assert_eq!(t.len(), cap, "window must stay at capacity");
        assert!(
            (t.tau() - 1.0).abs() < 1e-12,
            "stale regime survived the window: tau {}",
            t.tau()
        );
    });
}
