//! Elastic-autoscaling integration tests: conservation across scale-out and
//! scale-in for every router, determinism of uncertainty-aware decisions,
//! the scale-in-never-strands-a-live-request regression, retired-replica
//! accounting, the transfer-cost steal gate, the quantile-cost router A/B,
//! and the headline comparison — uncertainty-aware provisioning beats a
//! static fleet on goodput per replica-second at the same peak cap.

use std::collections::BTreeSet;

use sagesched::autoscale::ScaleAction;
use sagesched::cluster::{run_router_experiment, EventCluster, ReplicaState};
use sagesched::config::{
    ArrivalKind, AutoscaleKind, ExperimentConfig, FailureEvent, PolicyKind,
    RouterKind, ScaleStep,
};
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

fn event_count(cluster: &EventCluster, action: ScaleAction) -> usize {
    cluster
        .scaling_events
        .iter()
        .filter(|e| e.action == action)
        .count()
}

#[test]
fn step_scaling_conserves_requests_for_every_router() {
    // scripted scale-out (2 -> 4) and scale-in (4 -> 2) mid-run: every
    // router must complete each request exactly once with all cluster
    // bookkeeping drained, and the lifecycle must fire exactly once per
    // transition
    let mut cfg = cluster_cfg(2, 160, 24.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![
        ScaleStep { at: 1.5, target: 4 },
        ScaleStep { at: 4.5, target: 2 },
    ];
    cfg.cluster.autoscale.provision_delay = 0.5;
    cfg.cluster.autoscale.interval = 1.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        // conservation: completed + rejected + aborted == submitted
        let outcomes = cluster.merged_outcomes();
        let accounted =
            outcomes.len() as u64 + cluster.rejected() + cluster.aborted();
        assert_eq!(accounted, 160, "{router:?} lost requests under scaling");
        assert_eq!(cluster.rejected(), 0, "{router:?} rejected under scaling");
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(
            completed.len(),
            outcomes.len(),
            "{router:?} duplicated completions under scaling"
        );
        assert_eq!(completed, submitted, "{router:?} completion set mismatch");
        // no leaked bookkeeping
        assert_eq!(cluster.in_flight_count(), 0, "{router:?} leaked in-flight");
        assert!(
            cluster.total_backlog() < 1e-6,
            "{router:?} leaked predicted backlog"
        );
        // lifecycle fired exactly once per scripted transition
        assert_eq!(cluster.replicas.len(), 4, "{router:?} replica roster");
        assert_eq!(event_count(&cluster, ScaleAction::Provision), 2, "{router:?}");
        assert_eq!(event_count(&cluster, ScaleAction::Up), 2, "{router:?}");
        assert_eq!(event_count(&cluster, ScaleAction::Drain), 2, "{router:?}");
        assert_eq!(event_count(&cluster, ScaleAction::Retire), 2, "{router:?}");
        let retired = cluster
            .replicas
            .iter()
            .filter(|r| r.state == ReplicaState::Retired)
            .count();
        assert_eq!(retired, 2, "{router:?} retired-replica count");
    }
}

#[test]
fn scale_in_never_strands_a_live_request() {
    // a hard scale-in while the cluster is saturated: the victim holds
    // running/preempted work at drain time, which must finish in place —
    // and its queued work must be re-routed, never dropped
    let mut cfg = cluster_cfg(2, 120, 60.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 1.0, target: 1 }];
    cfg.cluster.autoscale.interval = 1.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let outcomes = cluster.merged_outcomes();
    assert_eq!(outcomes.len(), 120, "scale-in lost requests");
    let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
    assert_eq!(completed, submitted, "completion set mismatch");
    assert_eq!(cluster.in_flight_count(), 0);
    assert_eq!(event_count(&cluster, ScaleAction::Drain), 1);
    assert_eq!(event_count(&cluster, ScaleAction::Retire), 1);
    // the victim really is gone and empty
    let retire = cluster
        .scaling_events
        .iter()
        .find(|e| e.action == ScaleAction::Retire)
        .expect("retire event");
    let victim = &cluster.replicas[retire.replica];
    assert_eq!(victim.state, ReplicaState::Retired);
    assert!(victim.coord.is_idle(), "retired replica still holds work");
    assert!(victim.retired_at.is_some());
    // at rps 60 on 2 replicas the victim was mid-flight at t=1: it must
    // have served something before retiring, and the retire must come
    // after the drain began
    assert!(retire.at >= 1.0, "retired before the scale-in decision");
}

#[test]
fn uncertainty_aware_decisions_are_deterministic() {
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 8;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.interval = 1.0;
    cfg.cluster.autoscale.provision_delay = 1.0;
    let a = run_router_experiment(&cfg, RouterKind::CostAware).unwrap();
    let b = run_router_experiment(&cfg, RouterKind::CostAware).unwrap();
    assert_eq!(a.scaling_events, b.scaling_events, "scaling timeline differs");
    assert_eq!(a.routed, b.routed);
    assert_eq!(a.replica_seconds, b.replica_seconds);
    assert_eq!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean);
    assert_eq!(a.aggregate.measured, 160);
    // conservation under elastic scaling
    let n = a.aggregate.completed + a.aggregate.rejected + a.aggregate.aborted;
    assert_eq!(n, 160);
}

#[test]
fn autoscaling_composes_with_replica_failures() {
    // an outage on replica 0 while the uncertainty-aware policy is also
    // scaling: both lifecycles re-route work; conservation must still be
    // exact for every router
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 1.5, duration: 2.0 }];
    cfg.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
    // min == initial fleet: only scale-*out* can fire, so replica 0 is
    // guaranteed to still be active when its scheduled outage hits
    cfg.cluster.autoscale.min_replicas = 4;
    cfg.cluster.autoscale.max_replicas = 6;
    cfg.cluster.autoscale.work_per_replica = 5.0e5;
    cfg.cluster.autoscale.cooldown = 2.0;
    cfg.cluster.autoscale.provision_delay = 1.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        let outcomes = cluster.merged_outcomes();
        let accounted =
            outcomes.len() as u64 + cluster.rejected() + cluster.aborted();
        assert_eq!(accounted, 160, "{router:?} lost requests");
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(completed, submitted, "{router:?} completion set mismatch");
        assert_eq!(cluster.in_flight_count(), 0, "{router:?} leaked in-flight");
        assert!(event_count(&cluster, ScaleAction::Fail) >= 1, "{router:?}");
    }
}

#[test]
fn retired_replicas_stop_accruing_downtime_and_replica_seconds() {
    // the accounting regression: a replica retired mid-run must not count
    // as "down" for the remainder, and is billed only to its retirement
    let mut cfg = cluster_cfg(2, 120, 20.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 2.0, target: 1 }];
    cfg.cluster.autoscale.interval = 1.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 120);
    let report = cluster.report(0.0);
    let retire = report
        .scaling_events
        .iter()
        .find(|e| e.action == ScaleAction::Retire)
        .expect("retire event");
    let victim = retire.replica;
    let survivor = 1 - victim;
    // never down: a retired replica is gone, not "failed for the rest of
    // the run"
    assert_eq!(report.downtime[victim], 0.0);
    assert_eq!(report.downtime[survivor], 0.0);
    // billed exactly to its retirement instant (spawned at 0, no outages)
    assert!(
        (report.replica_seconds[victim] - retire.at).abs() < 1e-9,
        "victim billed {} but retired at {}",
        report.replica_seconds[victim],
        retire.at
    );
    assert!(
        report.replica_seconds[victim] < report.replica_seconds[survivor],
        "victim billed {} >= survivor {}",
        report.replica_seconds[victim],
        report.replica_seconds[survivor]
    );
    // the survivor is billed to the cluster horizon, which covers the
    // whole drain tail
    assert!(report.replica_seconds[survivor] > retire.at);
}

#[test]
fn steal_gate_blocks_unprofitable_transfers_and_reports_them() {
    // the PR 2 stealing scenario (one fast, one 20x-slower replica): with
    // the gate effectively off stealing rebalances as before; with an
    // enormous per-token transfer penalty every candidate is rejected,
    // reported, and the run still completes losslessly
    let mut base = cluster_cfg(2, 120, 24.0);
    base.cluster.speeds = vec![1.0, 0.05];
    let workload = WorkloadGen::new(base.workload.clone(), base.seed).generate();

    let mut free = base.clone();
    free.cluster.steal_transfer_per_token = 0.0;
    let mut cluster = EventCluster::with_router(&free, RouterKind::RoundRobin);
    cluster.run(workload.requests.clone()).unwrap();
    assert_eq!(cluster.completed(), 120);
    assert!(cluster.stolen > 0, "free transfer must steal");
    assert_eq!(cluster.steals_skipped(), 0);

    let mut gated = base.clone();
    gated.cluster.steal_transfer_per_token = 1.0e12;
    let mut cluster = EventCluster::with_router(&gated, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 120, "gated run lost requests");
    assert_eq!(cluster.stolen, 0, "absurd transfer cost must block stealing");
    assert!(
        cluster.steals_skipped() > 0,
        "rejected candidates must be reported"
    );
    let report = cluster.report(0.0);
    assert_eq!(report.stolen, 0);
    assert!(report.steals_skipped > 0);
}

#[test]
fn quantile_cost_router_ab_against_cost_aware_under_heavy_tails() {
    // same seeded bursty heavy-tailed workload (the default mix includes
    // the long-output write dataset), heterogeneous fleet: the
    // distribution-aware router must (a) conserve requests, (b) be exactly
    // reproducible, and (c) actually route differently from the mean-based
    // router — variance changes decisions, not just labels
    let mut cfg = cluster_cfg(4, 240, 24.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.cluster.speeds = vec![1.0, 1.0, 0.5, 0.5];
    let mean_based = run_router_experiment(&cfg, RouterKind::CostAware).unwrap();
    let q1 = run_router_experiment(&cfg, RouterKind::QuantileCost).unwrap();
    let q2 = run_router_experiment(&cfg, RouterKind::QuantileCost).unwrap();
    for r in [&mean_based, &q1] {
        let accounted =
            r.aggregate.completed + r.aggregate.rejected + r.aggregate.aborted;
        assert_eq!(accounted, 240, "{} lost requests", r.router);
        assert_eq!(r.aggregate.rejected, 0);
    }
    // determinism of the A/B itself
    assert_eq!(q1.routed, q2.routed);
    assert_eq!(q1.aggregate.ttlt.mean, q2.aggregate.ttlt.mean);
    // the quantile changes routing decisions on heavy-tailed backlogs
    assert_ne!(
        q1.routed, mean_based.routed,
        "quantile-cost routed identically to cost-aware"
    );
}

#[test]
fn uncertainty_aware_beats_static_on_goodput_per_replica_second() {
    // the fig12c acceptance scenario: bursty (MMPP) and diurnal demand at
    // the same long-run rate, static 6-replica fleet vs uncertainty-aware
    // provisioning capped at the same 6-replica peak. Both must serve every
    // request; the elastic fleet must do it on meaningfully fewer
    // replica-seconds, i.e. higher goodput per replica-second.
    for kind in [ArrivalKind::Mmpp, ArrivalKind::Diurnal] {
        let mut base = cluster_cfg(6, 240, 6.0);
        base.workload.arrival.kind = kind;
        base.workload.arrival.burst_factor = 6.0;
        base.workload.arrival.burst_on_mean = 2.0;
        base.workload.arrival.burst_off_mean = 6.0;
        base.workload.arrival.diurnal_period = 30.0;
        base.workload.arrival.diurnal_amplitude = 0.8;

        let static_run = run_router_experiment(&base, RouterKind::CostAware).unwrap();

        let mut elastic = base.clone();
        elastic.cluster.autoscale.kind = AutoscaleKind::UncertaintyAware;
        elastic.cluster.autoscale.min_replicas = 2;
        elastic.cluster.autoscale.max_replicas = 6; // same peak cap
        elastic.cluster.autoscale.quantile = 0.9;
        elastic.cluster.autoscale.work_per_replica = 1.0e6;
        elastic.cluster.autoscale.interval = 1.0;
        elastic.cluster.autoscale.cooldown = 2.0;
        elastic.cluster.autoscale.provision_delay = 1.0;
        let elastic_run = run_router_experiment(&elastic, RouterKind::CostAware).unwrap();

        // both fleets are lossless at this load
        assert_eq!(static_run.aggregate.completed, 240, "{kind:?} static lossy");
        assert_eq!(elastic_run.aggregate.completed, 240, "{kind:?} elastic lossy");
        // the elastic fleet actually scaled (timeline is non-trivial)
        assert!(
            !elastic_run.scaling_events.is_empty(),
            "{kind:?}: uncertainty-aware never made a scaling decision"
        );
        assert!(
            elastic_run.total_replica_seconds() < static_run.total_replica_seconds(),
            "{kind:?}: elastic used {} replica-s >= static {}",
            elastic_run.total_replica_seconds(),
            static_run.total_replica_seconds()
        );
        assert!(
            elastic_run.goodput_per_replica_second
                > static_run.goodput_per_replica_second,
            "{kind:?}: elastic gp/rep-s {} <= static {}",
            elastic_run.goodput_per_replica_second,
            static_run.goodput_per_replica_second
        );
    }
}

#[test]
fn reactive_scaling_responds_to_load_and_conserves() {
    // watermark policy sanity: under sustained pressure on a small fleet it
    // scales out (provisions at least one replica) and still conserves
    let mut cfg = cluster_cfg(2, 200, 30.0);
    cfg.cluster.autoscale.kind = AutoscaleKind::Reactive;
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.autoscale.max_replicas = 8;
    cfg.cluster.autoscale.high_watermark = 6.0;
    cfg.cluster.autoscale.low_watermark = 1.0;
    cfg.cluster.autoscale.cooldown = 1.0;
    cfg.cluster.autoscale.interval = 0.5;
    cfg.cluster.autoscale.provision_delay = 0.5;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 200);
    assert!(
        event_count(&cluster, ScaleAction::Provision) >= 1,
        "reactive never scaled out under 15 rps/replica pressure"
    );
    assert_eq!(cluster.in_flight_count(), 0);
}
