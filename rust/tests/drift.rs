//! Workload-drift regression tests: the online ranking predictor must
//! re-adapt after a mid-run request-mix shift while the static history
//! window stays poisoned by stale observations, and the cluster's shared
//! predictor must feed each completed request into `observe()` at most
//! once no matter how many replicas touched it (failure re-route,
//! scale-in drain/migration, stealing).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use sagesched::cluster::EventCluster;
use sagesched::config::{
    DatasetKind, ExperimentConfig, FailureEvent, PredictorKind, RouterKind,
    ScaleStep, WorkloadConfig,
};
use sagesched::core::Request;
use sagesched::distribution::LengthDist;
use sagesched::predictor::{make_predictor, Predictor, PredictorStats};
use sagesched::util::stats::KendallTau;
use sagesched::workload::WorkloadGen;

/// Kendall's tau of a predictor's rank scores over one stream segment,
/// while the predictor keeps learning online (predict-then-observe, the
/// serving order).
fn run_segmented(
    predictor: &mut dyn Predictor,
    requests: &[Request],
    segments: &[std::ops::Range<usize>],
) -> Vec<f64> {
    let mut taus: Vec<KendallTau> =
        segments.iter().map(|s| KendallTau::new(s.len().max(2))).collect();
    for (i, r) in requests.iter().enumerate() {
        let rank = predictor.predict_rank(r);
        for (t, s) in taus.iter_mut().zip(segments) {
            if s.contains(&i) {
                t.push(rank, r.true_output_len as f64);
            }
        }
        predictor.observe(r, r.true_output_len);
    }
    taus.iter().map(|t| t.tau()).collect()
}

#[test]
fn ranking_predictor_recovers_after_drift_history_does_not() {
    // One drifted stream, two predictors. The drift remaps topic -> length
    // profile while leaving embeddings untouched, so the 10k history
    // window keeps retrieving same-topic neighbours whose recorded lengths
    // come from the dead regime (plus the offline pre-warm corpus, which
    // is pre-drift by construction). The ranking predictor's pairwise
    // updates are driven by fresh completions with stale pairs decayed
    // out, so its ordering quality must come back.
    let mut cfg = ExperimentConfig::default();
    cfg.workload = WorkloadConfig::single(DatasetKind::ShareGpt);
    cfg.workload.n_requests = 2_000;
    cfg.workload.drift.at_fraction = 0.3; // shift at request 600
    let requests = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate().requests;
    let pre = 300..600; // trained, still pre-drift
    let post = 1_700..2_000; // 1100+ post-drift completions to adapt on
    let mut taus = HashMap::new();
    for kind in [PredictorKind::History, PredictorKind::Ranking] {
        let mut p = make_predictor(
            kind,
            cfg.workload.embed_dim,
            cfg.history_capacity,
            cfg.similarity_threshold,
            cfg.seed,
        );
        sagesched::serve::prewarm_predictor(p.as_mut(), &cfg);
        let t = run_segmented(p.as_mut(), &requests, &[pre.clone(), post.clone()]);
        taus.insert(kind.name(), t);
    }
    let (hist_pre, hist_post) = (taus["history"][0], taus["history"][1]);
    let (rank_pre, rank_post) = (taus["ranking"][0], taus["ranking"][1]);
    assert!(
        hist_pre > 0.1 && rank_pre > 0.1,
        "both predictors must rank usefully before the drift \
         (history {hist_pre:.3}, ranking {rank_pre:.3})"
    );
    assert!(
        rank_post >= 0.8 * rank_pre,
        "ranking predictor failed to re-adapt: tau {rank_pre:.3} -> {rank_post:.3}"
    );
    assert!(
        hist_post < 0.8 * hist_pre,
        "history window unexpectedly recovered (tau {hist_pre:.3} -> \
         {hist_post:.3}) — is the drift actually poisoning retrieval?"
    );
}

#[test]
fn drift_flag_keeps_run_deterministic_and_reports_tau() {
    // same seed + drift => byte-identical tau/counters; the report must
    // actually carry the new predictor-quality fields
    let mut cfg = ExperimentConfig::default();
    cfg.workload.n_requests = 200;
    cfg.workload.rps = 20.0;
    cfg.workload.drift.at_fraction = 0.5;
    cfg.history_prewarm = 200;
    cfg.predictor = PredictorKind::Ranking;
    let a = sagesched::serve::run_experiment(&cfg).unwrap();
    let b = sagesched::serve::run_experiment(&cfg).unwrap();
    assert_eq!(a.pred_tau, b.pred_tau);
    assert_eq!(a.pred_tau_n, b.pred_tau_n);
    assert_eq!(a.pred_cold, b.pred_cold);
    assert!(a.pred_tau_n > 0, "completions must feed the tau window");
    assert!(a.pred_tau.is_finite());
    let json = a.to_json().to_string();
    for key in ["pred_tau", "pred_tau_n", "pred_threshold_hits", "pred_fallback", "pred_cold"] {
        assert!(json.contains(key), "report JSON lost {key}");
    }
}

// ---------------------------------------------------------------------------
// shared-predictor observation dedup across replicas
// ---------------------------------------------------------------------------

/// Wraps a predictor and counts `observe()` calls per request id.
struct CountingPredictor {
    inner: Box<dyn Predictor>,
    observed: Arc<Mutex<HashMap<u64, u32>>>,
}

impl Predictor for CountingPredictor {
    fn name(&self) -> &'static str {
        "counting"
    }
    fn predict(&mut self, req: &Request) -> LengthDist {
        self.inner.predict(req)
    }
    fn predict_point(&mut self, req: &Request) -> f64 {
        self.inner.predict_point(req)
    }
    fn predict_rank(&mut self, req: &Request) -> f64 {
        self.inner.predict_rank(req)
    }
    fn observe(&mut self, req: &Request, output_len: u32) {
        *self.observed.lock().unwrap().entry(req.id).or_insert(0) += 1;
        self.inner.observe(req, output_len);
    }
    fn stats(&self) -> PredictorStats {
        self.inner.stats()
    }
}

#[test]
fn cluster_shared_predictor_observes_each_request_at_most_once() {
    // a run that exercises every re-dispatch path at once — replica
    // failure (re-route), a scripted scale-in with migration-cost-aware
    // drain, and a slow replica for work stealing — must still feed each
    // completed request into the shared predictor exactly once
    let mut cfg = ExperimentConfig::default();
    cfg.workload.n_requests = 160;
    cfg.workload.rps = 24.0;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0;
    cfg.cluster.replicas = 4;
    cfg.cluster.speeds = vec![1.0, 1.0, 1.0, 0.25];
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 1.5, duration: 3.0 }];
    cfg.cluster.autoscale.kind = sagesched::config::AutoscaleKind::Step;
    cfg.cluster.autoscale.steps = vec![ScaleStep { at: 4.0, target: 2 }];
    cfg.cluster.autoscale.min_replicas = 2;
    cfg.cluster.migration_kv_per_token = 0.5;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::CostAware);
    let observed = Arc::new(Mutex::new(HashMap::new()));
    cluster.predictor = Box::new(CountingPredictor {
        inner: make_predictor(
            cfg.predictor,
            cfg.workload.embed_dim,
            cfg.history_capacity,
            cfg.similarity_threshold,
            cfg.seed,
        ),
        observed: Arc::clone(&observed),
    });
    cluster.run(workload.requests).unwrap();
    let completed = cluster.completed();
    assert!(completed > 0);
    let counts = observed.lock().unwrap();
    let doubles: Vec<(&u64, &u32)> =
        counts.iter().filter(|(_, &n)| n > 1).collect();
    assert!(
        doubles.is_empty(),
        "shared predictor observed requests more than once: {doubles:?}"
    );
    assert_eq!(
        counts.len(),
        completed,
        "every completion must reach the shared predictor exactly once"
    );
}
