//! Integration tests: whole-stack simulated serving across policies,
//! preemption modes, datasets and configs.

use sagesched::config::{
    DatasetKind, ExperimentConfig, PolicyKind, PredictorKind, PreemptMode, WorkloadConfig,
};
use sagesched::metrics::RunReport;
use sagesched::serve::{build_sim_coordinator, run_experiment};
use sagesched::util::json::Json;
use sagesched::workload::WorkloadGen;

fn cfg_with(policy: PolicyKind, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = policy;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg
}

#[test]
fn every_policy_completes_and_accounts() {
    for policy in PolicyKind::ALL {
        let cfg = cfg_with(policy, 150, 10.0);
        let r = run_experiment(&cfg).unwrap();
        assert_eq!(r.measured, 150, "{policy:?}");
        assert!(r.ttlt.mean > 0.0 && r.ttft.mean > 0.0);
        assert!(r.ttft.mean <= r.ttlt.mean);
        assert!(r.busy_decode > 0.0);
        assert!(r.decode_steps > 0);
    }
}

#[test]
fn sagesched_beats_fcfs_under_heavy_load() {
    let sage = run_experiment(&cfg_with(PolicyKind::SageSched, 800, 10.0)).unwrap();
    let fcfs = run_experiment(&cfg_with(PolicyKind::Fcfs, 800, 10.0)).unwrap();
    assert!(
        sage.ttlt.mean < fcfs.ttlt.mean * 0.95,
        "sagesched {:.2} !< 0.95 * fcfs {:.2}",
        sage.ttlt.mean,
        fcfs.ttlt.mean
    );
}

#[test]
fn preemption_modes_both_complete() {
    for mode in [PreemptMode::Swap, PreemptMode::Recompute] {
        let mut cfg = cfg_with(PolicyKind::SageSched, 250, 12.0);
        cfg.preempt_mode = mode;
        let r = run_experiment(&cfg).unwrap();
        assert_eq!(r.measured, 250, "{mode:?}");
    }
}

#[test]
fn recompute_is_costlier_than_swap_under_churn() {
    // recompute re-runs prefill for prompt+generated; with long Alpaca
    // prompts it should not be cheaper than swapping
    let mut base = cfg_with(PolicyKind::Trail, 400, 12.0);
    base.workload = WorkloadConfig::single(DatasetKind::Alpaca);
    base.workload.n_requests = 400;
    base.workload.rps = 12.0;
    let mut swap_cfg = base.clone();
    swap_cfg.preempt_mode = PreemptMode::Swap;
    let mut rec_cfg = base.clone();
    rec_cfg.preempt_mode = PreemptMode::Recompute;
    let swap = run_experiment(&swap_cfg).unwrap();
    let rec = run_experiment(&rec_cfg).unwrap();
    if swap.preemptions > 50 && rec.preemptions > 50 {
        assert!(rec.busy_prefill >= swap.busy_prefill);
    }
}

#[test]
fn single_dataset_workloads_complete() {
    for ds in DatasetKind::ALL {
        let mut cfg = cfg_with(PolicyKind::SageSched, 120, 8.0);
        cfg.workload = WorkloadConfig::single(ds);
        cfg.workload.n_requests = 120;
        let r = run_experiment(&cfg).unwrap();
        assert_eq!(r.measured, 120, "{ds:?}");
        assert_eq!(r.ttlt_by_dataset.len(), 1);
    }
}

#[test]
fn predictors_all_drive_sagesched() {
    for pred in [
        PredictorKind::History,
        PredictorKind::LengthHistory,
        PredictorKind::Proxy,
        PredictorKind::Oracle,
    ] {
        let mut cfg = cfg_with(PolicyKind::SageSched, 150, 9.0);
        cfg.predictor = pred;
        let r = run_experiment(&cfg).unwrap();
        assert_eq!(r.measured, 150, "{pred:?}");
    }
}

#[test]
fn report_json_roundtrips() {
    let r = run_experiment(&cfg_with(PolicyKind::SageSched, 80, 8.0)).unwrap();
    let j = Json::parse(&r.to_json().to_string()).unwrap();
    assert_eq!(j.str_or("policy", ""), "sagesched");
    assert_eq!(j.get("measured").unwrap().as_u64(), Some(80));
    assert!(j.get("ttlt").unwrap().f64_or("mean", -1.0) > 0.0);
}

#[test]
fn experiment_is_deterministic_per_seed() {
    let a = run_experiment(&cfg_with(PolicyKind::SageSched, 150, 9.0)).unwrap();
    let b = run_experiment(&cfg_with(PolicyKind::SageSched, 150, 9.0)).unwrap();
    assert_eq!(a.ttlt.mean, b.ttlt.mean);
    assert_eq!(a.preemptions, b.preemptions);
    let mut cfg = cfg_with(PolicyKind::SageSched, 150, 9.0);
    cfg.seed = 1;
    let c = run_experiment(&cfg).unwrap();
    assert_ne!(a.ttlt.mean, c.ttlt.mean);
}

#[test]
fn config_json_drives_experiment() {
    let j = Json::parse(
        r#"{"policy":"fcfs","engine":"h800-qwen32b",
            "workload":{"rps":6,"n_requests":60}}"#,
    )
    .unwrap();
    let mut cfg = ExperimentConfig::from_json(&j).unwrap();
    cfg.warmup_fraction = 0.0;
    let r = run_experiment(&cfg).unwrap();
    assert_eq!(r.policy, "fcfs");
    assert_eq!(r.measured, 60);
}

#[test]
fn coordinator_stepwise_api() {
    // drive the coordinator manually (as the HTTP server does)
    let cfg = cfg_with(PolicyKind::SageSched, 0, 8.0);
    let mut coord = build_sim_coordinator(&cfg);
    let mut wl = cfg.workload.clone();
    wl.n_requests = 10;
    let reqs = WorkloadGen::new(wl, 3).generate().requests;
    for mut r in reqs {
        r.arrival = 0.0;
        coord.submit(r);
    }
    assert_eq!(coord.live_count(), 10);
    let mut steps = 0;
    while coord.step().unwrap() {
        steps += 1;
        assert!(steps < 100_000, "stuck");
    }
    assert_eq!(coord.outcomes().len(), 10);
    assert_eq!(coord.live_count(), 0);
}

#[test]
fn on_complete_callback_fires_for_every_request() {
    let cfg = cfg_with(PolicyKind::Fcfs, 0, 8.0);
    let mut coord = build_sim_coordinator(&cfg);
    let counter = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let c2 = counter.clone();
    coord.on_complete = Some(Box::new(move |_out, _eng| {
        c2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    }));
    let mut wl = cfg.workload.clone();
    wl.n_requests = 25;
    coord
        .run_workload(WorkloadGen::new(wl, 4).generate().requests)
        .unwrap();
    assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 25);
}

#[test]
fn markdown_report_emission() {
    let r = run_experiment(&cfg_with(PolicyKind::Ltr, 60, 6.0)).unwrap();
    let header = RunReport::markdown_header();
    let row = r.markdown_row();
    assert!(header.contains("TTLT"));
    assert!(row.contains("ltr"));
}

#[test]
fn noise_degrades_gracefully_not_catastrophically() {
    let clean = run_experiment(&cfg_with(PolicyKind::SageSched, 400, 10.0)).unwrap();
    let mut noisy_cfg = cfg_with(PolicyKind::SageSched, 400, 10.0);
    noisy_cfg.noise_mix = 0.2;
    let noisy = run_experiment(&noisy_cfg).unwrap();
    assert_eq!(noisy.measured, 400);
    // the paper's fig11: uncertainty-aware scheduling is robust to noise
    assert!(
        noisy.ttlt.mean < clean.ttlt.mean * 1.5,
        "noise blew up TTLT: {:.2} vs {:.2}",
        noisy.ttlt.mean,
        clean.ttlt.mean
    );
}
