//! Disaggregated prefill/decode serving integration tests: request
//! conservation through the KV-transfer fabric (no loss, no duplication,
//! bandwidth-respecting delivery times), byte-identical same-seed reports
//! in disagg mode, and survival of a prefill-pool outage.

use std::collections::BTreeSet;

use sagesched::cluster::{run_router_experiment, EventCluster};
use sagesched::config::{
    ExperimentConfig, FailureEvent, PolicyKind, PoolRole, RouterKind,
};
use sagesched::workload::WorkloadGen;

fn disagg_cfg(n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = 4;
    // [Prefill, Decode] cycles over 4 replicas: 0,2 prefill / 1,3 decode
    cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    cfg
}

#[test]
fn fabric_conserves_requests_and_respects_bandwidth() {
    // every request prefills in the prefill pool, rides the fabric, and
    // finishes in the decode pool — exactly once, with every fabric hop
    // taking at least tokens / bandwidth
    let cfg = disagg_cfg(120, 24.0);
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.rejected(), 0, "disagg run rejected requests");
    let outcomes = cluster.merged_outcomes();
    assert_eq!(outcomes.len(), 120, "lost or duplicated work");
    let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
    assert_eq!(completed, submitted, "completion set != submission set");
    assert_eq!(cluster.in_flight_count(), 0, "leaked in-flight entries");
    assert!(
        cluster.total_backlog() < 1e-6,
        "leaked predicted backlog: {}",
        cluster.total_backlog()
    );
    // every prompt must have crossed the fabric (at least once — degraded
    // delivery under KV pressure can bounce a handoff back through prefill)
    assert!(
        cluster.transfers >= 120,
        "only {} fabric handoffs for 120 prompts",
        cluster.transfers
    );
    assert_eq!(cluster.transfer_log.len(), cluster.transfers as usize);
    assert!(cluster.transfer_tokens > 0);
    let bandwidth = cfg.cluster.transfer_bandwidth;
    for &(enqueue, delivery, tokens) in &cluster.transfer_log {
        let min_delay = tokens as f64 / bandwidth;
        assert!(
            delivery - enqueue >= min_delay - 1e-9,
            "fabric delivered {tokens} tokens in {}s < {min_delay}s floor",
            delivery - enqueue
        );
    }
    // the report surfaces fabric + pool accounting
    let report = cluster.report(0.0);
    assert_eq!(report.transfers, cluster.transfers);
    assert!(report.transfer_utilization > 0.0);
    assert!(report.transfer_utilization <= 1.0 + 1e-9);
    assert_eq!(report.pool_replica_seconds.len(), 2);
    assert!(report.pool_replica_seconds.iter().all(|&s| s > 0.0));
}

#[test]
fn congested_fabric_queues_instead_of_dropping() {
    // one slow link: handoffs must queue behind each other (some delivery
    // takes strictly longer than its own serialization time) and still all
    // arrive
    let mut cfg = disagg_cfg(120, 40.0);
    cfg.cluster.transfer_links = 1;
    cfg.cluster.transfer_bandwidth = 4_000.0;
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 120);
    assert!(cluster.transfers >= 120);
    let bandwidth = cfg.cluster.transfer_bandwidth;
    let queued = cluster
        .transfer_log
        .iter()
        .filter(|&&(enq, del, tokens)| del - enq > tokens as f64 / bandwidth + 1e-9)
        .count();
    assert!(
        queued > 0,
        "a single 4k-token/s link at 40 rps must make some handoff wait"
    );
}

#[test]
fn disagg_reports_are_bit_identical_across_runs() {
    // same seed, same config, run twice: the fabric (link choice, queueing,
    // delivery order) must be fully deterministic
    let cfg = disagg_cfg(120, 20.0);
    for router in [RouterKind::LeastLoaded, RouterKind::CostAware] {
        let a = run_router_experiment(&cfg, router).unwrap();
        let b = run_router_experiment(&cfg, router).unwrap();
        assert_eq!(a.aggregate.measured, b.aggregate.measured, "{router:?}");
        assert_eq!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean, "{router:?}");
        assert_eq!(a.aggregate.ttlt.p99, b.aggregate.ttlt.p99, "{router:?}");
        assert_eq!(a.aggregate.ttft.mean, b.aggregate.ttft.mean, "{router:?}");
        assert_eq!(a.aggregate.makespan, b.aggregate.makespan, "{router:?}");
        assert_eq!(a.transfers, b.transfers, "{router:?}");
        assert_eq!(a.transfer_tokens, b.transfer_tokens, "{router:?}");
        assert_eq!(
            a.transfer_utilization, b.transfer_utilization,
            "{router:?}"
        );
        assert_eq!(
            a.pool_replica_seconds, b.pool_replica_seconds,
            "{router:?}"
        );
        assert_eq!(a.routed, b.routed, "{router:?}");
        let am: Vec<usize> = a.per_replica.iter().map(|r| r.measured).collect();
        let bm: Vec<usize> = b.per_replica.iter().map(|r| r.measured).collect();
        assert_eq!(am, bm, "{router:?}");
    }
}

#[test]
fn prefill_pool_outage_conserves_requests() {
    // replica 0 (prefill pool) fails mid-run: its un-prefilled work is
    // re-dispatched to the surviving prefill replica, handoffs keep
    // flowing, and every request still completes exactly once
    let mut cfg = disagg_cfg(120, 24.0);
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 1.5, duration: 3.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let outcomes = cluster.merged_outcomes();
    let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
    assert_eq!(completed.len(), outcomes.len(), "duplicated completions");
    let accounted = outcomes.len() as u64 + cluster.rejected() + cluster.aborted();
    assert_eq!(accounted, 120, "lost requests under prefill outage");
    assert_eq!(completed, submitted, "completion set mismatch");
    assert!(cluster.transfers > 0, "fabric stalled after the outage");
    assert_eq!(cluster.in_flight_count(), 0, "leaked in-flight entries");
    assert!(cluster.total_backlog() < 1e-6, "leaked predicted backlog");
    let report = cluster.report(0.0);
    assert!((report.downtime[0] - 3.0).abs() < 1e-9);
}

#[test]
fn colocated_runs_ignore_the_fabric() {
    // with no pools configured the fabric must be inert: no transfers, no
    // utilization, no pool accounting
    let mut cfg = disagg_cfg(80, 20.0);
    cfg.cluster.pools.clear();
    let report = run_router_experiment(&cfg, RouterKind::LeastLoaded).unwrap();
    assert_eq!(report.aggregate.measured, 80);
    assert_eq!(report.transfers, 0);
    assert_eq!(report.transfer_tokens, 0);
    assert_eq!(report.transfer_utilization, 0.0);
    assert!(report.pool_replica_seconds.is_empty());
}
