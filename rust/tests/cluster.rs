//! Event-driven cluster integration tests: request conservation (no loss,
//! no duplication across replicas), per-seed determinism of aggregate
//! reports, and heterogeneous-capacity behavior.

use std::collections::BTreeSet;

use sagesched::cluster::{run_router_experiment, EventCluster};
use sagesched::config::{ExperimentConfig, PolicyKind, RouterKind};
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

#[test]
fn every_router_conserves_requests() {
    // every submitted request completes exactly once, on exactly one
    // replica — no loss, no duplication — for every router
    let cfg = cluster_cfg(4, 160, 24.0);
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    assert_eq!(submitted.len(), 160);
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        assert_eq!(cluster.rejected, 0, "{router:?} rejected requests");
        let outcomes = cluster.merged_outcomes();
        assert_eq!(outcomes.len(), 160, "{router:?} lost or duplicated work");
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(
            completed, submitted,
            "{router:?} completion set != submission set"
        );
        // routed counts must account for every request
        let routed: u64 = cluster.routed.iter().sum();
        assert_eq!(routed, 160);
    }
}

#[test]
fn identical_seed_and_router_give_bit_identical_reports() {
    let cfg = cluster_cfg(4, 120, 20.0);
    for router in [RouterKind::LeastLoaded, RouterKind::CostAware] {
        let a = run_router_experiment(&cfg, router).unwrap();
        let b = run_router_experiment(&cfg, router).unwrap();
        assert_eq!(a.aggregate.measured, b.aggregate.measured);
        assert_eq!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean, "{router:?}");
        assert_eq!(a.aggregate.ttlt.p99, b.aggregate.ttlt.p99);
        assert_eq!(a.aggregate.ttft.mean, b.aggregate.ttft.mean);
        assert_eq!(a.aggregate.makespan, b.aggregate.makespan);
        assert_eq!(a.aggregate.preemptions, b.aggregate.preemptions);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.imbalance, b.imbalance);
        let am: Vec<usize> = a.per_replica.iter().map(|r| r.measured).collect();
        let bm: Vec<usize> = b.per_replica.iter().map(|r| r.measured).collect();
        assert_eq!(am, bm);
    }
}

#[test]
fn different_seeds_differ() {
    let cfg = cluster_cfg(4, 120, 20.0);
    let mut cfg2 = cfg.clone();
    cfg2.seed = 17;
    let a = run_router_experiment(&cfg, RouterKind::LeastLoaded).unwrap();
    let b = run_router_experiment(&cfg2, RouterKind::LeastLoaded).unwrap();
    assert_ne!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean);
}

#[test]
fn heterogeneous_replicas_complete_everything() {
    // two full-speed and two quarter-speed replicas, smaller KV on the
    // slow ones: all requests still complete exactly once
    let mut cfg = cluster_cfg(4, 160, 16.0);
    cfg.cluster.speeds = vec![1.0, 1.0, 0.25, 0.25];
    cfg.cluster.kv_capacities = vec![10_000, 10_000, 6_000, 6_000];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 160);
    let report = cluster.report(0.0);
    assert_eq!(report.aggregate.measured, 160);
    // least-loaded routing sheds load away from the slow replicas: the
    // fast pair must complete at least as much as the slow pair
    let fast: usize = report.per_replica[..2].iter().map(|r| r.measured).sum();
    let slow: usize = report.per_replica[2..].iter().map(|r| r.measured).sum();
    assert!(
        fast >= slow,
        "fast pair completed {fast} < slow pair {slow}"
    );
}

#[test]
fn undersized_replica_errors_instead_of_hanging() {
    // a replica whose KV pool cannot hold a typical prompt must surface a
    // descriptive error, not spin the event loop forever
    let mut cfg = cluster_cfg(2, 10, 8.0);
    cfg.cluster.kv_capacities = vec![10_000, 64]; // replica 1: 4 blocks
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    let err = cluster.run(workload.requests).unwrap_err();
    assert!(
        err.to_string().contains("wedged"),
        "expected wedge diagnostic, got: {err}"
    );
}

#[test]
fn per_replica_reports_sum_to_aggregate() {
    let cfg = cluster_cfg(5, 150, 25.0);
    let report = run_router_experiment(&cfg, RouterKind::RoundRobin).unwrap();
    assert_eq!(report.replicas, 5);
    let sum: usize = report.per_replica.iter().map(|r| r.measured).sum();
    assert_eq!(sum, report.aggregate.measured);
    // round-robin spreads routing evenly: 150 over 5 replicas
    assert!(report.routed.iter().all(|&n| n == 30));
    assert!(report.imbalance >= 1.0);
}

#[test]
fn warmup_fraction_trims_cluster_aggregate() {
    let cfg = cluster_cfg(4, 120, 20.0);
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let full = cluster.report(0.0);
    let trimmed = cluster.report(0.25);
    assert_eq!(full.aggregate.measured, 120);
    assert_eq!(trimmed.aggregate.measured, 90);
}
