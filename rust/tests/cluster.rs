//! Event-driven cluster integration tests: request conservation (no loss,
//! no duplication across replicas), per-seed determinism of aggregate
//! reports, heterogeneous-capacity behavior, replica failure/re-routing,
//! and non-stationary (MMPP / diurnal) arrival streams.

use std::collections::BTreeSet;

use sagesched::cluster::{run_router_experiment, EventCluster};
use sagesched::config::{
    ArrivalKind, ExperimentConfig, FailureEvent, PolicyKind, RouterKind,
};
use sagesched::workload::WorkloadGen;

fn cluster_cfg(replicas: usize, n: usize, rps: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyKind::SageSched;
    cfg.workload.n_requests = n;
    cfg.workload.rps = rps;
    cfg.warmup_fraction = 0.0;
    cfg.history_prewarm = 0; // keep the tests fast
    cfg.cluster.replicas = replicas;
    cfg
}

#[test]
fn every_router_conserves_requests() {
    // every submitted request completes exactly once, on exactly one
    // replica — no loss, no duplication — for every router
    let cfg = cluster_cfg(4, 160, 24.0);
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    assert_eq!(submitted.len(), 160);
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        assert_eq!(cluster.rejected(), 0, "{router:?} rejected requests");
        let outcomes = cluster.merged_outcomes();
        assert_eq!(outcomes.len(), 160, "{router:?} lost or duplicated work");
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(
            completed, submitted,
            "{router:?} completion set != submission set"
        );
        // routed counts must account for every request
        let routed: u64 = cluster.routed.iter().sum();
        assert_eq!(routed, 160);
    }
}

#[test]
fn identical_seed_and_router_give_bit_identical_reports() {
    let cfg = cluster_cfg(4, 120, 20.0);
    for router in [RouterKind::LeastLoaded, RouterKind::CostAware] {
        let a = run_router_experiment(&cfg, router).unwrap();
        let b = run_router_experiment(&cfg, router).unwrap();
        assert_eq!(a.aggregate.measured, b.aggregate.measured);
        assert_eq!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean, "{router:?}");
        assert_eq!(a.aggregate.ttlt.p99, b.aggregate.ttlt.p99);
        assert_eq!(a.aggregate.ttft.mean, b.aggregate.ttft.mean);
        assert_eq!(a.aggregate.makespan, b.aggregate.makespan);
        assert_eq!(a.aggregate.preemptions, b.aggregate.preemptions);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.imbalance, b.imbalance);
        let am: Vec<usize> = a.per_replica.iter().map(|r| r.measured).collect();
        let bm: Vec<usize> = b.per_replica.iter().map(|r| r.measured).collect();
        assert_eq!(am, bm);
    }
}

#[test]
fn different_seeds_differ() {
    let cfg = cluster_cfg(4, 120, 20.0);
    let mut cfg2 = cfg.clone();
    cfg2.seed = 17;
    let a = run_router_experiment(&cfg, RouterKind::LeastLoaded).unwrap();
    let b = run_router_experiment(&cfg2, RouterKind::LeastLoaded).unwrap();
    assert_ne!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean);
}

#[test]
fn heterogeneous_replicas_complete_everything() {
    // two full-speed and two quarter-speed replicas, smaller KV on the
    // slow ones: all requests still complete exactly once
    let mut cfg = cluster_cfg(4, 160, 16.0);
    cfg.cluster.speeds = vec![1.0, 1.0, 0.25, 0.25];
    cfg.cluster.kv_capacities = vec![10_000, 10_000, 6_000, 6_000];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 160);
    let report = cluster.report(0.0);
    assert_eq!(report.aggregate.measured, 160);
    // least-loaded routing sheds load away from the slow replicas: the
    // fast pair must complete at least as much as the slow pair
    let fast: usize = report.per_replica[..2].iter().map(|r| r.measured).sum();
    let slow: usize = report.per_replica[2..].iter().map(|r| r.measured).sum();
    assert!(
        fast >= slow,
        "fast pair completed {fast} < slow pair {slow}"
    );
}

#[test]
fn undersized_replica_errors_instead_of_hanging() {
    // a replica whose KV pool cannot hold a typical prompt must surface a
    // descriptive error, not spin the event loop forever
    let mut cfg = cluster_cfg(2, 10, 8.0);
    cfg.cluster.kv_capacities = vec![10_000, 64]; // replica 1: 4 blocks
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    let err = cluster.run(workload.requests).unwrap_err();
    assert!(
        err.to_string().contains("wedged"),
        "expected wedge diagnostic, got: {err}"
    );
}

#[test]
fn per_replica_reports_sum_to_aggregate() {
    let cfg = cluster_cfg(5, 150, 25.0);
    let report = run_router_experiment(&cfg, RouterKind::RoundRobin).unwrap();
    assert_eq!(report.replicas, 5);
    let sum: usize = report.per_replica.iter().map(|r| r.measured).sum();
    assert_eq!(sum, report.aggregate.measured);
    // round-robin spreads routing evenly: 150 over 5 replicas
    assert!(report.routed.iter().all(|&n| n == 30));
    assert!(report.imbalance >= 1.0);
}

#[test]
fn failure_rerouting_conserves_requests_for_every_router() {
    // bursty arrivals + a mid-run outage on replica 0: every router must
    // re-dispatch the lost work over the survivors and still complete each
    // request exactly once, with all cluster bookkeeping drained
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.workload.arrival.kind = ArrivalKind::Mmpp;
    cfg.workload.arrival.burst_factor = 5.0;
    cfg.workload.arrival.burst_on_mean = 1.0;
    cfg.workload.arrival.burst_off_mean = 3.0;
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 1.5, duration: 3.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let submitted: BTreeSet<u64> = workload.requests.iter().map(|r| r.id).collect();
    for router in RouterKind::ALL {
        let mut cluster = EventCluster::with_router(&cfg, router);
        cluster.run(workload.requests.clone()).unwrap();
        let outcomes = cluster.merged_outcomes();
        let completed: BTreeSet<u64> = outcomes.iter().map(|o| o.id).collect();
        assert_eq!(
            completed.len(),
            outcomes.len(),
            "{router:?} duplicated completions under failure"
        );
        // conservation: completed + rejected + aborted == submitted
        let accounted =
            outcomes.len() as u64 + cluster.rejected() + cluster.aborted();
        assert_eq!(accounted, 160, "{router:?} lost requests under failure");
        assert_eq!(cluster.rejected(), 0, "{router:?} rejected under failure");
        assert_eq!(completed, submitted, "{router:?} completion set mismatch");
        // no leaked bookkeeping: nothing in flight, backlog drained
        assert_eq!(cluster.in_flight_count(), 0, "{router:?} leaked in-flight");
        assert!(
            cluster.total_backlog() < 1e-6,
            "{router:?} leaked predicted backlog: {}",
            cluster.total_backlog()
        );
    }
}

#[test]
fn failure_triggers_rerouting_and_records_downtime() {
    let mut cfg = cluster_cfg(4, 160, 24.0);
    cfg.cluster.failures = vec![FailureEvent { replica: 0, at: 1.5, duration: 3.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert!(
        cluster.re_routed > 0,
        "replica 0 must have held live work at the failure instant"
    );
    let report = cluster.report(0.0);
    assert_eq!(report.aggregate.measured, 160);
    assert!(
        (report.downtime[0] - 3.0).abs() < 1e-9,
        "downtime[0] = {}",
        report.downtime[0]
    );
    for i in 1..4 {
        assert_eq!(report.downtime[i], 0.0);
    }
    assert_eq!(report.re_routed, cluster.re_routed);
}

#[test]
fn failed_replica_recovers_and_serves_again() {
    // long tail of arrivals after the recovery point: the recovered
    // replica must rejoin the routable set (round-robin cycles over all
    // survivors, so post-recovery arrivals reach it again)
    let mut cfg = cluster_cfg(2, 120, 12.0);
    cfg.cluster.failures = vec![FailureEvent { replica: 1, at: 1.0, duration: 2.0 }];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 120);
    let report = cluster.report(0.0);
    // replica 1 completed work even though it crashed mid-run
    assert!(
        report.per_replica[1].completed > 0,
        "recovered replica never served again"
    );
    assert!((report.downtime[1] - 2.0).abs() < 1e-9);
}

#[test]
fn work_stealing_rebalances_a_cold_replica() {
    // an extreme speed skew: replica 1 is 20x slower, so least-loaded
    // routing still queues work on it during bursts while replica 0 goes
    // idle — stealing must move queued requests to the idle fast replica
    let mut cfg = cluster_cfg(2, 120, 24.0);
    cfg.cluster.speeds = vec![1.0, 0.05];
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
    cluster.run(workload.requests).unwrap();
    assert_eq!(cluster.completed(), 120);
    assert!(
        cluster.stolen > 0,
        "idle fast replica never stole from the backlogged slow one"
    );
    let report = cluster.report(0.0);
    // the fast replica ends up completing more than its routed share
    assert!(report.per_replica[0].completed > report.per_replica[1].completed);
}

#[test]
fn bursty_and_diurnal_cluster_runs_are_deterministic() {
    for kind in [ArrivalKind::Mmpp, ArrivalKind::Diurnal] {
        let mut cfg = cluster_cfg(4, 120, 20.0);
        cfg.workload.arrival.kind = kind;
        cfg.cluster.failures = vec![FailureEvent { replica: 2, at: 2.0, duration: 2.0 }];
        let a = run_router_experiment(&cfg, RouterKind::CostAware).unwrap();
        let b = run_router_experiment(&cfg, RouterKind::CostAware).unwrap();
        assert_eq!(a.aggregate.measured, 120, "{kind:?}");
        assert_eq!(a.aggregate.ttlt.mean, b.aggregate.ttlt.mean, "{kind:?}");
        assert_eq!(a.routed, b.routed, "{kind:?}");
        assert_eq!(a.re_routed, b.re_routed, "{kind:?}");
        assert_eq!(a.stolen, b.stolen, "{kind:?}");
        assert_eq!(a.downtime, b.downtime, "{kind:?}");
    }
}

#[test]
fn warmup_fraction_trims_cluster_aggregate() {
    let cfg = cluster_cfg(4, 120, 20.0);
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
    cluster.run(workload.requests).unwrap();
    let full = cluster.report(0.0);
    let trimmed = cluster.report(0.25);
    assert_eq!(full.aggregate.measured, 120);
    assert_eq!(trimmed.aggregate.measured, 90);
}
