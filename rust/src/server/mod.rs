//! Minimal HTTP/1.1 JSON API over the real-model coordinator.
//!
//! Hand-rolled on `std::net` (the offline build has no tokio/hyper): an
//! acceptor thread parses requests and forwards them over a channel to the
//! single serving thread, which owns the [`crate::serve::Coordinator`] over
//! the [`crate::engine::RealEngine`] and steps it continuously — SageSched
//! scheduling applied to live HTTP traffic.
//!
//! Endpoints:
//! * `POST /v1/generate`  body `{"prompt": "...", "max_tokens"?: n}` →
//!   `{"text", "output_tokens", "ttft_s", "ttlt_s"}`
//! * `GET /metrics`  → run-report JSON so far
//! * `GET /healthz`  → `{"ok":true}`

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::DatasetKind;
use crate::core::{Request, RequestId, RequestOutcome};
use crate::embedding::Embedder;
use crate::engine::RealEngine;
use crate::serve::Coordinator;
use crate::util::json::Json;

/// A parsed HTTP request (just what the API needs).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one HTTP/1.1 request from a stream.
pub fn read_http_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    loop {
        let mut hdr = String::new();
        reader.read_line(&mut hdr)?;
        let h = hdr.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Write an HTTP response with a JSON body.
pub fn write_json_response(stream: &mut TcpStream, status: u16, body: &Json) -> Result<()> {
    let text = body.to_string();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        text.len(),
        text
    )?;
    Ok(())
}

struct Submission {
    prompt: String,
    max_tokens: Option<u32>,
    reply: Sender<Json>,
}

enum ServerMsg {
    Generate(Submission),
    Metrics(Sender<Json>),
}

/// Handle to a running server (join on drop is intentional-manual).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the acceptor with a dummy connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving a [`RealEngine`]-backed coordinator on `addr`
/// (e.g. `"127.0.0.1:8080"`; port 0 picks a free port).
pub fn serve(addr: &str, mut coord: Coordinator<RealEngine>) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).context("binding server address")?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(false)?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx): (Sender<ServerMsg>, Receiver<ServerMsg>) = mpsc::channel();
    let next_id = Arc::new(AtomicU64::new(1));

    // --- serving thread: owns the coordinator ---------------------------
    let stop_srv = stop.clone();
    let serving = std::thread::spawn(move || {
        let started = Instant::now();
        let mut waiting: HashMap<RequestId, (Sender<Json>, Instant)> = HashMap::new();
        let (done_tx, done_rx): (
            Sender<(RequestOutcome, String)>,
            Receiver<(RequestOutcome, String)>,
        ) = mpsc::channel();
        coord.on_complete = Some(Box::new(move |outcome, engine: &mut RealEngine| {
            let text = engine.output_text(outcome.id).unwrap_or_default();
            let _ = done_tx.send((outcome.clone(), text));
        }));
        let mut embedder_dim = coord.engine.runtime().meta().d_model;
        loop {
            if stop_srv.load(Ordering::SeqCst) && waiting.is_empty() {
                break;
            }
            // ingest new work (non-blocking)
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    ServerMsg::Generate(sub) => {
                        let now = started.elapsed().as_secs_f64();
                        coord.advance_to(now);
                        let id = next_id_from(&sub);
                        let tokens = crate::tokenizer::encode_truncated(
                            &sub.prompt,
                            coord.engine.runtime().meta().prefill_len,
                        );
                        let emb = {
                            let mut e =
                                crate::runtime::HloEmbedder { rt: coord.engine.runtime() };
                            e.embed(&sub.prompt)
                        };
                        embedder_dim = emb.dim();
                        let req = Request {
                            id,
                            prompt: sub.prompt.clone(),
                            input_len: tokens.len() as u32,
                            true_output_len: u32::MAX, // unknown: real inference
                            arrival: now,
                            dataset: DatasetKind::ShareGpt,
                            topic: 0,
                            embedding: emb,
                            true_dist: None,
                            // HTTP traffic defaults to the Standard tier
                            // (tiered serving is a simulator-side study)
                            slo: crate::slo::SloClass::Standard,
                            prefix_key: Vec::new(),
                        };
                        if let Some(mt) = sub.max_tokens {
                            coord.engine.max_output = mt;
                        }
                        if coord.submit(req) {
                            waiting.insert(id, (sub.reply, Instant::now()));
                        } else {
                            let _ = sub.reply.send(Json::obj(vec![(
                                "error",
                                Json::str("server overloaded (queue full)"),
                            )]));
                        }
                    }
                    ServerMsg::Metrics(reply) => {
                        let report = coord.report(0.0);
                        let _ = reply.send(report.to_json());
                    }
                }
            }
            let _ = embedder_dim;
            // serve
            coord.advance_to(started.elapsed().as_secs_f64());
            match coord.step() {
                Ok(true) => {}
                Ok(false) => std::thread::sleep(Duration::from_millis(2)),
                Err(e) => {
                    log::error!("serving step failed: {e:#}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
            // deliver completions
            while let Ok((outcome, text)) = done_rx.try_recv() {
                if let Some((reply, _)) = waiting.remove(&outcome.id) {
                    let _ = reply.send(Json::obj(vec![
                        ("text", Json::str(text)),
                        ("output_tokens", Json::num(outcome.output_len as f64)),
                        ("ttft_s", Json::num(outcome.ttft())),
                        ("ttlt_s", Json::num(outcome.ttlt())),
                    ]));
                }
            }
        }
    });

    // --- acceptor thread -------------------------------------------------
    let stop_acc = stop.clone();
    let tx_acc = tx.clone();
    let id_gen = next_id.clone();
    let acceptor = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop_acc.load(Ordering::SeqCst) {
                break;
            }
            let Ok(mut stream) = conn else { continue };
            let tx = tx_acc.clone();
            let id_gen = id_gen.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(&mut stream, &tx, &id_gen);
            });
        }
    });

    Ok(ServerHandle { addr: local, stop, threads: vec![serving, acceptor] })
}

// request ids for HTTP traffic are allocated by the acceptor side and
// smuggled through the prompt-handling closure; keep a simple global
fn next_id_from(sub: &Submission) -> RequestId {
    // stable-enough unique id: hash of pointer + time
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    (sub as *const Submission as usize).hash(&mut h);
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos()
        .hash(&mut h);
    h.finish()
}

fn handle_connection(
    stream: &mut TcpStream,
    tx: &Sender<ServerMsg>,
    _id_gen: &AtomicU64,
) -> Result<()> {
    let req = read_http_request(stream)?;
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            write_json_response(stream, 200, &Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", "/metrics") => {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(ServerMsg::Metrics(reply_tx)).ok();
            match reply_rx.recv_timeout(Duration::from_secs(5)) {
                Ok(j) => write_json_response(stream, 200, &j),
                Err(_) => write_json_response(
                    stream,
                    500,
                    &Json::obj(vec![("error", Json::str("metrics timeout"))]),
                ),
            }
        }
        ("POST", "/v1/generate") => {
            let body = match Json::parse(&req.body) {
                Ok(b) => b,
                Err(e) => {
                    return write_json_response(
                        stream,
                        400,
                        &Json::obj(vec![("error", Json::str(format!("bad json: {e}")))]),
                    )
                }
            };
            let Some(prompt) = body.get("prompt").and_then(Json::as_str) else {
                return write_json_response(
                    stream,
                    400,
                    &Json::obj(vec![("error", Json::str("missing prompt"))]),
                );
            };
            let max_tokens = body.get("max_tokens").and_then(Json::as_u64).map(|v| v as u32);
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(ServerMsg::Generate(Submission {
                prompt: prompt.to_string(),
                max_tokens,
                reply: reply_tx,
            }))
            .ok();
            match reply_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(j) => write_json_response(stream, 200, &j),
                Err(_) => write_json_response(
                    stream,
                    500,
                    &Json::obj(vec![("error", Json::str("generation timeout"))]),
                ),
            }
        }
        _ => write_json_response(
            stream,
            404,
            &Json::obj(vec![("error", Json::str("not found"))]),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_parsing_roundtrip() {
        // spin a trivial echo server to exercise read_http_request
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_http_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/v1/generate");
            assert_eq!(req.body, r#"{"prompt":"hi"}"#);
            write_json_response(&mut s, 200, &Json::obj(vec![("ok", Json::Bool(true))]))
                .unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write!(
            c,
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{{\"prompt\":\"hi\"}}"
        )
        .unwrap();
        let mut resp = String::new();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.contains(r#"{"ok":true}"#));
        t.join().unwrap();
    }
}
