//! Gittins index computation (§3.3 of the paper).
//!
//! For a job whose (remaining) service cost is a random variable `X ~ D`,
//! the Gittins index is
//!
//! ```text
//!     G(D) = inf_{Δ>0}  E[min{X, Δ}] / P(X ≤ Δ)
//! ```
//!
//! — the minimum attainable *amortized* cost per unit of completion
//! probability. Serving the job with the smallest index minimizes mean
//! latency for jobs with unknown durations but known duration
//! distributions (Gittins & Jones 1979; Gittins 1989).
//!
//! For a discrete distribution the infimum is attained at a support point,
//! so the index is computed exactly in O(k) with prefix sums. Runtime
//! *refresh* conditions the cost distribution on the service already
//! received (`X > a`) and re-evaluates; SageSched does this only at bucket
//! boundaries to bound overhead and avoid priority thrashing.

use crate::distribution::LengthDist;

/// Exact Gittins index of a discrete cost distribution.
///
/// Evaluates `E[min(X, Δ)] / P(X ≤ Δ)` at every support point Δ and takes
/// the minimum. Support must be non-negative costs.
pub fn gittins_index(dist: &LengthDist) -> f64 {
    let values = dist.support();
    let probs = dist.probs();
    debug_assert!(!values.is_empty());

    // prefix(j) = Σ_{i<=j} p_i * v_i   and   cdf(j) = Σ_{i<=j} p_i
    // E[min(X, v_j)] = prefix(j) + v_j * (1 - cdf(j))
    let mut best = f64::INFINITY;
    let mut prefix = 0.0;
    let mut cdf = 0.0;
    for (v, p) in values.iter().zip(probs) {
        prefix += v * p;
        cdf += p;
        let e_min = prefix + v * (1.0 - cdf);
        let g = e_min / cdf;
        if g < best {
            best = g;
        }
    }
    best
}

/// Gittins index of the *remaining* cost for a job that has already
/// consumed `age` cost units without completing.
///
/// When the observed age exceeds the distribution's maximum support (the
/// prediction was an underestimate — an "overdue" job), there is no
/// conditional distribution to form. The belief-consistent treatment is
/// memorylessness *plus* a monotone penalty: remaining cost is at least as
/// uncertain as a fresh draw, and the index must not *drop* below what it
/// was at the support edge (otherwise overdue jobs oscillate back to top
/// priority — a measurable TTLT pathology). `age + mean` is increasing in
/// age and dominates every in-support index, keeping overdue jobs parked
/// behind predictable ones, exactly how SRPT treats revealed-long jobs.
pub fn gittins_index_at_age(dist: &LengthDist, age: f64) -> f64 {
    match dist.conditional_excess(age) {
        Some(rem) => gittins_index(&rem),
        None => age + dist.mean().max(1.0),
    }
}

/// Bucketed Gittins refresh state for one request (§3.3's
/// timeliness/stability tradeoff): the index is recomputed only when the
/// generated-token count crosses a bucket boundary.
#[derive(Clone, Debug)]
pub struct BucketedGittins {
    /// cost distribution fixed at admission (cost units)
    dist: LengthDist,
    /// bucket size in *output tokens* (paper default 200)
    bucket_tokens: u32,
    /// last bucket for which the index was computed
    last_bucket: Option<u32>,
    /// cached index value
    cached: f64,
    /// number of index recomputations (observability / fig12)
    pub refresh_count: u32,
}

impl BucketedGittins {
    pub fn new(dist: LengthDist, bucket_tokens: u32) -> BucketedGittins {
        assert!(bucket_tokens >= 1);
        BucketedGittins {
            dist,
            bucket_tokens,
            last_bucket: None,
            cached: f64::INFINITY,
            refresh_count: 0,
        }
    }

    /// Current index given `generated` output tokens so far and the cost
    /// already consumed (in cost units, from the cost model). Recomputes
    /// only at bucket boundaries.
    pub fn index(&mut self, generated: u32, consumed_cost: f64) -> f64 {
        let bucket = generated / self.bucket_tokens;
        if self.last_bucket != Some(bucket) {
            self.cached = gittins_index_at_age(&self.dist, consumed_cost);
            self.last_bucket = Some(bucket);
            self.refresh_count += 1;
        }
        self.cached
    }

    /// Force a recomputation (used when the underlying prediction changes).
    pub fn invalidate(&mut self) {
        self.last_bucket = None;
    }

    pub fn dist(&self) -> &LengthDist {
        &self.dist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_mass_index_is_value() {
        // deterministic job: G = E[min(X,Δ)]/P(X<=Δ) minimized at Δ=c → c
        let d = LengthDist::point(42.0);
        assert!((gittins_index(&d) - 42.0).abs() < 1e-12);
    }

    #[test]
    fn prefers_cheap_likely_completion() {
        // 90% chance of finishing at cost 1, 10% at cost 1000:
        // G at Δ=1: E[min]=0.9*1+0.1*1 = 1.0; /0.9 = 1.111
        let d = LengthDist::from_weighted(&[(1.0, 0.9), (1000.0, 0.1)]);
        let g = gittins_index(&d);
        assert!((g - (1.0 / 0.9)).abs() < 1e-9, "g={g}");
        // far below the mean (≈ 100.9): Gittins exploits the distribution
        assert!(g < d.mean() / 50.0);
    }

    #[test]
    fn uniform_two_point() {
        // X ∈ {2, 10} equally likely.
        // Δ=2: (0.5*2 + 0.5*2)/0.5 = 4;  Δ=10: mean=6 / 1 = 6 → G=4
        let d = LengthDist::from_weighted(&[(2.0, 0.5), (10.0, 0.5)]);
        assert!((gittins_index(&d) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fig6_shape_gittins_disagrees_with_mean() {
        // Request A: moderate, concentrated cost. Request B: larger mean but
        // bimodal with high early-completion mass (the paper's fig6).
        let a = LengthDist::from_weighted(&[(80.0, 0.5), (120.0, 0.5)]); // mean 100
        let b = LengthDist::from_weighted(&[(10.0, 0.6), (400.0, 0.4)]); // mean 166
        assert!(b.mean() > a.mean());
        // Gittins prefers B (index ≈ (0.6*10+0.4*10)/0.6 = 16.7 < 80)
        assert!(gittins_index(&b) < gittins_index(&a));
    }

    #[test]
    fn index_monotone_under_stochastic_dominance() {
        let small = LengthDist::from_samples(&[10.0, 20.0, 30.0]);
        let large = LengthDist::from_samples(&[100.0, 200.0, 300.0]);
        assert!(gittins_index(&small) < gittins_index(&large));
    }

    #[test]
    fn age_conditioning_removes_low_support() {
        let d = LengthDist::from_weighted(&[(10.0, 0.5), (100.0, 0.5)]);
        let g0 = gittins_index_at_age(&d, 0.0);
        let g50 = gittins_index_at_age(&d, 50.0);
        // after surviving past 10, only the 100 branch remains: remaining 50
        assert!((g50 - 50.0).abs() < 1e-9);
        assert!(g0 < g50);
    }

    #[test]
    fn overdue_penalized_and_monotone() {
        let d = LengthDist::from_samples(&[10.0, 20.0]);
        let g25 = gittins_index_at_age(&d, 25.0);
        let g40 = gittins_index_at_age(&d, 40.0);
        // overdue index exceeds any in-support index and keeps growing
        assert!(g25 > 20.0);
        assert!(g40 > g25);
    }

    #[test]
    fn bucketed_refresh_only_at_boundaries() {
        let d = LengthDist::from_samples(&[100.0, 5000.0, 20000.0]);
        let mut b = BucketedGittins::new(d, 200);
        let g0 = b.index(0, 0.0);
        let g1 = b.index(50, 1000.0); // same bucket → cached
        assert_eq!(g0, g1);
        assert_eq!(b.refresh_count, 1);
        let g2 = b.index(200, 4000.0); // new bucket → refresh
        assert_eq!(b.refresh_count, 2);
        assert_ne!(g0, g2);
    }

    #[test]
    fn invalidate_forces_recompute() {
        let d = LengthDist::from_samples(&[10.0, 100.0]);
        let mut b = BucketedGittins::new(d, 200);
        b.index(0, 0.0);
        b.invalidate();
        b.index(0, 0.0);
        assert_eq!(b.refresh_count, 2);
    }

    #[test]
    fn gittins_leq_mean_always() {
        // E[min(X,Δ)]/P(X≤Δ) at the max support point equals the mean, so
        // the infimum is ≤ mean for every distribution.
        let dists = [
            LengthDist::from_samples(&[1.0, 2.0, 3.0]),
            LengthDist::from_weighted(&[(5.0, 0.2), (50.0, 0.5), (500.0, 0.3)]),
            LengthDist::uniform(10.0, 1000.0, 25),
        ];
        for d in dists {
            assert!(gittins_index(&d) <= d.mean() + 1e-9);
        }
    }
}
