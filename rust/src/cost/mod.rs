//! Service-cost modeling (§3.2 of the paper).
//!
//! The paper's key observation: in the *memory-bound* regime the cost that
//! matters is cumulative KVCache·time, `Σ_{l=1..I+O} l · U_MT`; in the
//! *compute-bound* regime it is cumulative attention compute,
//! `Σ_{l=I..I+O} l · U_CT`. Both reduce (up to a unit constant that does not
//! affect relative order) to the same paradigm
//!
//! ```text
//!     C(I, O) = O²/2 + I·O
//! ```
//!
//! so a single *resource-bound* model serves both regimes. The fig10
//! baselines — `C = O` (output-length-based, as SSJF/TRAIL assume) and
//! `C = I + 2·O` (overall-length-based, as in fairness-serving work) — are
//! implemented alongside for the ablation.

use crate::config::CostModelKind;
use crate::distribution::LengthDist;

/// Maps (input length, output length) to a scalar service cost, and output
/// length *distributions* to cost distributions.
pub trait CostModel: Send + Sync {
    fn kind(&self) -> CostModelKind;

    /// Total service cost of a request with input `i` that will emit `o`
    /// output tokens.
    fn cost(&self, i: u32, o: f64) -> f64;

    /// Cost already consumed after generating `g` of the output tokens.
    /// Must equal `cost(i, g)` for consistency (cost is cumulative in O).
    fn consumed(&self, i: u32, g: u32) -> f64 {
        self.cost(i, g as f64)
    }

    /// Transform an output-length distribution into a service-cost
    /// distribution. Valid because every model here is strictly increasing
    /// in `o` for fixed `i`.
    fn cost_dist(&self, i: u32, lengths: &LengthDist) -> LengthDist {
        lengths.map_monotonic(|o| self.cost(i, o))
    }
}

/// The paper's model: `C = O²/2 + I·O`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceBoundCost;

impl CostModel for ResourceBoundCost {
    fn kind(&self) -> CostModelKind {
        CostModelKind::ResourceBound
    }

    fn cost(&self, i: u32, o: f64) -> f64 {
        0.5 * o * o + i as f64 * o
    }
}

/// Fig10 baseline 1: `C = O` (what output-length-based schedulers assume).
#[derive(Clone, Copy, Debug, Default)]
pub struct OutputLenCost;

impl CostModel for OutputLenCost {
    fn kind(&self) -> CostModelKind {
        CostModelKind::OutputLen
    }

    fn cost(&self, _i: u32, o: f64) -> f64 {
        o
    }
}

/// Fig10 baseline 2: `C = I + 2·O` (input + doubled output weight, after
/// Sheng et al.'s fairness cost). Note the `I` offset cancels in *remaining*
/// cost but not in initial queuing order.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverallLenCost;

impl CostModel for OverallLenCost {
    fn kind(&self) -> CostModelKind {
        CostModelKind::OverallLen
    }

    fn cost(&self, i: u32, o: f64) -> f64 {
        i as f64 + 2.0 * o
    }
}

/// Construct a boxed cost model from its kind.
pub fn make_cost_model(kind: CostModelKind) -> Box<dyn CostModel> {
    match kind {
        CostModelKind::ResourceBound => Box::new(ResourceBoundCost),
        CostModelKind::OutputLen => Box::new(OutputLenCost),
        CostModelKind::OverallLen => Box::new(OverallLenCost),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_bound_formula() {
        let m = ResourceBoundCost;
        // C = O²/2 + I·O
        assert_eq!(m.cost(10, 4.0), 8.0 + 40.0);
        assert_eq!(m.cost(0, 2.0), 2.0);
        assert_eq!(m.cost(5, 0.0), 0.0);
    }

    #[test]
    fn consumed_matches_cost_prefix() {
        let m = ResourceBoundCost;
        assert_eq!(m.consumed(10, 4), m.cost(10, 4.0));
        assert!(m.consumed(10, 5) > m.consumed(10, 4));
    }

    #[test]
    fn all_models_strictly_increasing_in_o() {
        for kind in [
            CostModelKind::ResourceBound,
            CostModelKind::OutputLen,
            CostModelKind::OverallLen,
        ] {
            let m = make_cost_model(kind);
            let mut prev = m.cost(100, 0.0);
            for o in 1..50 {
                let c = m.cost(100, o as f64);
                assert!(c > prev, "{kind:?} not increasing at o={o}");
                prev = c;
            }
        }
    }

    #[test]
    fn cost_dist_transforms_support() {
        let lengths = LengthDist::from_samples(&[10.0, 20.0]);
        let m = ResourceBoundCost;
        let cd = m.cost_dist(100, &lengths);
        assert_eq!(cd.support()[0], 0.5 * 100.0 + 1000.0);
        assert_eq!(cd.support()[1], 0.5 * 400.0 + 2000.0);
        assert_eq!(cd.probs(), lengths.probs());
    }

    #[test]
    fn hybridity_example_from_fig2b() {
        // Two requests with equal output length but different input length
        // must have different costs under the paper's model (but identical
        // under output-length-based modeling) — the crux of demand
        // hybridity.
        let rb = ResourceBoundCost;
        let ol = OutputLenCost;
        assert!(rb.cost(1000, 100.0) > rb.cost(10, 100.0));
        assert_eq!(ol.cost(1000, 100.0), ol.cost(10, 100.0));
    }
}
