//! Byte-level tokenizer, mirroring `python/compile/config.py` exactly.
//!
//! Token ids 0..=255 are raw bytes; 256 = BOS, 257 = EOS, 258 = PAD. The
//! same mapping is used by the L2 model at AOT time, so the rust request
//! path and the compiled artifacts always agree on vocabulary.

/// Beginning-of-sequence token id.
pub const BOS_ID: u32 = 256;
/// End-of-sequence token id (generation terminates on sampling this).
pub const EOS_ID: u32 = 257;
/// Padding token id (fills idle decode lanes / prompt tails).
pub const PAD_ID: u32 = 258;
/// Vocabulary size (256 bytes + BOS + EOS + PAD).
pub const VOCAB: usize = 259;

/// Encode text into `[BOS, byte...]` token ids.
pub fn encode(text: &str) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS_ID);
    out.extend(text.as_bytes().iter().map(|&b| b as u32));
    out
}

/// Encode and truncate to at most `max_len` tokens (BOS always kept).
pub fn encode_truncated(text: &str, max_len: usize) -> Vec<u32> {
    let mut toks = encode(text);
    toks.truncate(max_len.max(1));
    toks
}

/// Decode generated token ids back to text. Non-byte tokens (BOS/EOS/PAD)
/// are skipped; invalid UTF-8 is replaced.
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t < 256)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Pad a token sequence to `len` with PAD (panics if already longer).
pub fn pad_to(tokens: &[u32], len: usize) -> Vec<u32> {
    assert!(tokens.len() <= len, "sequence longer than pad target");
    let mut out = tokens.to_vec();
    out.resize(len, PAD_ID);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let toks = encode("hello");
        assert_eq!(toks[0], BOS_ID);
        assert_eq!(toks.len(), 6);
        assert_eq!(decode(&toks), "hello");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo ∆ world";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn special_tokens_skipped_in_decode() {
        let mut toks = encode("ab");
        toks.push(EOS_ID);
        toks.push(PAD_ID);
        assert_eq!(decode(&toks), "ab");
    }

    #[test]
    fn truncation_keeps_bos() {
        let toks = encode_truncated("abcdefgh", 4);
        assert_eq!(toks.len(), 4);
        assert_eq!(toks[0], BOS_ID);
        assert_eq!(decode(&toks), "abc");
    }

    #[test]
    fn pad_to_fills_with_pad() {
        let toks = pad_to(&encode("a"), 5);
        assert_eq!(toks.len(), 5);
        assert_eq!(&toks[2..], &[PAD_ID, PAD_ID, PAD_ID]);
    }

    #[test]
    #[should_panic]
    fn pad_to_shorter_panics() {
        pad_to(&encode("abcdef"), 3);
    }

    #[test]
    fn vocab_constants_consistent() {
        assert_eq!(VOCAB, 259);
        assert!(BOS_ID < VOCAB as u32 && EOS_ID < VOCAB as u32 && PAD_ID < VOCAB as u32);
    }
}
