//! Empirical length/cost distributions.
//!
//! SageSched's core data type: a discrete distribution over output lengths
//! (or service costs), represented as sorted support points with
//! probabilities. Built from history samples by the predictor, transformed
//! into cost space by a [`crate::cost::CostModel`], conditioned on observed
//! age, and consumed by [`crate::gittins`].

use crate::util::rng::Rng;

/// A discrete probability distribution over non-negative values with a
/// sorted support. Probabilities are kept normalized (sum == 1 ± eps).
#[derive(Clone, Debug, PartialEq)]
pub struct LengthDist {
    /// strictly increasing support values
    values: Vec<f64>,
    /// probabilities aligned with `values`, summing to 1
    probs: Vec<f64>,
}

impl LengthDist {
    /// Build from (value, weight) pairs; values are merged (summing weights),
    /// sorted, and weights normalized. Panics on empty/non-positive input.
    pub fn from_weighted(pairs: &[(f64, f64)]) -> LengthDist {
        assert!(!pairs.is_empty(), "empty distribution");
        let mut sorted: Vec<(f64, f64)> = pairs
            .iter()
            .filter(|(_, w)| *w > 0.0)
            .copied()
            .collect();
        assert!(!sorted.is_empty(), "all weights non-positive");
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN value"));
        let mut values = Vec::with_capacity(sorted.len());
        let mut probs: Vec<f64> = Vec::with_capacity(sorted.len());
        for (v, w) in sorted {
            if let Some(last) = values.last() {
                if v == *last {
                    *probs.last_mut().unwrap() += w;
                    continue;
                }
            }
            values.push(v);
            probs.push(w);
        }
        let total: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= total;
        }
        LengthDist { values, probs }
    }

    /// Build from raw samples (each sample weight 1).
    pub fn from_samples(samples: &[f64]) -> LengthDist {
        let pairs: Vec<(f64, f64)> = samples.iter().map(|&s| (s, 1.0)).collect();
        LengthDist::from_weighted(&pairs)
    }

    /// A distribution with all mass at one point.
    pub fn point(value: f64) -> LengthDist {
        LengthDist { values: vec![value], probs: vec![1.0] }
    }

    /// Uniform over `n` evenly spaced points in [lo, hi].
    pub fn uniform(lo: f64, hi: f64, n: usize) -> LengthDist {
        assert!(n >= 1 && hi >= lo);
        if n == 1 {
            return LengthDist::point(0.5 * (lo + hi));
        }
        let step = (hi - lo) / (n - 1) as f64;
        let values: Vec<f64> = (0..n).map(|i| lo + step * i as f64).collect();
        let probs = vec![1.0 / n as f64; n];
        LengthDist { values, probs }
    }

    pub fn support(&self) -> &[f64] {
        &self.values
    }

    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.values
            .iter()
            .zip(&self.probs)
            .map(|(v, p)| v * p)
            .sum()
    }

    pub fn variance(&self) -> f64 {
        let m = self.mean();
        self.values
            .iter()
            .zip(&self.probs)
            .map(|(v, p)| p * (v - m) * (v - m))
            .sum()
    }

    pub fn min(&self) -> f64 {
        self.values[0]
    }

    pub fn max(&self) -> f64 {
        *self.values.last().unwrap()
    }

    /// P(X <= x).
    pub fn cdf(&self, x: f64) -> f64 {
        let mut acc = 0.0;
        for (v, p) in self.values.iter().zip(&self.probs) {
            if *v <= x {
                acc += p;
            } else {
                break;
            }
        }
        acc
    }

    /// Smallest support value v with CDF(v) >= q.
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let mut acc = 0.0;
        for (v, p) in self.values.iter().zip(&self.probs) {
            acc += p;
            if acc >= q - 1e-12 {
                return *v;
            }
        }
        self.max()
    }

    /// Map support values through a strictly increasing function (e.g. a
    /// length→cost transform); probabilities are preserved.
    pub fn map_monotonic(&self, f: impl Fn(f64) -> f64) -> LengthDist {
        let values: Vec<f64> = self.values.iter().map(|&v| f(v)).collect();
        for w in values.windows(2) {
            debug_assert!(w[1] > w[0], "map_monotonic needs a strictly increasing f");
        }
        LengthDist { values, probs: self.probs.clone() }
    }

    /// Condition on X > a: the remaining-value distribution of X - a.
    /// Returns None when no support mass lies above `a` (job "overdue":
    /// callers fall back to a point mass — see `gittins::overdue_index`).
    pub fn conditional_excess(&self, a: f64) -> Option<LengthDist> {
        let mut values = Vec::new();
        let mut probs = Vec::new();
        for (v, p) in self.values.iter().zip(&self.probs) {
            if *v > a {
                values.push(*v - a);
                probs.push(*p);
            }
        }
        if values.is_empty() {
            return None;
        }
        let total: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= total;
        }
        Some(LengthDist { values, probs })
    }

    /// Mix with another distribution: (1-w)·self + w·other.
    /// Used by fig11's noise injection (merge a uniform at ratio 1:4).
    pub fn mix(&self, other: &LengthDist, w: f64) -> LengthDist {
        assert!((0.0..=1.0).contains(&w));
        let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(self.len() + other.len());
        for (v, p) in self.values.iter().zip(&self.probs) {
            pairs.push((*v, p * (1.0 - w)));
        }
        for (v, p) in other.values.iter().zip(&other.probs) {
            pairs.push((*v, p * w));
        }
        LengthDist::from_weighted(&pairs)
    }

    /// Collapse to at most `k` buckets (quantile-spaced), keeping the mean of
    /// each bucket as its representative. Bounds Gittins evaluation cost.
    pub fn compress(&self, k: usize) -> LengthDist {
        assert!(k >= 1);
        if self.len() <= k {
            return self.clone();
        }
        let per = 1.0 / k as f64;
        let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(k);
        let mut acc = 0.0;
        let mut bucket_mass = 0.0;
        let mut bucket_mean = 0.0;
        let mut next_edge = per;
        for (v, p) in self.values.iter().zip(&self.probs) {
            bucket_mass += p;
            bucket_mean += v * p;
            acc += p;
            if acc >= next_edge - 1e-12 {
                pairs.push((bucket_mean / bucket_mass, bucket_mass));
                bucket_mass = 0.0;
                bucket_mean = 0.0;
                next_edge += per;
            }
        }
        if bucket_mass > 0.0 {
            pairs.push((bucket_mean / bucket_mass, bucket_mass));
        }
        LengthDist::from_weighted(&pairs)
    }

    /// Sample a value.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.values[rng.categorical(&self.probs)]
    }

    /// Total-variation distance to another distribution over the merged
    /// support (both treated as discrete).
    pub fn tv_distance(&self, other: &LengthDist) -> f64 {
        let mut i = 0;
        let mut j = 0;
        let mut tv = 0.0;
        while i < self.len() || j < other.len() {
            let (vi, vj) = (
                self.values.get(i).copied().unwrap_or(f64::INFINITY),
                other.values.get(j).copied().unwrap_or(f64::INFINITY),
            );
            if vi < vj {
                tv += self.probs[i];
                i += 1;
            } else if vj < vi {
                tv += other.probs[j];
                j += 1;
            } else {
                tv += (self.probs[i] - other.probs[j]).abs();
                i += 1;
                j += 1;
            }
        }
        tv / 2.0
    }

    /// 1-Wasserstein (earth mover's) distance via CDF difference.
    pub fn w1_distance(&self, other: &LengthDist) -> f64 {
        // merge supports, integrate |CDF_a - CDF_b|
        let mut points: Vec<f64> = self
            .values
            .iter()
            .chain(other.values.iter())
            .copied()
            .collect();
        points.sort_by(|a, b| a.partial_cmp(b).unwrap());
        points.dedup();
        let mut dist = 0.0;
        for w in points.windows(2) {
            let (x0, x1) = (w[0], w[1]);
            dist += (self.cdf(x0) - other.cdf(x0)).abs() * (x1 - x0);
        }
        dist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(vals: &[f64]) -> LengthDist {
        LengthDist::from_samples(vals)
    }

    #[test]
    fn from_samples_merges_and_normalizes() {
        let dist = d(&[2.0, 1.0, 2.0, 3.0]);
        assert_eq!(dist.support(), &[1.0, 2.0, 3.0]);
        assert!((dist.probs()[1] - 0.5).abs() < 1e-12);
        assert!((dist.probs().iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_variance() {
        let dist = d(&[1.0, 3.0]);
        assert!((dist.mean() - 2.0).abs() < 1e-12);
        assert!((dist.variance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_and_quantile() {
        let dist = d(&[10.0, 20.0, 30.0, 40.0]);
        assert!((dist.cdf(20.0) - 0.5).abs() < 1e-12);
        assert_eq!(dist.quantile(0.5), 20.0);
        assert_eq!(dist.quantile(0.51), 30.0);
        assert_eq!(dist.quantile(1.0), 40.0);
        assert_eq!(dist.cdf(5.0), 0.0);
    }

    #[test]
    fn conditional_excess_shifts_and_renormalizes() {
        let dist = d(&[10.0, 20.0, 30.0]);
        let c = dist.conditional_excess(15.0).unwrap();
        assert_eq!(c.support(), &[5.0, 15.0]);
        assert!((c.probs()[0] - 0.5).abs() < 1e-12);
        assert!(dist.conditional_excess(30.0).is_none());
    }

    #[test]
    fn conditional_excess_at_zero_is_identity() {
        let dist = d(&[10.0, 20.0]);
        let c = dist.conditional_excess(0.0).unwrap();
        assert_eq!(c.support(), dist.support());
    }

    #[test]
    fn map_monotonic_preserves_probs() {
        let dist = d(&[1.0, 2.0]);
        let m = dist.map_monotonic(|x| x * x);
        assert_eq!(m.support(), &[1.0, 4.0]);
        assert_eq!(m.probs(), dist.probs());
    }

    #[test]
    fn mix_weights_mass() {
        let a = LengthDist::point(1.0);
        let b = LengthDist::point(2.0);
        let m = a.mix(&b, 0.25);
        assert_eq!(m.support(), &[1.0, 2.0]);
        assert!((m.probs()[0] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn compress_preserves_mean_approximately() {
        let vals: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let dist = LengthDist::from_samples(&vals);
        let c = dist.compress(10);
        assert!(c.len() <= 11);
        assert!((c.mean() - dist.mean()).abs() / dist.mean() < 0.01);
    }

    #[test]
    fn sampling_matches_probs() {
        let dist = LengthDist::from_weighted(&[(1.0, 0.8), (5.0, 0.2)]);
        let mut rng = Rng::new(11);
        let n = 20_000;
        let ones = (0..n).filter(|_| dist.sample(&mut rng) == 1.0).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.8).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn tv_distance_properties() {
        let a = d(&[1.0, 2.0]);
        let b = d(&[3.0, 4.0]);
        assert!((a.tv_distance(&b) - 1.0).abs() < 1e-12);
        assert!(a.tv_distance(&a) < 1e-12);
        let c = a.mix(&b, 0.5);
        assert!((a.tv_distance(&c) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn w1_distance_point_masses() {
        let a = LengthDist::point(0.0);
        let b = LengthDist::point(10.0);
        assert!((a.w1_distance(&b) - 10.0).abs() < 1e-12);
        assert!(a.w1_distance(&a) < 1e-12);
    }

    #[test]
    fn uniform_constructor() {
        let u = LengthDist::uniform(0.0, 100.0, 11);
        assert_eq!(u.len(), 11);
        assert!((u.mean() - 50.0).abs() < 1e-9);
    }
}
