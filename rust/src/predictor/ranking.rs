//! Online learning-to-rank output-length predictor.
//!
//! "Efficient LLM Scheduling by Learning to Rank" (vllm-ltr) observes that
//! SJF/Gittins-style schedulers do not need calibrated token counts — they
//! need the *relative ordering* of concurrent requests' output lengths.
//! [`RankingPredictor`] reproduces that idea inside the simulator with no
//! external ML dependency: a linear scoring model over cheap prompt
//! features, trained incrementally by pairwise logistic (RankNet-style)
//! SGD on completed requests.
//!
//! **Features.** φ(req) = [bias, normalized log input length, sin/cos of
//! the arrival phase (diurnal-style context), the prompt embedding]. All
//! are available at admission for ~free; no tokenizer or proxy model runs.
//!
//! **Training.** Each `observe()` pairs the completed request against a
//! handful of recent completions from a bounded FIFO buffer and takes one
//! logistic step per pair toward "longer output ⇒ larger score". Pairs are
//! down-weighted by `decay^age` of the stale partner, so observations from
//! a previous workload regime lose influence exponentially — this is what
//! lets the model re-adapt after a mid-run drift while the 10k-window
//! [`HistoryPredictor`](super::HistoryPredictor) keeps retrieving poisoned
//! neighbours for thousands of requests.
//!
//! **Serving.** `predict_rank()` returns the raw score w·φ — the seam the
//! SJF/Gittins policies sort by. `predict()` (needed by the cost model and
//! Gittins index, which want a distribution) calibrates the score against
//! the buffer: the observed lengths of the `calib_k` completions whose
//! scores are nearest the query's, decay-weighted, form the predicted
//! length distribution. Rank quality is reported as windowed Kendall's tau
//! in `RunReport`/`ClusterReport`.

use std::collections::VecDeque;

use crate::core::Request;
use crate::distribution::LengthDist;
use crate::util::rng::Rng;

use super::{cold_start_prior, Predictor, PredictorStats};

/// One completed request retained for pairwise training and calibration.
#[derive(Clone, Debug)]
struct Obs {
    phi: Vec<f64>,
    output_len: f64,
    /// observation sequence number (for exponential age weighting)
    at: u64,
}

/// Online pairwise learning-to-rank predictor (see module docs).
pub struct RankingPredictor {
    /// linear score weights, one per feature
    w: Vec<f64>,
    embed_dim: usize,
    /// SGD step size
    pub lr: f64,
    /// per-observation age discount: pair weight = decay^(age of partner)
    pub decay: f64,
    /// training/calibration buffer (FIFO)
    buffer: VecDeque<Obs>,
    cap: usize,
    /// pairwise updates drawn per observation
    pub pairs_per_obs: usize,
    /// neighbours (by score) used to calibrate `predict()`'s distribution
    pub calib_k: usize,
    /// observations required before leaving the cold-start prior
    pub min_obs: usize,
    /// cap on distribution support (compression)
    pub max_support: usize,
    rng: Rng,
    /// total observations ever seen (drives age weighting)
    seen: u64,
    /// retrieval-outcome counters (observability); `threshold_hits`
    /// counts model-served predictions, `cold` counts prior fallbacks
    pub stats: PredictorStats,
}

impl RankingPredictor {
    pub fn new(embed_dim: usize, seed: u64) -> RankingPredictor {
        let dim = embed_dim + 4;
        RankingPredictor {
            w: vec![0.0; dim],
            embed_dim,
            lr: 0.1,
            decay: 0.995,
            buffer: VecDeque::new(),
            cap: 512,
            pairs_per_obs: 8,
            calib_k: 32,
            min_obs: 16,
            max_support: 64,
            rng: Rng::new(seed ^ 0x7a_4e_11),
            seen: 0,
            stats: PredictorStats::default(),
        }
    }

    /// Number of completions currently in the training buffer.
    pub fn len(&self) -> usize {
        self.buffer.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffer.is_empty()
    }

    /// Feature map φ(req); all components are O(1) to compute and bounded.
    fn features(&self, req: &Request) -> Vec<f64> {
        let mut phi = Vec::with_capacity(self.embed_dim + 4);
        phi.push(1.0);
        // ln(4096) ≈ 8.3 normalizes typical prompt lengths into [0, 1]
        phi.push(((1.0 + req.input_len as f64).ln() / 8.3).min(2.0));
        let phase = 2.0 * std::f64::consts::PI * req.arrival / 120.0;
        phi.push(phase.sin());
        phi.push(phase.cos());
        for i in 0..self.embed_dim {
            phi.push(*req.embedding.0.get(i).unwrap_or(&0.0) as f64);
        }
        phi
    }

    fn score_phi(&self, phi: &[f64]) -> f64 {
        self.w.iter().zip(phi).map(|(w, x)| w * x).sum()
    }

    /// Current ranking score for a request (larger = longer expected
    /// output). Exposed for diagnostics; `predict_rank` is the trait seam.
    pub fn score(&self, req: &Request) -> f64 {
        self.score_phi(&self.features(req))
    }
}

impl Predictor for RankingPredictor {
    fn name(&self) -> &'static str {
        "ranking"
    }

    fn predict(&mut self, req: &Request) -> LengthDist {
        if self.buffer.len() < self.min_obs.max(1) {
            self.stats.cold += 1;
            return cold_start_prior();
        }
        self.stats.threshold_hits += 1;
        let s = self.score(req);
        // calibrate: lengths of the calib_k buffered completions whose
        // scores are nearest the query's, decay-weighted by age
        let mut by_dist: Vec<(f64, f64, u64)> = self
            .buffer
            .iter()
            .map(|o| ((self.score_phi(&o.phi) - s).abs(), o.output_len, o.at))
            .collect();
        let k = self.calib_k.min(by_dist.len());
        by_dist.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        by_dist.truncate(k);
        let pairs: Vec<(f64, f64)> = by_dist
            .iter()
            .map(|&(_, len, at)| (len, self.decay.powi((self.seen - at) as i32)))
            .collect();
        let total: f64 = pairs.iter().map(|(_, w)| w).sum();
        if total <= 0.0 {
            return cold_start_prior();
        }
        LengthDist::from_weighted(&pairs).compress(self.max_support)
    }

    fn predict_rank(&mut self, req: &Request) -> f64 {
        self.score(req)
    }

    fn observe(&mut self, req: &Request, output_len: u32) {
        let phi = self.features(req);
        let len = output_len as f64;
        // pairwise logistic steps against sampled buffered completions
        for _ in 0..self.pairs_per_obs {
            if self.buffer.is_empty() {
                break;
            }
            let j = self.rng.below(self.buffer.len() as u64) as usize;
            let partner = &self.buffer[j];
            if partner.output_len == len {
                continue;
            }
            let target = if len > partner.output_len { 1.0 } else { 0.0 };
            let margin = self.score_phi(&phi) - self.score_phi(&partner.phi);
            let p = 1.0 / (1.0 + (-margin).exp());
            let age = (self.seen - partner.at) as i32;
            let step = self.lr * self.decay.powi(age) * (p - target);
            for ((w, a), b) in self.w.iter_mut().zip(&phi).zip(&partner.phi) {
                *w -= step * (a - b);
            }
        }
        self.seen += 1;
        if self.buffer.len() == self.cap {
            self.buffer.pop_front();
        }
        self.buffer.push_back(Obs { phi, output_len: len, at: self.seen });
    }

    fn stats(&self) -> PredictorStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, WorkloadConfig};
    use crate::util::stats::KendallTau;
    use crate::workload::WorkloadGen;

    fn make_requests(n: usize, seed: u64) -> Vec<Request> {
        let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
        cfg.n_requests = n;
        WorkloadGen::new(cfg, seed).generate().requests
    }

    fn tau_on(p: &mut RankingPredictor, reqs: &[Request]) -> f64 {
        let mut t = KendallTau::new(reqs.len());
        for r in reqs {
            t.push(p.predict_rank(r), r.true_output_len as f64);
        }
        t.tau()
    }

    #[test]
    fn cold_start_returns_prior_and_counts_cold() {
        let reqs = make_requests(1, 1);
        let mut p = RankingPredictor::new(64, 1);
        let d = p.predict(&reqs[0]);
        assert!(d.len() > 10);
        assert_eq!(p.stats.cold, 1);
        assert_eq!(p.stats.threshold_hits, 0);
    }

    #[test]
    fn learns_topic_length_ordering() {
        let reqs = make_requests(900, 2);
        let mut p = RankingPredictor::new(64, 2);
        let before = tau_on(&mut p, &reqs[700..]);
        for r in &reqs[..700] {
            p.observe(r, r.true_output_len);
        }
        let after = tau_on(&mut p, &reqs[700..]);
        assert!(
            after > 0.25 && after > before + 0.2,
            "training must improve rank quality: {before} -> {after}"
        );
    }

    #[test]
    fn decay_relearns_after_ordering_flip() {
        // train on true lengths, then keep observing the same stream with
        // the ordering inverted; stale pairs must decay out and the score
        // ordering must flip with them
        let reqs = make_requests(1600, 3);
        let mut p = RankingPredictor::new(64, 3);
        let probe = &reqs[1400..];
        let flip = |l: u32| 4096.0 - (l as f64).min(4000.0);
        for r in &reqs[..700] {
            p.observe(r, r.true_output_len);
        }
        let pre = tau_on(&mut p, probe);
        assert!(pre > 0.2, "pre-flip tau too weak: {pre}");
        for r in &reqs[700..1400] {
            p.observe(r, flip(r.true_output_len) as u32);
        }
        let post = tau_on(&mut p, probe);
        assert!(
            post < -0.2 * pre.min(1.0),
            "ordering must invert after the flip: pre {pre}, post {post}"
        );
    }

    #[test]
    fn calibrated_distribution_tracks_score_neighbourhood() {
        let reqs = make_requests(600, 4);
        let mut p = RankingPredictor::new(64, 4);
        for r in &reqs[..500] {
            p.observe(r, r.true_output_len);
        }
        // predictions must be finite, positive, and responsive: the mean
        // for high-score prompts should exceed the mean for low-score ones
        let mut scored: Vec<(f64, &Request)> =
            reqs[500..].iter().map(|r| (p.score(r), r)).collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let lo = p.predict(scored[0].1).mean();
        let hi = p.predict(scored.last().unwrap().1).mean();
        assert!(lo.is_finite() && hi.is_finite() && lo > 0.0 && hi > 0.0);
        assert!(
            hi > lo,
            "calibrated means must follow the score ordering: {lo} vs {hi}"
        );
    }

    #[test]
    fn buffer_is_bounded() {
        let reqs = make_requests(40, 5);
        let mut p = RankingPredictor::new(64, 5);
        p.cap = 16;
        for _ in 0..3 {
            for r in &reqs {
                p.observe(r, r.true_output_len);
            }
        }
        assert_eq!(p.len(), 16);
    }
}
