//! Output-length prediction (§3.1 of the paper).
//!
//! The paper's contribution is the **semantic-aware history-based
//! predictor** ([`HistoryPredictor`]): embed the prompt, retrieve recently
//! served requests whose prompt cosine-similarity exceeds a threshold
//! (default 0.8, FIFO 10k window), and use *their* observed output lengths
//! as the predicted distribution — training-free, model-agnostic,
//! distribution-valued.
//!
//! The ablation baselines of Fig. 9 live here too:
//! [`LengthHistoryPredictor`] (semantic-*unaware*: match on input length
//! instead of prompt content) and [`ProxyPredictor`] (the "fine-tuned
//! DistillBert" style model — emulated as a calibrated noisy observer of
//! the true distribution, since the baseline is characterized by *what it
//! predicts and how accurately*, not by its weights; accuracy is set to
//! match the paper's reported 34.1% bucket accuracy). [`OraclePredictor`]
//! supplies ground truth for upper-bound ablations.

use crate::core::Request;
use crate::distribution::LengthDist;
use crate::embedding::{Embedding, FlatIndex};
use crate::util::rng::Rng;

pub mod ranking;
pub use ranking::RankingPredictor;

/// Retrieval-outcome counters, split three ways so the report can tell a
/// genuine semantic match from a relaxed one (the pre-fix accounting
/// lumped fallback retrievals in with threshold hits):
/// - `threshold_hits`: enough above-threshold matches on their own;
/// - `fallback`: above-threshold matches kept but topped up with nearest
///   below-threshold neighbours to reach `min_matches`;
/// - `cold`: too little history even after the fill — prior returned.
///
/// Counters tick once per `predict()` call; the serving path calls
/// `predict` both directly and through the `predict_point`/`predict_rank`
/// defaults, so totals count predictions made, not requests admitted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredictorStats {
    pub threshold_hits: u64,
    pub fallback: u64,
    pub cold: u64,
}

/// A predictor maps an incoming request to an output-length distribution
/// and learns from completed requests.
pub trait Predictor: Send {
    fn name(&self) -> &'static str;

    /// Predict the output-length distribution for a request.
    fn predict(&mut self, req: &Request) -> LengthDist;

    /// Record a completed request's observed output length.
    fn observe(&mut self, req: &Request, output_len: u32);

    /// Point prediction (for SJF-style policies): distribution mean.
    fn predict_point(&mut self, req: &Request) -> f64 {
        self.predict(req).mean()
    }

    /// Ranking score: any value whose *ordering* across concurrent
    /// requests tracks the ordering of true output lengths (larger score
    /// = longer expected output). SJF-style policies sort by this, so a
    /// predictor good at relative ordering but poorly calibrated in
    /// absolute tokens (e.g. [`RankingPredictor`]) still schedules well.
    /// Defaults to the point prediction, which preserves the pre-seam
    /// behaviour for every analytic predictor.
    fn predict_rank(&mut self, req: &Request) -> f64 {
        self.predict_point(req)
    }

    /// Retrieval-outcome counters; all zero for predictors without a
    /// retrieval stage.
    fn stats(&self) -> PredictorStats {
        PredictorStats::default()
    }
}

/// Fallback prior used before any history exists: wide uniform.
/// (The paper augments the warm-up window with public-dataset requests; a
/// wide prior plays that role here and washes out after a few hundred
/// observations.)
fn cold_start_prior() -> LengthDist {
    LengthDist::uniform(8.0, 1024.0, 32)
}

// ---------------------------------------------------------------------------
// Semantic-aware history-based predictor (the paper's, §3.1)
// ---------------------------------------------------------------------------

/// History record payload: observed output length.
#[derive(Clone, Debug)]
struct HistoryRecord {
    output_len: u32,
}

/// The paper's semantic-aware history-based predictor.
pub struct HistoryPredictor {
    index: FlatIndex<HistoryRecord>,
    /// cosine-similarity threshold (paper default 0.8)
    pub threshold: f32,
    /// minimum matches before trusting the retrieved distribution
    pub min_matches: usize,
    /// cap on distribution support (compression)
    pub max_support: usize,
    /// retrieval-outcome counters (observability)
    pub stats: PredictorStats,
}

impl HistoryPredictor {
    pub fn new(embed_dim: usize, capacity: usize, threshold: f32) -> HistoryPredictor {
        HistoryPredictor {
            index: FlatIndex::new(embed_dim, capacity),
            threshold,
            min_matches: 5,
            max_support: 64,
            stats: PredictorStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Core retrieval: all matches above threshold, augmented with the
    /// nearest below-threshold neighbours when they number fewer than
    /// `min_matches` (paper: public-dataset fallback). The union keeps
    /// every genuine semantic match — the fallback only *fills*, it never
    /// replaces. Returns the retrieved lengths plus the count of true
    /// threshold hits for the accounting split.
    fn retrieve(&self, emb: &Embedding) -> (usize, Vec<u32>) {
        let (n_hits, recs) =
            self.index
                .search_threshold_filled(emb, self.threshold, self.min_matches);
        (n_hits, recs.into_iter().map(|(_, r)| r.output_len).collect())
    }
}

impl Predictor for HistoryPredictor {
    fn name(&self) -> &'static str {
        "history"
    }

    fn predict(&mut self, req: &Request) -> LengthDist {
        let (n_hits, lens) = self.retrieve(&req.embedding);
        if lens.len() < self.min_matches {
            self.stats.cold += 1;
            return cold_start_prior();
        }
        if n_hits >= self.min_matches {
            self.stats.threshold_hits += 1;
        } else {
            self.stats.fallback += 1;
        }
        let samples: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
        LengthDist::from_samples(&samples).compress(self.max_support)
    }

    fn observe(&mut self, req: &Request, output_len: u32) {
        self.index
            .insert(req.embedding.clone(), HistoryRecord { output_len });
    }

    fn stats(&self) -> PredictorStats {
        self.stats
    }
}

// ---------------------------------------------------------------------------
// Semantic-unaware history-based predictor (fig9 baseline 1)
// ---------------------------------------------------------------------------

/// History predictor that matches on *input length* instead of prompt
/// semantics: retrieves past requests whose input length is within a
/// relative band. Same windowing/filtering as [`HistoryPredictor`].
pub struct LengthHistoryPredictor {
    window: std::collections::VecDeque<(u32, u32)>, // (input_len, output_len)
    capacity: usize,
    /// relative half-width of the input-length band, e.g. 0.2 → ±20%
    pub band: f64,
    pub min_matches: usize,
    pub max_support: usize,
}

impl LengthHistoryPredictor {
    pub fn new(capacity: usize) -> LengthHistoryPredictor {
        LengthHistoryPredictor {
            window: Default::default(),
            capacity,
            band: 0.2,
            min_matches: 5,
            max_support: 64,
        }
    }
}

impl Predictor for LengthHistoryPredictor {
    fn name(&self) -> &'static str {
        "length-history"
    }

    fn predict(&mut self, req: &Request) -> LengthDist {
        let i = req.input_len as f64;
        let lo = i * (1.0 - self.band);
        let hi = i * (1.0 + self.band);
        let mut lens: Vec<f64> = self
            .window
            .iter()
            .filter(|(il, _)| (*il as f64) >= lo && (*il as f64) <= hi)
            .map(|(_, ol)| *ol as f64)
            .collect();
        if lens.len() < self.min_matches {
            // relax: nearest input lengths
            let mut all: Vec<(f64, f64)> = self
                .window
                .iter()
                .map(|(il, ol)| ((*il as f64 - i).abs(), *ol as f64))
                .collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            lens = all.into_iter().take(self.min_matches).map(|(_, o)| o).collect();
        }
        if lens.len() < self.min_matches {
            return cold_start_prior();
        }
        LengthDist::from_samples(&lens).compress(self.max_support)
    }

    fn observe(&mut self, req: &Request, output_len: u32) {
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back((req.input_len, output_len));
    }
}

// ---------------------------------------------------------------------------
// "LLM-based" proxy predictor (SSJF/fig9 baseline 2)
// ---------------------------------------------------------------------------

/// Emulates a fine-tuned proxy model (DistillBert in SSJF, OPT-125M in LTR).
///
/// Characterization (matching the paper's measurements, not the weights):
/// the proxy observes the request's true distribution but reports a
/// *blurred* version — its point estimate hits the true 100-token bucket
/// with probability `bucket_accuracy` (34.1% in the paper's Fig. 2(a));
/// otherwise it lands in a nearby bucket with geometric spread. The
/// distribution variant (Fig. 9's "semantic-aware LLM-based" predictor with
/// the argmax layer removed) returns a widened version of the true
/// distribution.
pub struct ProxyPredictor {
    rng: Rng,
    /// base probability of quantizing into the true bucket; the *effective*
    /// bucket accuracy (base + lucky regression errors) calibrates to the
    /// paper's measured 34.1% (Fig. 2(a))
    pub bucket_accuracy: f64,
    /// bucket width in tokens (paper: 100)
    pub bucket_tokens: f64,
    /// widening factor for distribution prediction (1 = exact)
    pub blur: f64,
    /// emulated per-prediction latency (seconds) — the paper measures
    /// ~3.6 ms; figure 12's overhead model consumes this
    pub latency_s: f64,
}

impl ProxyPredictor {
    pub fn new(seed: u64) -> ProxyPredictor {
        ProxyPredictor {
            rng: Rng::new(seed ^ 0x9c0f_fee5),
            bucket_accuracy: 0.30,
            bucket_tokens: 100.0,
            blur: 0.35,
            latency_s: 0.0036,
        }
    }

    /// The noisy point estimate (used by SSJF-style policies).
    ///
    /// Real prompt-level length regressors compress their predictions
    /// toward the corpus mean (that is precisely why they land in the
    /// right 100-token bucket only ~34% of the time, paper Fig. 2(a)):
    /// the estimate shrinks `truth` toward a global prior in log space
    /// before the lognormal regression error and bucket quantization.
    pub fn noisy_point(&mut self, true_output: u32) -> f64 {
        let truth = (true_output as f64).max(1.0);
        let prior = 180.0f64; // corpus-scale mean output length
        let shrunk = (truth.ln() * 0.5 + prior.ln() * 0.5).exp();
        if self.rng.f64() < self.bucket_accuracy {
            let b = (shrunk / self.bucket_tokens).floor();
            (b + 0.5) * self.bucket_tokens
        } else {
            let factor = self.rng.lognormal(0.0, self.blur * 1.6);
            (shrunk * factor).max(1.0)
        }
    }
}

impl Predictor for ProxyPredictor {
    fn name(&self) -> &'static str {
        "proxy"
    }

    fn predict(&mut self, req: &Request) -> LengthDist {
        let base = req
            .true_dist
            .clone()
            .unwrap_or_else(|| LengthDist::point(req.true_output_len.max(1) as f64));
        // widen: scale support spread around the (noisily shifted) mean
        let mean = base.mean();
        let shift = self.rng.lognormal(0.0, self.blur * 0.5);
        let target_mean = mean * shift;
        let widened = base.map_monotonic(|v| {
            let centered = v - mean;
            (target_mean + centered * (1.0 + self.blur)).max(0.1) + v * 1e-9
        });
        widened
    }

    fn observe(&mut self, _req: &Request, _output_len: u32) {}

    fn predict_point(&mut self, req: &Request) -> f64 {
        self.noisy_point(req.true_output_len)
    }
}

// ---------------------------------------------------------------------------
// Oracle
// ---------------------------------------------------------------------------

/// Ground-truth oracle: returns the request's true topic distribution (or a
/// point mass on the true output length when asked for a point).
pub struct OraclePredictor;

impl Predictor for OraclePredictor {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn predict(&mut self, req: &Request) -> LengthDist {
        req.true_dist
            .clone()
            .unwrap_or_else(|| LengthDist::point(req.true_output_len.max(1) as f64))
    }

    fn observe(&mut self, _req: &Request, _output_len: u32) {}

    fn predict_point(&mut self, req: &Request) -> f64 {
        req.true_output_len.max(1) as f64
    }
}

/// Build a predictor from config.
pub fn make_predictor(
    kind: crate::config::PredictorKind,
    embed_dim: usize,
    history_capacity: usize,
    threshold: f32,
    seed: u64,
) -> Box<dyn Predictor> {
    use crate::config::PredictorKind as K;
    match kind {
        K::History => Box::new(HistoryPredictor::new(embed_dim, history_capacity, threshold)),
        K::LengthHistory => Box::new(LengthHistoryPredictor::new(history_capacity)),
        K::Proxy => Box::new(ProxyPredictor::new(seed)),
        K::Oracle => Box::new(OraclePredictor),
        K::Ranking => Box::new(RankingPredictor::new(embed_dim, seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, WorkloadConfig};
    use crate::workload::WorkloadGen;

    fn make_requests(n: usize, seed: u64) -> Vec<Request> {
        let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
        cfg.n_requests = n;
        WorkloadGen::new(cfg, seed).generate().requests
    }

    #[test]
    fn history_cold_start_returns_prior() {
        let reqs = make_requests(1, 1);
        let mut p = HistoryPredictor::new(64, 100, 0.8);
        let d = p.predict(&reqs[0]);
        assert!(d.len() > 10); // wide prior
        assert_eq!(p.stats.cold, 1);
        assert_eq!(p.stats.threshold_hits, 0);
        assert_eq!(p.stats.fallback, 0);
    }

    #[test]
    fn history_fallback_counted_separately_from_threshold_hits() {
        let reqs = make_requests(40, 11);
        let mut p = HistoryPredictor::new(64, 1000, 0.8);
        // observe a handful of requests, then predict for a prompt from a
        // different topic: retrieval must fill via nearest neighbours and
        // the accounting must say "fallback", not "hit"
        for r in &reqs[..20] {
            p.observe(r, r.true_output_len);
        }
        let mut far = reqs[30].clone();
        far.embedding = Embedding::normalize(vec![-1.0; 64]);
        let d = p.predict(&far);
        assert!(d.mean() > 0.0);
        assert_eq!(p.stats.cold, 0);
        assert_eq!(p.stats.threshold_hits, 0);
        assert_eq!(p.stats.fallback, 1, "relaxed retrieval miscounted: {:?}", p.stats);
    }

    #[test]
    fn history_learns_topic_distributions() {
        let reqs = make_requests(800, 2);
        let mut p = HistoryPredictor::new(64, 10_000, 0.8);
        // warm up on the first 600
        for r in &reqs[..600] {
            p.observe(r, r.true_output_len);
        }
        // predictions for the rest should be closer (W1) to the true topic
        // distribution than the marginal over all requests
        let all_lens: Vec<f64> =
            reqs[..600].iter().map(|r| r.true_output_len as f64).collect();
        let marginal = LengthDist::from_samples(&all_lens);
        let mut better = 0;
        let mut total = 0;
        for r in &reqs[600..] {
            let pred = p.predict(r);
            let truth = r.true_dist.as_ref().unwrap();
            if pred.w1_distance(truth) < marginal.w1_distance(truth) {
                better += 1;
            }
            total += 1;
        }
        assert!(
            better as f64 / total as f64 > 0.7,
            "only {better}/{total} better than marginal"
        );
    }

    #[test]
    fn history_fifo_eviction_caps_memory() {
        let reqs = make_requests(50, 3);
        let mut p = HistoryPredictor::new(64, 16, 0.8);
        for r in &reqs {
            p.observe(r, r.true_output_len);
        }
        assert_eq!(p.len(), 16);
    }

    #[test]
    fn length_history_groups_by_input_len() {
        let mut p = LengthHistoryPredictor::new(1000);
        let reqs = make_requests(400, 4);
        for r in &reqs[..300] {
            p.observe(r, r.true_output_len);
        }
        let d = p.predict(&reqs[350]);
        assert!(d.mean() > 0.0);
        // must only use neighbours in input length when abundant
        let i = reqs[350].input_len as f64;
        let within: Vec<f64> = reqs[..300]
            .iter()
            .filter(|r| (r.input_len as f64) >= i * 0.8 && (r.input_len as f64) <= i * 1.2)
            .map(|r| r.true_output_len as f64)
            .collect();
        if within.len() >= 5 {
            let expect = LengthDist::from_samples(&within);
            assert!(d.w1_distance(&expect) < 1.0 + expect.mean() * 0.35);
        }
    }

    #[test]
    fn proxy_bucket_accuracy_calibrated() {
        // system-level calibration: predicted-vs-*realized* bucket accuracy
        // over a real workload must land near the paper's 34.1% (fig2a)
        let reqs = make_requests(4000, 5);
        let mut p = ProxyPredictor::new(5);
        let mut hits = 0;
        for r in &reqs {
            let expected = r.true_dist.as_ref().unwrap().mean();
            let est = p.noisy_point(expected.round() as u32);
            if (est / 100.0).floor() == (r.true_output_len / 100) as f64 {
                hits += 1;
            }
        }
        let acc = hits as f64 / reqs.len() as f64;
        assert!(
            (acc - 0.341).abs() < 0.12,
            "bucket accuracy {acc} not ≈ 0.341"
        );
    }

    #[test]
    fn proxy_distribution_wider_than_truth() {
        let reqs = make_requests(10, 6);
        let mut p = ProxyPredictor::new(6);
        let r = &reqs[0];
        let pred = p.predict(r);
        let truth = r.true_dist.as_ref().unwrap();
        assert!(pred.variance() > truth.variance() * 0.9);
    }

    #[test]
    fn oracle_returns_truth() {
        let reqs = make_requests(5, 7);
        let mut p = OraclePredictor;
        let r = &reqs[0];
        assert_eq!(p.predict(r), r.true_dist.clone().unwrap());
        assert_eq!(p.predict_point(r), r.true_output_len as f64);
    }

    #[test]
    fn make_predictor_constructs_all() {
        use crate::config::PredictorKind as K;
        for k in [K::History, K::LengthHistory, K::Proxy, K::Oracle, K::Ranking] {
            let p = make_predictor(k, 64, 100, 0.8, 1);
            assert!(!p.name().is_empty());
        }
    }
}
