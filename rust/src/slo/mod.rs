//! Per-request SLO classes: latency tiers with class-aware admission,
//! scheduling, and routing.
//!
//! Production "overall efficiency" is efficiency *weighted by what each
//! request is worth*: an interactive chat turn that misses its
//! time-to-first-token budget is worth nothing to the user even if it
//! eventually completes, while a batch summarization job is indifferent to
//! minutes of queueing. This module makes that explicit with a small set of
//! latency tiers ([`SloClass`]): each tier carries a TTFT target, a
//! completion-deadline (TTLT) target, a goodput *weight*, and an admission
//! headroom rule ([`SloClassSpec`]). The tier is stamped on every request by
//! the workload generator (configurable mix via
//! [`WorkloadConfig::slo_mix`](crate::config::WorkloadConfig)) and threads
//! through the whole stack:
//!
//! * **Scheduling** — [`ClassAwarePolicy`] wraps any base
//!   [`Policy`](crate::sched::Policy) (SageSched's Gittins refresh, the
//!   baselines, the oracle) with a tier ladder: requests whose
//!   *deadline slack* has run out are served first (most overdue first),
//!   then Interactive, Standard, and Batch bands, each ordered by the inner
//!   policy. Slack is judged against a configurable **quantile** of the
//!   predicted *remaining* service-cost distribution, not its mean — the
//!   robust-to-prediction-error stance of *Adaptively Robust LLM Inference
//!   Optimization under Prediction Uncertainty*: a request whose cost tail
//!   is heavy goes urgent sooner than its mean alone would suggest. The
//!   urgent band doubles as the starvation guard: a Batch request ages into
//!   it as its (loose but finite) deadline approaches, so sustained
//!   Interactive load cannot starve Batch forever.
//! * **Admission** — each tier admits only while the live set is below its
//!   `admit_fraction` of the queue bound, so under overload Batch is
//!   refused while headroom is still reserved for Interactive
//!   (see [`Coordinator::submit`](crate::serve::Coordinator::submit)).
//! * **Routing** — the cluster's class-aware router wrapper
//!   ([`crate::cluster::ClassAwareRouter`]) sends tight tiers to replicas
//!   with KV headroom, picked on a high quantile of the outstanding-cost
//!   distribution; loose tiers keep the configured base router.
//! * **Autoscaling** — the cluster reports a *weight*-scaled forecast
//!   backlog so [`crate::autoscale::UncertaintyAware`] provisions for the
//!   SLO-weighted work distribution rather than the raw one.
//! * **Metrics** — [`crate::metrics::RunReport`] / `ClusterReport` carry
//!   per-class latency percentiles, SLO-attainment rates, and SLO-weighted
//!   goodput (including per replica-second), surfaced in CLI summaries,
//!   JSON, and the `fig13c` bench (class-blind vs class-aware serving under
//!   MMPP bursts plus a replica failure).
//!
//! With [`SloConfig::class_aware`] off (the default) every component
//! behaves exactly as before: classes are still stamped and reported, but
//! no decision reads them.

use crate::sched::{Policy, ReqView};
use crate::util::rng::Rng;

/// A request's latency tier. Order matters: earlier tiers are tighter and
/// are served/admitted preferentially by the class-aware components.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SloClass {
    /// Chat-style traffic: tight TTFT and completion targets, top weight.
    Interactive,
    /// Default API traffic: moderate targets.
    Standard,
    /// Offline/bulk traffic: loose (but finite) targets, lowest weight.
    Batch,
}

impl SloClass {
    pub const ALL: [SloClass; 3] =
        [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Dense index (0 = Interactive, 1 = Standard, 2 = Batch) for per-class
    /// counter arrays.
    pub fn index(&self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn from_name(s: &str) -> Option<SloClass> {
        SloClass::ALL.iter().copied().find(|c| c.name() == s)
    }
}

/// Targets, weight, and admission rule of one latency tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloClassSpec {
    pub class: SloClass,
    /// Time-to-first-token target (seconds).
    pub ttft_target: f64,
    /// Completion-deadline target measured from arrival (seconds). Also the
    /// deadline the class-aware scheduler computes slack against.
    pub ttlt_target: f64,
    /// Goodput weight of one attained request of this class.
    pub weight: f64,
    /// Fraction of the admission window (`max_queue`) this class may fill:
    /// with a bound of Q, a class-c request is admitted only while fewer
    /// than `ceil(Q * admit_fraction)` requests are live. 1.0 = full
    /// window; lower fractions make the class yield headroom to tighter
    /// tiers under overload.
    pub admit_fraction: f64,
}

impl SloClassSpec {
    /// Whether a completed request with these latencies met the tier's SLO.
    pub fn attained(&self, ttft: f64, ttlt: f64) -> bool {
        ttft <= self.ttft_target && ttlt <= self.ttlt_target
    }
}

/// The full tier table (one spec per [`SloClass`], in `SloClass::ALL`
/// order).
#[derive(Clone, Debug, PartialEq)]
pub struct SloSpecs {
    specs: [SloClassSpec; 3],
}

impl Default for SloSpecs {
    fn default() -> Self {
        SloSpecs {
            specs: [
                SloClassSpec {
                    class: SloClass::Interactive,
                    ttft_target: 2.0,
                    ttlt_target: 20.0,
                    weight: 4.0,
                    admit_fraction: 1.0,
                },
                SloClassSpec {
                    class: SloClass::Standard,
                    ttft_target: 8.0,
                    ttlt_target: 60.0,
                    weight: 1.0,
                    admit_fraction: 0.9,
                },
                SloClassSpec {
                    class: SloClass::Batch,
                    ttft_target: 60.0,
                    ttlt_target: 240.0,
                    weight: 0.25,
                    admit_fraction: 0.7,
                },
            ],
        }
    }
}

impl SloSpecs {
    pub fn spec(&self, class: SloClass) -> &SloClassSpec {
        &self.specs[class.index()]
    }

    pub fn spec_mut(&mut self, class: SloClass) -> &mut SloClassSpec {
        &mut self.specs[class.index()]
    }

    pub fn iter(&self) -> impl Iterator<Item = &SloClassSpec> {
        self.specs.iter()
    }

    /// TTFT-tightness goodput weight of one class: the base weight scaled
    /// by how much tighter its first-token target is than [`Standard`]'s
    /// (`w · ttft_ref / ttft_target`). Under disaggregated serving this is
    /// what the *prefill* pool provisions against — TTFT is paid entirely
    /// on the prefill side, so a backlog of tight-TTFT work buys the
    /// prefill pool proportionally more headroom.
    ///
    /// [`Standard`]: SloClass::Standard
    pub fn prefill_weight(&self, class: SloClass) -> f64 {
        let s = self.spec(class);
        let ttft_ref = self.spec(SloClass::Standard).ttft_target;
        s.weight * (ttft_ref / s.ttft_target)
    }

    /// Completion-tightness (TPOT-side) goodput weight of one class:
    /// the base weight scaled by how much tighter its completion deadline
    /// is than [`Standard`]'s (`w · ttlt_ref / ttlt_target`). Under
    /// disaggregated serving this is what the *decode* pool provisions
    /// against — token-by-token progress toward the deadline happens
    /// entirely on the decode side.
    ///
    /// [`Standard`]: SloClass::Standard
    pub fn decode_weight(&self, class: SloClass) -> f64 {
        let s = self.spec(class);
        let ttlt_ref = self.spec(SloClass::Standard).ttlt_target;
        s.weight * (ttlt_ref / s.ttlt_target)
    }

    pub fn validate(&self) -> Result<(), String> {
        for s in &self.specs {
            let bad_num = s.ttft_target.is_nan()
                || s.ttlt_target.is_nan()
                || s.weight.is_nan()
                || s.admit_fraction.is_nan();
            if bad_num
                || s.ttft_target <= 0.0
                || s.ttlt_target < s.ttft_target
                || s.weight <= 0.0
                || !(0.0 < s.admit_fraction && s.admit_fraction <= 1.0)
            {
                return Err(format!(
                    "slo class {}: need ttft > 0, ttlt >= ttft, weight > 0, \
                     admit_fraction in (0,1]",
                    s.class.name()
                ));
            }
        }
        Ok(())
    }
}

/// SLO subsystem configuration (part of
/// [`ExperimentConfig`](crate::config::ExperimentConfig)).
#[derive(Clone, Debug, PartialEq)]
pub struct SloConfig {
    /// Master switch: when false (the default) classes are stamped and
    /// reported but no scheduling/admission/routing/autoscaling decision
    /// reads them — bit-identical to pre-SLO behavior.
    pub class_aware: bool,
    /// Per-class targets, weights, and admission rules.
    pub specs: SloSpecs,
    /// Quantile of the predicted *remaining* cost distribution used for
    /// deadline-slack estimation (robust tiering: 0.5 = mean-like, higher
    /// values go urgent sooner on heavy-tailed work).
    pub sched_quantile: f64,
    /// Seconds of service per cost-model unit, converting the remaining
    /// cost quantile into a time estimate for slack. Only the urgency
    /// *threshold* depends on it, so rough calibration suffices; the
    /// default matches the resource-bound cost of a typical request
    /// (~1e5 units) taking a few seconds on the calibrated sim profiles.
    pub cost_time_scale: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            class_aware: false,
            specs: SloSpecs::default(),
            sched_quantile: 0.9,
            cost_time_scale: 3.0e-5,
        }
    }
}

impl SloConfig {
    /// Parameter bounds shared by every config surface (JSON and CLI).
    pub fn validate(&self) -> Result<(), String> {
        self.specs.validate()?;
        if !(0.0 < self.sched_quantile && self.sched_quantile < 1.0) {
            return Err("slo: sched_quantile must be in (0,1)".to_string());
        }
        if self.cost_time_scale.is_nan() || self.cost_time_scale < 0.0 {
            return Err("slo: cost_time_scale must be >= 0".to_string());
        }
        Ok(())
    }
}

/// Semantic validation every (class, weight) mix must pass, whatever
/// surface it arrived through (CLI grammar, JSON config): weights finite
/// and non-negative, at least one positive. One function so the rules
/// cannot drift between entry points.
pub fn validate_mix(mix: &[(SloClass, f64)]) -> Result<(), String> {
    for &(class, w) in mix {
        if w.is_nan() || w < 0.0 {
            return Err(format!(
                "slo mix: weight for {} must be >= 0",
                class.name()
            ));
        }
    }
    if mix.iter().all(|&(_, w)| w <= 0.0) {
        return Err("slo mix: at least one class weight must be positive".to_string());
    }
    Ok(())
}

/// Parse a `class:weight` mix list — the CLI's `--slo-mix` grammar, e.g.
/// `interactive:0.3,standard:0.5,batch:0.2`. Shared by the `sagesched`
/// binary and the examples so the grammar cannot diverge; semantics are
/// checked by [`validate_mix`].
pub fn parse_mix(s: &str) -> Result<Vec<(SloClass, f64)>, String> {
    let mut mix = Vec::new();
    for item in s.split(',') {
        let item = item.trim();
        let (name, w) = item
            .split_once(':')
            .ok_or_else(|| format!("slo mix {item:?}: expected class:weight"))?;
        let class = SloClass::from_name(name.trim())
            .ok_or_else(|| format!("slo mix {item:?}: unknown class {name:?}"))?;
        let weight: f64 = w
            .trim()
            .parse()
            .map_err(|_| format!("slo mix {item:?}: bad weight"))?;
        mix.push((class, weight));
    }
    validate_mix(&mix)?;
    Ok(mix)
}

/// Deterministic class assigner: its own PCG stream, derived from the
/// workload seed but independent of the arrival/sampling streams, so
/// stamping classes never perturbs an existing seeded trace.
pub struct ClassAssigner {
    rng: Rng,
    weights: Vec<f64>,
    classes: Vec<SloClass>,
}

impl ClassAssigner {
    pub fn new(mix: &[(SloClass, f64)], seed: u64) -> ClassAssigner {
        let (classes, weights): (Vec<SloClass>, Vec<f64>) = mix.iter().copied().unzip();
        ClassAssigner { rng: Rng::new(seed ^ 0x510_c1a5), weights, classes }
    }

    /// Draw the next request's class (one RNG draw per request).
    pub fn next_class(&mut self) -> SloClass {
        if self.classes.is_empty() {
            return SloClass::Standard;
        }
        let i = self.rng.categorical(&self.weights);
        self.classes[i]
    }
}

// ---------------------------------------------------------------------------
// Class-aware scheduling wrapper
// ---------------------------------------------------------------------------

/// Band width of the tier ladder; bands must not overlap after the
/// coordinator's preemption hysteresis shaves a relative margin off running
/// requests, hence the gap between band centers exceeds the squash range.
const BAND: f64 = 4.0;
/// Center of the urgent band, far below every class band.
const URGENT_BASE: f64 = -12.0;

/// Order-preserving squash of an unbounded priority into (-1, 1), so inner
/// priorities of any scale fit inside one ladder band.
fn squash(x: f64) -> f64 {
    x / (1.0 + x.abs())
}

/// Class-aware wrapper around any base [`Policy`]: a deadline/tier ladder
/// on top of the inner ordering.
///
/// Priority bands (smaller = served first):
///
/// 1. **Urgent** — requests whose deadline slack is exhausted. Slack is
///    `arrival + ttlt_target − now − t̂`, where `t̂` converts the
///    [`SloConfig::sched_quantile`] of the predicted *remaining* cost
///    distribution to seconds via [`SloConfig::cost_time_scale`]. Ordered
///    most-overdue first; this is also the aging path that keeps Batch from
///    starving (its loose deadline eventually runs out too).
/// 2. **Interactive**, 3. **Standard**, 4. **Batch** — each band ordered by
///    the (squashed) inner policy priority.
pub struct ClassAwarePolicy {
    inner: Box<dyn Policy>,
    cfg: SloConfig,
}

impl ClassAwarePolicy {
    pub fn new(inner: Box<dyn Policy>, cfg: SloConfig) -> ClassAwarePolicy {
        ClassAwarePolicy { inner, cfg }
    }

    /// Seconds of deadline slack left for `v`, robust to cost-tail error:
    /// negative once the request must run *now* to have any chance of
    /// meeting its completion target.
    pub fn slack(&self, v: &ReqView) -> f64 {
        let spec = self.cfg.specs.spec(v.req.slo);
        let remaining_cost = v
            .cost_dist
            .conditional_excess(v.consumed_cost)
            .map(|d| d.quantile(self.cfg.sched_quantile))
            .unwrap_or(0.0);
        let est_service = remaining_cost * self.cfg.cost_time_scale;
        (v.req.arrival + spec.ttlt_target) - v.now - est_service
    }
}

impl Policy for ClassAwarePolicy {
    fn kind(&self) -> crate::config::PolicyKind {
        self.inner.kind()
    }

    fn priority(&mut self, v: &ReqView) -> f64 {
        let inner_p = self.inner.priority(v);
        let slack = self.slack(v);
        if slack <= 0.0 {
            // most overdue first; inner ordering as an epsilon tie-break
            URGENT_BASE + squash(slack) + 1e-6 * squash(inner_p)
        } else {
            let rank = v.req.slo.index() as f64;
            rank * BAND + squash(inner_p)
        }
    }

    fn preemptive(&self) -> bool {
        self.inner.preemptive()
    }

    fn forget(&mut self, id: crate::core::RequestId) {
        self.inner.forget(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;
    use crate::core::{Phase, Request};
    use crate::cost::{CostModel, ResourceBoundCost};
    use crate::distribution::LengthDist;
    use crate::embedding::Embedding;
    use crate::sched::FcfsPolicy;

    fn req(id: u64, arrival: f64, slo: SloClass) -> Request {
        Request {
            id,
            prompt: String::new(),
            input_len: 10,
            true_output_len: 50,
            arrival,
            dataset: DatasetKind::ShareGpt,
            topic: 0,
            embedding: Embedding::normalize(vec![1.0]),
            true_dist: Some(LengthDist::point(50.0)),
            slo,
            prefix_key: Vec::new(),
        }
    }

    fn view<'a>(
        r: &'a Request,
        now: f64,
        pred: &'a LengthDist,
        cost: &'a LengthDist,
    ) -> ReqView<'a> {
        ReqView {
            req: r,
            phase: Phase::Queued,
            generated: 0,
            pred_lengths: pred,
            cost_dist: cost,
            point_pred: pred.mean(),
            rank_pred: pred.mean(),
            consumed_cost: 0.0,
            now,
        }
    }

    #[test]
    fn class_names_roundtrip() {
        for c in SloClass::ALL {
            assert_eq!(SloClass::from_name(c.name()), Some(c));
        }
        assert_eq!(SloClass::from_name("nope"), None);
        assert_eq!(SloClass::Interactive.index(), 0);
        assert_eq!(SloClass::Batch.index(), 2);
    }

    #[test]
    fn default_specs_validate_and_order_tiers() {
        let cfg = SloConfig::default();
        assert!(cfg.validate().is_ok());
        let s = &cfg.specs;
        assert!(
            s.spec(SloClass::Interactive).ttlt_target
                < s.spec(SloClass::Standard).ttlt_target
        );
        assert!(
            s.spec(SloClass::Standard).ttlt_target < s.spec(SloClass::Batch).ttlt_target
        );
        assert!(
            s.spec(SloClass::Interactive).weight > s.spec(SloClass::Batch).weight
        );
        assert!(
            s.spec(SloClass::Interactive).admit_fraction
                > s.spec(SloClass::Batch).admit_fraction
        );
    }

    #[test]
    fn spec_validation_rejects_garbage() {
        let mut cfg = SloConfig::default();
        cfg.specs.spec_mut(SloClass::Batch).weight = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = SloConfig::default();
        cfg.specs.spec_mut(SloClass::Standard).admit_fraction = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = SloConfig::default();
        cfg.specs.spec_mut(SloClass::Interactive).ttlt_target = 0.5; // < ttft
        assert!(cfg.validate().is_err());
        let mut cfg = SloConfig::default();
        cfg.sched_quantile = 1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn mix_grammar_roundtrips_and_rejects_garbage() {
        let mix = parse_mix("interactive:0.3, standard:0.5, batch:0.2").unwrap();
        assert_eq!(
            mix,
            vec![
                (SloClass::Interactive, 0.3),
                (SloClass::Standard, 0.5),
                (SloClass::Batch, 0.2),
            ]
        );
        for bad in [
            "interactive",
            "zzz:0.5",
            "interactive:x",
            "interactive:-1",
            "interactive:0,batch:0",
        ] {
            assert!(parse_mix(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn assigner_is_seeded_and_respects_degenerate_mix() {
        let mix = vec![(SloClass::Interactive, 1.0)];
        let mut a = ClassAssigner::new(&mix, 7);
        for _ in 0..50 {
            assert_eq!(a.next_class(), SloClass::Interactive);
        }
        let mix =
            vec![(SloClass::Interactive, 1.0), (SloClass::Batch, 1.0)];
        let seq = |seed| -> Vec<SloClass> {
            let mut a = ClassAssigner::new(&mix, seed);
            (0..100).map(|_| a.next_class()).collect()
        };
        assert_eq!(seq(42), seq(42), "same seed must stamp identically");
        assert_ne!(seq(42), seq(43), "different seeds must differ");
    }

    #[test]
    fn squash_is_monotone_and_bounded() {
        let mut prev = -1.0;
        for x in [-1e12, -100.0, -1.0, 0.0, 0.5, 3.0, 1e9] {
            let s = squash(x);
            assert!(s > prev, "squash not monotone at {x}");
            assert!((-1.0..1.0).contains(&s));
            prev = s;
        }
    }

    #[test]
    fn tiers_order_fresh_requests_by_class() {
        let mut p = ClassAwarePolicy::new(Box::new(FcfsPolicy), SloConfig::default());
        let d = LengthDist::point(50.0);
        let c = ResourceBoundCost.cost_dist(10, &d);
        let (ri, rs, rb) = (
            req(1, 0.0, SloClass::Interactive),
            req(2, 0.0, SloClass::Standard),
            req(3, 0.0, SloClass::Batch),
        );
        let pi = p.priority(&view(&ri, 0.0, &d, &c));
        let ps = p.priority(&view(&rs, 0.0, &d, &c));
        let pb = p.priority(&view(&rb, 0.0, &d, &c));
        assert!(pi < ps && ps < pb, "tier ladder broken: {pi} {ps} {pb}");
    }

    #[test]
    fn overdue_batch_outranks_fresh_interactive() {
        // the starvation guard: a Batch request at its deadline ages into
        // the urgent band, ahead of brand-new Interactive traffic
        let cfg = SloConfig::default();
        let batch_deadline = cfg.specs.spec(SloClass::Batch).ttlt_target;
        let mut p = ClassAwarePolicy::new(Box::new(FcfsPolicy), cfg);
        let d = LengthDist::point(50.0);
        let c = ResourceBoundCost.cost_dist(10, &d);
        let now = batch_deadline + 1.0;
        let old_batch = req(1, 0.0, SloClass::Batch);
        let fresh_int = req(2, now, SloClass::Interactive);
        let pb = p.priority(&view(&old_batch, now, &d, &c));
        let pi = p.priority(&view(&fresh_int, now, &d, &c));
        assert!(
            pb < pi,
            "overdue batch ({pb}) must outrank fresh interactive ({pi})"
        );
    }

    #[test]
    fn heavier_cost_tail_goes_urgent_sooner() {
        // equal means, different tails: the quantile-based slack must mark
        // the heavy-tailed request urgent at a time when the narrow one
        // still has slack
        let mut cfg = SloConfig::default();
        cfg.sched_quantile = 0.9;
        cfg.cost_time_scale = 1.0e-3;
        let p = ClassAwarePolicy::new(Box::new(FcfsPolicy), cfg.clone());
        let narrow = LengthDist::point(10_000.0);
        let wide = LengthDist::from_weighted(&[(1_000.0, 0.5), (19_000.0, 0.5)]);
        assert!((narrow.mean() - wide.mean()).abs() < 1e-6);
        let r = req(1, 0.0, SloClass::Interactive);
        // at this instant: slack = 20 - now - q90_cost * 1e-3
        // narrow: q90 = 10k -> est 10 s; wide: q90 = 19k -> est 19 s
        let now = 5.0;
        let s_narrow = p.slack(&view(&r, now, &narrow, &narrow));
        let s_wide = p.slack(&view(&r, now, &wide, &wide));
        assert!(s_narrow > 0.0, "narrow tail must still have slack: {s_narrow}");
        assert!(s_wide < 0.0, "heavy tail must be urgent already: {s_wide}");
    }

    #[test]
    fn wrapper_forwards_inner_semantics() {
        let p = ClassAwarePolicy::new(Box::new(FcfsPolicy), SloConfig::default());
        assert!(!p.preemptive(), "must forward inner preemptive()");
        assert_eq!(p.kind(), crate::config::PolicyKind::Fcfs);
    }
}
