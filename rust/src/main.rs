//! `sagesched` CLI: run experiments, serve the real model, inspect configs.
//!
//! ```text
//! sagesched run   [--policy sagesched] [--rps 8] [--n 600] [--engine a40-llama8b]
//!                 [--predictor history] [--cost resource-bound] [--seed 0]
//!                 [--config file.json] [--json]
//! sagesched sweep [--rps-list 4,6,8,10] ...      compare all paper baselines
//! sagesched serve [--addr 127.0.0.1:8080] [--artifacts artifacts]
//! sagesched smoke [--artifacts artifacts]        load + run the HLO artifacts once
//! sagesched cluster [--replicas 4] [--routers all] [--speeds 1.0,0.5]
//!                   event-driven multi-replica sim, one row per router
//! sagesched cluster --overhead [--nodes 1,4,16,64]   fig12 overhead sweep
//! ```

use anyhow::{bail, Context, Result};

use sagesched::cluster::{run_router_experiment, ClusterSim};
use sagesched::config::{
    ArrivalKind, AutoscaleKind, CostModelKind, DomainFailureEvent, EngineProfile,
    ExperimentConfig, FailureDomain, FailureEvent, PolicyKind, PoolRole,
    PredictorKind, RouterKind, ScaleStep,
};
use sagesched::metrics::{ClusterReport, DispatchScope};
use sagesched::engine::RealEngine;
use sagesched::metrics::RunReport;
use sagesched::runtime::Runtime;
use sagesched::serve::{run_experiment, Coordinator};
use sagesched::util::cli::Args;
use sagesched::util::json::Json;

fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        ExperimentConfig::from_json(&j).map_err(|e| anyhow::anyhow!(e))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::from_name(p).context("unknown --policy")?;
    }
    if let Some(p) = args.get("predictor") {
        cfg.predictor = PredictorKind::from_name(p).context("unknown --predictor")?;
    }
    if let Some(c) = args.get("cost") {
        cfg.cost_model = CostModelKind::from_name(c).context("unknown --cost")?;
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = EngineProfile::by_name(e).context("unknown --engine")?;
    }
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.workload.rps = args.f64_or("rps", cfg.workload.rps);
    cfg.workload.n_requests = args.usize_or("n", cfg.workload.n_requests);
    if let Some(a) = args.get("arrival") {
        cfg.workload.arrival.kind =
            ArrivalKind::from_name(a).context("unknown --arrival")?;
    }
    let arr = &mut cfg.workload.arrival;
    arr.burst_factor = args.f64_or("burst-factor", arr.burst_factor);
    arr.burst_on_mean = args.f64_or("burst-on", arr.burst_on_mean);
    arr.burst_off_mean = args.f64_or("burst-off", arr.burst_off_mean);
    arr.diurnal_period = args.f64_or("diurnal-period", arr.diurnal_period);
    arr.diurnal_amplitude = args.f64_or("diurnal-amplitude", arr.diurnal_amplitude);
    if let Err(e) = arr.validate() {
        bail!("{e} (--burst-factor/--burst-on/--burst-off/--diurnal-period/--diurnal-amplitude)");
    }
    if let Some(d) = args.get("drift") {
        // bare `--drift` (the parser stores "true") means "shift the mix at
        // the halfway point"; an explicit value places the shift elsewhere
        cfg.workload.drift.at_fraction = if d == "true" {
            0.5
        } else {
            d.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--drift: bad fraction {d:?}"))?
        };
        if let Err(e) = cfg.workload.drift.validate() {
            bail!("{e} (--drift)");
        }
    }
    if let Some(f) = args.get("fail") {
        cfg.cluster.failures =
            FailureEvent::parse_list(f).map_err(|e| anyhow::anyhow!("--fail: {e}"))?;
    }
    if let Some(d) = args.get("domains") {
        cfg.cluster.failure_domains = FailureDomain::parse_groups(d)
            .map_err(|e| anyhow::anyhow!("--domains: {e}"))?;
    }
    if let Some(f) = args.get("fail-domain") {
        cfg.cluster.domain_failures = DomainFailureEvent::parse_list(f)
            .map_err(|e| anyhow::anyhow!("--fail-domain: {e}"))?;
        if cfg.cluster.failure_domains.is_empty() {
            bail!("--fail-domain requires --domains (or failure_domains in the config)");
        }
    }
    cfg.similarity_threshold =
        args.f64_or("threshold", cfg.similarity_threshold as f64) as f32;
    cfg.bucket_tokens = args.u64_or("bucket", cfg.bucket_tokens as u64) as u32;
    cfg.noise_mix = args.f64_or("noise", cfg.noise_mix);
    cfg.cluster.replicas = args.usize_or("replicas", cfg.cluster.replicas);
    if let Some(r) = args.get("router") {
        cfg.cluster.router = RouterKind::from_name(r).context("unknown --router")?;
    }
    cfg.cluster.router_quantile = args.f64_or("router-quantile", cfg.cluster.router_quantile);
    if !(0.0 < cfg.cluster.router_quantile && cfg.cluster.router_quantile < 1.0) {
        bail!("--router-quantile must be in (0,1)");
    }
    cfg.cluster.steal_transfer_per_token =
        args.f64_or("steal-transfer", cfg.cluster.steal_transfer_per_token);
    if cfg.cluster.steal_transfer_per_token < 0.0 {
        bail!("--steal-transfer must be >= 0");
    }
    cfg.cluster.migration_kv_per_token =
        args.f64_or("migrate-kv", cfg.cluster.migration_kv_per_token);
    cfg.cluster.migration_quantile =
        args.f64_or("migrate-quantile", cfg.cluster.migration_quantile);
    // disaggregated prefill/decode serving: --disagg alone splits the
    // roster alternating prefill/decode; --pool names the cycle explicitly
    if args.has("disagg") && cfg.cluster.pools.is_empty() {
        cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
    }
    if let Some(p) = args.get("pool") {
        cfg.cluster.pools = p
            .split(',')
            .map(|s| {
                PoolRole::from_name(s.trim())
                    .ok_or_else(|| anyhow::anyhow!("--pool: unknown pool role {s:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    cfg.cluster.transfer_bandwidth =
        args.f64_or("transfer-bandwidth", cfg.cluster.transfer_bandwidth);
    cfg.cluster.transfer_links =
        args.usize_or("transfer-links", cfg.cluster.transfer_links);
    if let Some(r) = args.get("decode-router") {
        cfg.cluster.decode_router =
            Some(RouterKind::from_name(r).context("unknown --decode-router")?);
    }
    cfg.cluster.shortlist_k = args.usize_or("shortlist-k", cfg.cluster.shortlist_k);
    if let Err(e) = cfg.cluster.validate() {
        let hint = if e.contains("shortlist") {
            "--shortlist-k"
        } else if e.contains("transfer") || e.contains("pool") {
            "--disagg/--pool/--transfer-bandwidth/--transfer-links"
        } else {
            "--migrate-kv/--migrate-quantile"
        };
        bail!("{e} ({hint})");
    }
    if let Some(a) = args.get("autoscale") {
        cfg.cluster.autoscale.kind =
            AutoscaleKind::from_name(a).context("unknown --autoscale")?;
    }
    if let Some(s) = args.get("scale-steps") {
        cfg.cluster.autoscale.steps =
            ScaleStep::parse_list(s).map_err(|e| anyhow::anyhow!("--scale-steps: {e}"))?;
    }
    {
        let asc = &mut cfg.cluster.autoscale;
        asc.min_replicas = args.usize_or("scale-min", asc.min_replicas);
        asc.max_replicas = args.usize_or("scale-max", asc.max_replicas);
        asc.provision_delay = args.f64_or("scale-delay", asc.provision_delay);
        asc.cooldown = args.f64_or("scale-cooldown", asc.cooldown);
        asc.interval = args.f64_or("scale-interval", asc.interval);
        asc.high_watermark = args.f64_or("scale-high", asc.high_watermark);
        asc.low_watermark = args.f64_or("scale-low", asc.low_watermark);
        asc.kv_high_watermark = args.f64_or("scale-kv-high", asc.kv_high_watermark);
        asc.kv_low_watermark = args.f64_or("scale-kv-low", asc.kv_low_watermark);
        asc.quantile = args.f64_or("scale-quantile", asc.quantile);
        asc.work_per_replica = args.f64_or("scale-work", asc.work_per_replica);
        if args.has("scale-prewarm") {
            asc.prewarm = true;
        }
        if let Err(e) = asc.validate() {
            bail!("{e} (--autoscale/--scale-* flags)");
        }
    }
    if args.has("sessions") {
        cfg.workload.sessions.enabled = true;
    }
    {
        let s = &mut cfg.workload.sessions;
        s.prefix_share = args.f64_or("prefix-share", s.prefix_share);
        s.turns_mean = args.f64_or("session-turns", s.turns_mean);
        s.think_mean = args.f64_or("session-think", s.think_mean);
        s.system_prompt_tokens =
            args.u64_or("system-prompt-tokens", s.system_prompt_tokens as u64) as u32;
        if let Err(e) = s.validate() {
            bail!("{e} (--sessions/--prefix-share/--session-* flags)");
        }
    }
    if args.has("slo-aware") {
        cfg.slo.class_aware = true;
    }
    if let Some(m) = args.get("slo-mix") {
        cfg.workload.slo_mix =
            sagesched::slo::parse_mix(m).map_err(|e| anyhow::anyhow!("--slo-mix: {e}"))?;
    }
    cfg.slo.sched_quantile = args.f64_or("slo-quantile", cfg.slo.sched_quantile);
    if let Err(e) = cfg.slo.validate() {
        bail!("{e} (--slo-aware/--slo-mix/--slo-quantile flags)");
    }
    if let Some(s) = args.get("speeds") {
        cfg.cluster.speeds = parse_f64_list("speeds", s)?;
        if cfg.cluster.speeds.iter().any(|&v| v <= 0.0) {
            bail!("--speeds entries must be positive, got {s}");
        }
    }
    if let Some(b) = args.get("batch-sizes") {
        let batches = parse_f64_list("batch-sizes", b)?;
        if batches.iter().any(|&v| v < 1.0) {
            bail!("--batch-sizes entries must be >= 1, got {b}");
        }
        cfg.cluster.batch_sizes = batches.into_iter().map(|v| v as usize).collect();
    }
    if let Some(k) = args.get("kv-capacities") {
        let kvs = parse_f64_list("kv-capacities", k)?;
        let min_kv = sagesched::serve::KV_BLOCK_TOKENS as f64;
        if kvs.iter().any(|&v| v < min_kv) {
            bail!("--kv-capacities entries must be >= {min_kv} tokens (one KV block), got {k}");
        }
        cfg.cluster.kv_capacities = kvs.into_iter().map(|v| v as usize).collect();
    }
    Ok(cfg)
}

/// Parse a comma-separated numeric list, rejecting (not skipping) bad
/// entries so a typo can't silently reshape the cluster.
fn parse_f64_list(flag: &str, s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{flag}: bad numeric entry {x:?} in {s:?}"))
        })
        .collect()
}

fn print_report(report: &RunReport, as_json: bool) {
    if as_json {
        println!("{}", report.to_json());
    } else {
        println!("{}", RunReport::markdown_header());
        println!("{}", report.markdown_row());
        println!(
            "goodput: {:.1}% ({} completed, {} rejected, {} timed out)",
            report.goodput() * 100.0,
            report.completed,
            report.rejected,
            report.aborted
        );
        print_kv_summary(report);
        print_slo_summary(report);
    }
}

/// KV-cache occupancy / prefix-cache lines shared by `run` and `cluster`
/// summaries.
fn print_kv_summary(report: &RunReport) {
    println!(
        "  kv: peak {} blocks, fragmentation {:.3}, swap out/in {}/{}, \
         peak swapped {} tokens",
        report.kv_peak_used_blocks,
        report.kv_fragmentation,
        report.swap_out_events,
        report.swap_in_events,
        report.kv_swapped_tokens_peak,
    );
    if report.kv_prefix_lookups > 0 {
        println!(
            "  prefix cache: hit rate {:.1}% ({} of {} probes), \
             {} prefill tokens saved, {} warm evictions",
            report.kv_prefix_hit_rate() * 100.0,
            report.kv_prefix_hits,
            report.kv_prefix_lookups,
            report.kv_prefill_tokens_saved,
            report.kv_prefix_evictions,
        );
    }
}

/// Per-SLO-class attainment lines shared by `run` and `cluster` summaries.
fn print_slo_summary(report: &RunReport) {
    if report.slo.is_empty() {
        return;
    }
    for (name, s) in &report.slo {
        if s.submitted() == 0 {
            continue;
        }
        println!(
            "  slo {name}: attainment {:.1}% ({} of {} within ttft<={:.1}s \
             ttlt<={:.1}s; {} rejected, {} timed out; TTLT p90 {:.2}s)",
            s.attainment() * 100.0,
            s.attained,
            s.submitted(),
            s.ttft_target,
            s.ttlt_target,
            s.rejected,
            s.aborted,
            s.ttlt.p90,
        );
    }
    println!(
        "  slo-weighted goodput: {:.3}",
        report.slo_weighted_goodput()
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let report = if let Some(trace_path) = args.get("trace") {
        // replay a recorded trace instead of generating a fresh workload
        let requests = sagesched::workload::trace::load(trace_path)?;
        let mut coord = sagesched::serve::build_sim_coordinator(&cfg);
        sagesched::serve::prewarm_predictor(coord.predictor.as_mut(), &cfg);
        coord.run_workload(requests)?;
        coord.report(cfg.warmup_fraction)
    } else {
        run_experiment(&cfg)?
    };
    print_report(&report, args.has("json"));
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let out = args.str_or("out", "trace.jsonl");
    let wl = sagesched::workload::WorkloadGen::new(cfg.workload.clone(), cfg.seed)
        .generate();
    sagesched::workload::trace::save(&out, &wl.requests)?;
    println!("wrote {} requests to {out}", wl.requests.len());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = config_from_args(args)?;
    let rps_list: Vec<f64> = args
        .str_or("rps-list", "4,6,8,10")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    for rps in rps_list {
        println!("## rps = {rps}");
        println!("{}", RunReport::markdown_header());
        for policy in PolicyKind::PAPER_BASELINES {
            let mut cfg = base.clone();
            cfg.policy = policy;
            cfg.workload.rps = rps;
            let report = run_experiment(&cfg)?;
            println!("{}", report.markdown_row());
        }
        println!();
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let rt = Runtime::load(&dir)?;
    let meta = rt.meta().clone();
    println!(
        "loaded artifacts: vocab={} layers={} heads={} max_seq={} batch={}",
        meta.vocab, meta.n_layers, meta.n_heads, meta.max_seq, meta.decode_batch
    );
    let tokens = sagesched::tokenizer::encode("hello sagesched");
    let pf = rt.run_prefill(&tokens)?;
    println!("prefill ok: {} logits, k/v {} floats", pf.logits.len(), pf.k.len());
    let emb = rt.run_embed(&tokens)?;
    println!("embed ok: dim {}", emb.len());
    let b = meta.decode_batch;
    let toks = vec![meta.pad_id as i32; b];
    let pos = vec![0i32; b];
    let ce = meta.cache_elems();
    let dec = rt.run_decode(&toks, &pos, &vec![0.0; ce], &vec![0.0; ce])?;
    println!("decode ok: {} logits", dec.logits.len());

    // end-to-end short generation through the engine
    use sagesched::engine::{Engine, LaneState};
    let mut engine = RealEngine::new(rt, 0);
    let req = sagesched::core::Request {
        id: 1,
        prompt: "tell me a story about fjords".into(),
        input_len: tokens.len() as u32,
        true_output_len: u32::MAX,
        arrival: 0.0,
        dataset: sagesched::config::DatasetKind::Write,
        topic: 0,
        embedding: sagesched::embedding::Embedding::normalize(vec![1.0; 8]),
        true_dist: None,
        slo: sagesched::slo::SloClass::Standard,
        prefix_key: Vec::new(),
    };
    let _ = engine.prefill(&req)?;
    let mut lanes = vec![LaneState::new(&req, 1)];
    let mut steps = 0;
    while !lanes[0].finished && steps < 64 {
        engine.decode_step(&mut lanes, 0)?;
        steps += 1;
    }
    println!(
        "generated {} tokens in {} decode steps (text: {:?})",
        lanes[0].generated,
        steps,
        engine.output_text(1).unwrap_or_default()
    );
    println!("smoke OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let addr = args.str_or("addr", "127.0.0.1:8080");
    let rt = Runtime::load(&dir)?;
    let cfg = config_from_args(args)?;
    let engine = RealEngine::new(rt, cfg.seed);
    let mut policy = sagesched::sched::make_policy(&cfg);
    if cfg.slo.class_aware {
        policy = Box::new(sagesched::slo::ClassAwarePolicy::new(
            policy,
            cfg.slo.clone(),
        ));
    }
    let predictor = sagesched::predictor::make_predictor(
        cfg.predictor,
        engine.runtime().meta().d_model,
        cfg.history_capacity,
        cfg.similarity_threshold,
        cfg.seed,
    );
    let cost = sagesched::cost::make_cost_model(cfg.cost_model);
    let mut coord = Coordinator::new(
        engine,
        policy,
        predictor,
        cost,
        sagesched::config::PreemptMode::Recompute,
    );
    coord.slo = cfg.slo.clone();
    let handle = sagesched::server::serve(&addr, coord)?;
    println!("serving on http://{} (policy: {})", handle.addr, cfg.policy.name());
    println!("POST /v1/generate {{\"prompt\": \"...\"}} | GET /metrics | GET /healthz");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;

    // secondary mode: the legacy fig12 shared-service overhead sweep
    if args.has("overhead") {
        let sizes: Vec<usize> = args
            .str_or("nodes", "1,2,4,8,16,32,64")
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        let sim = ClusterSim::new(cfg);
        println!("| nodes | rps | predict (ms) | sched (ms) | total (ms) | predictor util |");
        println!("|---|---|---|---|---|---|");
        for o in sim.sweep(&sizes) {
            println!(
                "| {} | {:.0} | {:.3} | {:.3} | {:.3} | {:.2} |",
                o.nodes,
                o.aggregate_rps,
                o.predict_latency * 1e3,
                o.sched_latency * 1e3,
                o.total_latency * 1e3,
                o.predictor_utilization
            );
        }
        return Ok(());
    }

    // primary mode: event-driven multi-replica simulation, one row per
    // router, same seeded workload for every router
    let routers: Vec<RouterKind> = match args.str_or("routers", "all").as_str() {
        "all" => RouterKind::ALL.to_vec(),
        list => list
            .split(',')
            .map(|s| {
                RouterKind::from_name(s.trim())
                    .with_context(|| format!("unknown router {s}"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    if routers.is_empty() {
        bail!("--routers produced an empty list");
    }
    println!(
        "# {} replicas · {} requests @ {} rps ({} arrivals) · policy {} · seed {}",
        cfg.cluster.replicas,
        cfg.workload.n_requests,
        cfg.workload.rps,
        cfg.workload.arrival.kind.name(),
        cfg.policy.name(),
        cfg.seed
    );
    if !cfg.cluster.speeds.is_empty() {
        println!("# replica speeds (cycled): {:?}", cfg.cluster.speeds);
    }
    if cfg.cluster.autoscale.kind != AutoscaleKind::Off {
        let asc = &cfg.cluster.autoscale;
        println!(
            "# autoscale: {} (min {} / max {}, provision {:.1}s, interval {:.1}s)",
            asc.kind.name(),
            asc.min_replicas,
            asc.max_replicas,
            asc.provision_delay,
            asc.interval
        );
        for s in &asc.steps {
            println!("# scale step: t={:.1}s -> {} replicas", s.at, s.target);
        }
    }
    if !cfg.cluster.failures.is_empty() {
        for f in &cfg.cluster.failures {
            println!(
                "# outage: replica {} down {:.1}s..{:.1}s",
                f.replica,
                f.at,
                f.at + f.duration
            );
        }
    }
    for df in &cfg.cluster.domain_failures {
        // a bad domain index is a hard error when the cluster runs; the
        // banner just skips it
        if let Some(dom) = cfg.cluster.failure_domains.get(df.domain) {
            println!(
                "# domain outage: {} (replicas {:?}) down {:.1}s..{:.1}s",
                dom.name,
                dom.replicas,
                df.at,
                df.at + df.duration
            );
        }
    }
    if cfg.cluster.migration_kv_per_token > 0.0 {
        println!(
            "# scale-in: migration-cost-aware (kv transfer {:.2}/token, \
             remaining-cost quantile p{:.0})",
            cfg.cluster.migration_kv_per_token,
            cfg.cluster.migration_quantile * 100.0
        );
    }
    if cfg.cluster.disagg() {
        let pools: Vec<&str> = (0..cfg.cluster.replicas)
            .map(|i| cfg.cluster.pool_of(i).map(|p| p.name()).unwrap_or("?"))
            .collect();
        println!(
            "# disaggregated: pools [{}] · transfer fabric {} links × {:.0} \
             tokens/s{}",
            pools.join(","),
            cfg.cluster.transfer_links,
            cfg.cluster.transfer_bandwidth,
            cfg.cluster
                .decode_router
                .map(|r| format!(" · decode router {}", r.name()))
                .unwrap_or_default()
        );
    }
    if cfg.workload.sessions.enabled {
        let s = &cfg.workload.sessions;
        println!(
            "# sessions: on (prefix-share {:.2}, mean turns {:.1}, think {:.1}s, \
             system prompt {} tokens, {} prompts/dataset)",
            s.prefix_share, s.turns_mean, s.think_mean, s.system_prompt_tokens,
            s.prompts_per_dataset
        );
    }
    if cfg.slo.class_aware {
        let mix: Vec<String> = cfg
            .workload
            .slo_mix
            .iter()
            .map(|(c, w)| format!("{}:{w}", c.name()))
            .collect();
        println!("# slo: class-aware serving (mix {})", mix.join(","));
    }
    println!("{}", ClusterReport::markdown_header());
    let mut reports = Vec::new();
    for router in routers {
        let report = run_router_experiment(&cfg, router)?;
        println!("{}", report.markdown_row());
        reports.push(report);
    }
    for r in &reports {
        println!(
            "# {}: goodput {:.1}% ({} completed, {} rejected, {} timed out, \
             {} re-routed, {} drained, {} migrated, {} stolen, {} steals \
             skipped, {} domain outages) — \
             {:.0} replica-s, {:.3} goodput/replica-s, \
             {:.3} slo-weighted gp/replica-s",
            r.router,
            r.aggregate.goodput() * 100.0,
            r.aggregate.completed,
            r.aggregate.rejected,
            r.aggregate.aborted,
            r.re_routed,
            r.drained,
            r.migrated,
            r.stolen,
            r.steals_skipped,
            r.domain_outages,
            r.total_replica_seconds(),
            r.goodput_per_replica_second,
            r.slo_weighted_goodput_per_replica_second
        );
        if r.transfers > 0 || !r.pool_replica_seconds.is_empty() {
            let pools = if r.pool_replica_seconds.len() == 2 {
                format!(
                    ", prefill/decode replica-s {:.0}/{:.0}",
                    r.pool_replica_seconds[0], r.pool_replica_seconds[1]
                )
            } else {
                String::new()
            };
            println!(
                "  fabric: {} transfers, {} kv tokens shipped, utilization \
                 {:.3}{pools}",
                r.transfers, r.transfer_tokens, r.transfer_utilization
            );
        }
        // per-scope hits/fallbacks/rescans, shown only when the indexes
        // actually answered or attempted something (the oracle mode's
        // all-rescan tally would be noise)
        let attempted: u64 = DispatchScope::ALL
            .iter()
            .map(|&s| {
                let sc = r.fastpath.scope(s);
                sc.hits + sc.fallbacks
            })
            .sum();
        if attempted > 0 {
            let per: Vec<String> = DispatchScope::ALL
                .iter()
                .filter(|&&s| r.fastpath.scope(s).decisions() > 0)
                .map(|&s| {
                    let sc = r.fastpath.scope(s);
                    format!("{} {}/{}/{}", s.name(), sc.hits, sc.fallbacks, sc.rescans)
                })
                .collect();
            println!(
                "  fast path: {:.1}% hits — h/f/r by scope: {}",
                r.fastpath.hit_rate() * 100.0,
                per.join(", ")
            );
        }
        print_kv_summary(&r.aggregate);
        print_slo_summary(&r.aggregate);
    }
    if let Some(r) = reports.iter().find(|r| !r.scaling_events.is_empty()) {
        println!("\n## scaling timeline ({})", r.router);
        println!("| t (s) | replica | event |");
        println!("|---|---|---|");
        for e in &r.scaling_events {
            println!("| {:.2} | {} | {} |", e.at, e.replica, e.action.name());
        }
    }
    if args.has("json") {
        for r in &reports {
            println!("{}", r.to_json());
        }
    }
    if args.has("per-replica") {
        for r in &reports {
            println!("\n## {} per-replica", r.router);
            println!("{}", sagesched::metrics::RunReport::markdown_header());
            for pr in &r.per_replica {
                println!("{}", pr.markdown_row());
            }
        }
    }
    Ok(())
}

fn cmd_predquality(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let n = args.usize_or("n", 500);
    let mut predictor = sagesched::predictor::make_predictor(
        cfg.predictor,
        cfg.workload.embed_dim,
        cfg.history_capacity,
        cfg.similarity_threshold,
        cfg.seed,
    );
    sagesched::serve::prewarm_predictor(predictor.as_mut(), &cfg);
    let mut wl = cfg.workload.clone();
    wl.n_requests = n;
    let probes = sagesched::workload::WorkloadGen::new(wl, cfg.seed ^ 0x9).generate();
    // marginal baseline over the probe set
    let all: Vec<f64> = probes.requests.iter().map(|r| r.true_output_len as f64).collect();
    let marginal = sagesched::distribution::LengthDist::from_samples(&all);
    let mut w1_pred = 0.0;
    let mut w1_marg = 0.0;
    let mut mean_abs_err = 0.0;
    let mut tau = sagesched::util::stats::KendallTau::new(n.max(2));
    for r in &probes.requests {
        let pred = predictor.predict(r);
        let truth = r.true_dist.as_ref().unwrap();
        w1_pred += pred.w1_distance(truth);
        w1_marg += marginal.w1_distance(truth);
        mean_abs_err += (pred.mean() - truth.mean()).abs();
        tau.push(predictor.predict_rank(r), r.true_output_len as f64);
    }
    println!(
        "predictor={} n={n} mean W1(pred,true)={:.1} W1(marginal,true)={:.1} meanErr={:.1} tau={:.3}",
        predictor.name(),
        w1_pred / n as f64,
        w1_marg / n as f64,
        mean_abs_err / n as f64,
        tau.tau()
    );
    Ok(())
}

const USAGE: &str = "usage: sagesched <run|sweep|smoke|serve|cluster> [flags]
  run     run one simulated experiment        (--policy --rps --n --engine --json)
  sweep   compare the paper's six schedulers  (--rps-list 4,6,8,10)
  smoke   load + execute the HLO artifacts    (--artifacts artifacts)
  serve   HTTP server over the real model     (--addr 127.0.0.1:8080)
  cluster event-driven multi-replica sim, one row per router
          (--replicas 4 --routers all|round-robin,least-loaded,least-kv,
             cost-aware,quantile-cost,cache-affinity   --router-quantile 0.9
             (cache-affinity: session-sticky placement — backlog minus the
              prefill cost the target's warm shared-prefix blocks save)
           --speeds 1.0,0.5 --batch-sizes 256,128 --kv-capacities 10000,6000
           --fail 1@30+10,0@60+5   replica outages (replica@start+duration)
           --domains rack0:0,1;rack1:2,3   correlated failure domains
           --fail-domain 0@30+10   domain outages (domain@start+duration)
           --steal-transfer 2      work-steal transfer penalty (cost/token)
           --migrate-kv 0.5        migration-cost-aware scale-in: KV
                                   transfer cost per resident token (0=off)
           --migrate-quantile 0.9  remaining-cost quantile for migration
           --per-replica --json)
          autoscaling (elastic replica scale-out/in mid-run):
          --autoscale off|step|reactive|uncertainty
          --scale-steps 10@6,40@2       scripted time@target steps
          --scale-min 1 --scale-max 16  target clamp
          --scale-delay 2 --scale-cooldown 5 --scale-interval 1
          --scale-high 8 --scale-low 2  reactive live/replica watermarks
          --scale-kv-high 0.85 --scale-kv-low 0.3 reactive KV watermarks
          --scale-quantile 0.9 --scale-work 1e6   uncertainty-aware
          --scale-prewarm               prewarm new replicas' predictors
          disaggregated prefill/decode pools (cluster):
          --disagg                      split replicas into prefill/decode
                                        pools (alternating); prefill runs
                                        each prompt to first token, a
                                        bandwidth-limited KV-transfer fabric
                                        ships it to the decode pool; each
                                        pool autoscales independently
          --pool prefill,prefill,decode,decode  explicit role cycle
          --transfer-bandwidth 20000    fabric link bandwidth (kv tokens/s)
          --transfer-links 2            parallel fabric links
          --decode-router least-kv      decode-pool delivery router
                                        (defaults to the main router)
  cluster --overhead   fig12 shared-service overhead sweep (--nodes 1,4,16,64)
  gen-trace record a workload trace           (--out trace.jsonl --n 1000)
  SLO classes (run / sweep / cluster / gen-trace):
          --slo-aware                  class-aware scheduling/admission/routing
          --slo-mix interactive:0.25,standard:0.5,batch:0.25  stamping mix
          --slo-quantile 0.9           deadline-slack cost quantile
          (tier targets/weights via the JSON config's "slo" block)
  session workloads (run / sweep / cluster / gen-trace):
          --sessions                   multi-turn conversations: each turn's
                                       prompt extends the previous context,
                                       carrying a shared-prefix token-key
                                       chain the KV cache can hit on
          --prefix-share 0.6           fraction of arrivals starting sessions
          --session-turns 4 --session-think 6   mean extra turns / think time
          --system-prompt-tokens 256   per-dataset shared system-prompt size
          (JSON config: the workload.sessions block, incl prompts_per_dataset)
  arrival-process flags (run / sweep / cluster / gen-trace):
          --arrival poisson|mmpp|diurnal
          --burst-factor 6 --burst-on 10 --burst-off 40       (mmpp)
          --diurnal-period 120 --diurnal-amplitude 0.8        (diurnal)
  predictors (run / sweep / cluster / predquality):
          --predictor history|length-history|proxy|oracle|ranking
            ranking = online learning-to-rank over prompt features; adapts
            under drift, reported as windowed Kendall's tau (pred_tau)
          --drift [0.5]   mid-run workload shift: remap topic->length
                          profiles after this fraction of requests (bare
                          flag shifts at the halfway point; JSON config's
                          workload.drift block adds dataset-mix switches)
  (run also accepts --trace file.jsonl to replay a recorded trace)
  performance:
          the cluster sim routes dispatches through incrementally-maintained
          score indexes (see cluster::index); results are byte-identical to
          the pre-index full rescans, locked in by tests/perf_equiv.rs.
          fast-path coverage (router x dispatch scope; h = index hit,
          s = shortlist + dominance bound, may fall back; - = full rescan):
            router          intake  decode  drain  migration
            round-robin       h       h       h       h
            least-loaded      h       h       h       h
            least-kv          h       h       h       h
            cost-aware        h       h       h       h
            quantile-cost     h       h       h       h
            cache-affinity    s       s       s       s
            class-aware wrap  h*      h*      h*      h*
          h* = interactive arm answered from the tight-quantile/headroom
          index pair; other classes per the wrapped router above.
          decode/migration scopes additionally require the per-request
          KV-fit filter to be vacuous (scope-min total KV suffices),
          otherwise the dispatch is a counted rescan.
          --shortlist-k 8   cache-affinity shortlist width: the per-request
                            warm-prefix adjustment is applied to the K
                            best-base-score replicas plus every known warm
                            site; a dominance bound proves nothing outside
                            can win, else the dispatch falls back to the
                            full rescan (counted; >= 1, hard error on 0)
          per-scope hits/fallbacks/rescans are reported in the cluster
          summary and the report JSON's \"fastpath\" block.
          regenerate the checked-in BENCH_cluster.json baseline with
            cargo bench --bench cluster_scale          (1,000-replica run)
            cargo bench --bench cluster_scale -- --smoke   (CI-sized gate)
          the harness exits non-zero on any report drift";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("smoke") => cmd_smoke(&args),
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("predquality") => cmd_predquality(&args),
        Some("gen-trace") => cmd_gen_trace(&args),
        Some(other) => bail!("unknown command {other}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
