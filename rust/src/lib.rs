//! # SageSched — efficient LLM scheduling under demand uncertainty & hybridity
//!
//! Reproduction of *"SageSched: Efficient LLM Scheduling Confronting Demand
//! Uncertainty and Hybridity"* (Gan et al., 2026) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request router, continuous
//!   batcher, paged KV-cache manager, preemptive scheduler, plus the paper's
//!   three contributions — the [`predictor::HistoryPredictor`] (semantic-aware
//!   history-based output-length-distribution prediction), the
//!   [`cost::ResourceBoundCost`] model (`C = O²/2 + I·O`), and the
//!   [`gittins`]-index-based uncertainty-aware policy
//!   ([`sched`]'s `sagesched` policy).
//! * **L2 (`python/compile/model.py`)** — a tiny decoder-only LM (prefill /
//!   decode / embedder) in JAX, AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/attention.py`)** — the Pallas flash-decode
//!   attention kernel inside the L2 decode step.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO artifacts
//! via the PJRT C API (`xla` crate) and [`engine::RealEngine`] serves real
//! tokens from them. [`engine::SimEngine`] is the calibrated roofline
//! simulator used for the paper's large-scale experiments (the paper's own
//! testbed was A40/H800 GPUs; see DESIGN.md for the substitution argument).
//!
//! The build is fully offline, so heavyweight ecosystem crates are replaced
//! by in-tree substrates: [`util::json`] (JSON), [`util::rng`] (PCG64),
//! [`util::stats`], [`util::cli`], and a hand-rolled bench harness under
//! `rust/benches/`.

pub mod autoscale;
pub mod cluster;
pub mod config;
pub mod core;
pub mod cost;
pub mod distribution;
pub mod embedding;
pub mod engine;
pub mod gittins;
pub mod kvcache;
pub mod metrics;
pub mod predictor;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod server;
pub mod slo;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::autoscale::{AutoscalePolicy, ScaleAction, ScalingEvent};
    pub use crate::cluster::{run_router_experiment, EventCluster, Router};
    pub use crate::config::{
        ArrivalConfig, ArrivalKind, AutoscaleConfig, AutoscaleKind, ClusterConfig,
        CostModelKind, DatasetKind, EngineProfile, ExperimentConfig, FailureEvent,
        PolicyKind, PredictorKind, RouterKind, ScaleStep, WorkloadConfig,
    };
    pub use crate::workload::arrivals::ArrivalProcess;
    pub use crate::core::{Request, RequestId, RequestOutcome};
    pub use crate::cost::{CostModel, OutputLenCost, OverallLenCost, ResourceBoundCost};
    pub use crate::distribution::LengthDist;
    pub use crate::engine::{Engine, SimEngine};
    pub use crate::gittins::gittins_index;
    pub use crate::metrics::{ClusterReport, RunReport};
    pub use crate::predictor::{HistoryPredictor, Predictor};
    pub use crate::sched::Policy;
    pub use crate::serve::{run_experiment, Coordinator};
    pub use crate::slo::{ClassAwarePolicy, SloClass, SloClassSpec, SloConfig, SloSpecs};
    pub use crate::workload::WorkloadGen;
}
