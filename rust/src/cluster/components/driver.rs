//! [`AutoscaleDriver`]: the elastic-provisioning decision chain.

use crate::autoscale::{AutoscalePolicy, ScaleAction};
use crate::cluster::ctx::ClusterCtx;
use crate::cluster::kernel::{EventPayload, EventQueue, KernelEvent};
use crate::cluster::replica::ReplicaState;
use crate::config::PoolRole;
use crate::util::stats::normal_quantile_clamped;

use super::ClusterComponent;

/// Drives the elastic provisioning policy: seeds the periodic decision
/// chain (each fired decision schedules its successor while arrivals
/// remain or work is live, so the chain covers the drain tail too), fires
/// the policy's scripted decision times, turns scale-out targets into
/// provisioning spawns (spawn-ready events after the provisioning delay),
/// and picks scale-in victims.
///
/// Victim selection: provisioning replicas are always cancelled first
/// (they hold no work — a scale-out/scale-in whipsaw must not destroy warm
/// serving capacity while a cold replica is still on its way up). Among
/// active replicas, the legacy rule drains the one with the fewest live
/// requests (ties to the highest index). With migration-cost-aware
/// scale-in enabled (`migration_kv_per_token > 0`) the victim is instead
/// the replica with the smallest *predicted drain cost* — each
/// partially-generated request contributes the cheaper of waiting out a
/// quantile of its predicted remaining cost and shipping its KV — so the
/// cluster retires the replica whose work is closest to done or cheapest
/// to move, not merely the one with the fewest requests.
///
/// Under disaggregated serving the driver holds one policy *instance per
/// pool* (same [`AutoscaleConfig`](crate::config::AutoscaleConfig),
/// independent cooldowns): at each decision point the prefill pool is
/// sized against its TTFT-weighted prefill forecast and the decode pool
/// against its completion-weighted decode forecast (see
/// [`crate::cluster::disagg`]), spawns join the deciding pool, and victim
/// selection never crosses a pool boundary (nor drains a pool's last
/// active replica — each pool must stay routable).
pub struct AutoscaleDriver {
    /// One policy per scaling scope: `[(pool, instance)]` — a single
    /// `(None, _)` entry colocated, one entry per [`PoolRole`] under
    /// disaggregation. Empty when autoscaling is off.
    policies: Vec<(Option<PoolRole>, Box<dyn AutoscalePolicy>)>,
    /// z-score of the migration-cost quantile (victim scoring).
    z_migration: f64,
}

impl AutoscaleDriver {
    pub fn new(cfg: &crate::config::ExperimentConfig) -> AutoscaleDriver {
        let mut policies: Vec<(Option<PoolRole>, Box<dyn AutoscalePolicy>)> = Vec::new();
        if cfg.cluster.disagg() {
            for role in PoolRole::ALL {
                if let Some(p) = crate::autoscale::make_autoscaler(&cfg.cluster.autoscale)
                {
                    policies.push((Some(role), p));
                }
            }
        } else if let Some(p) = crate::autoscale::make_autoscaler(&cfg.cluster.autoscale)
        {
            policies.push((None, p));
        }
        AutoscaleDriver {
            policies,
            z_migration: normal_quantile_clamped(cfg.cluster.migration_quantile),
        }
    }

    /// Run every policy at a decision point; scale-out spawns fresh
    /// replicas (future spawn-ready events) into the deciding pool,
    /// scale-in begins draining victims immediately. The desired target
    /// counts capacity that is present or committed (active + provisioning
    /// + down) within the policy's scope.
    fn on_decision(
        &mut self,
        at: f64,
        ctx: &mut ClusterCtx,
        kernel: &mut EventQueue,
    ) -> anyhow::Result<()> {
        // decide first, act second: the decisions borrow the policies
        // mutably (cooldown state) while reading ctx; the actions mutate
        // ctx while victim scoring reads the driver
        let decisions: Vec<(Option<PoolRole>, Option<usize>, usize)> = self
            .policies
            .iter_mut()
            .map(|(pool, pol)| {
                let view = match pool {
                    Some(role) => ctx.pool_autoscale_view(at, *role),
                    None => ctx.autoscale_view(at),
                };
                (*pool, pol.target(&view), view.present())
            })
            .collect();
        for (pool, target, present) in decisions {
            let Some(target) = target else { continue };
            let target = target.max(1);
            if target > present {
                let delay = ctx.cfg.cluster.autoscale.provision_delay;
                for _ in 0..(target - present) {
                    let i = ctx.spawn_replica(at, pool);
                    ctx.record(at, i, ScaleAction::Provision);
                    kernel.push(at + delay, EventPayload::SpawnReady { replica: i });
                }
            } else {
                let mut shrink = present - target;
                while shrink > 0 {
                    // cancel not-yet-ready replicas first (newest first):
                    // they hold no work, so retiring them is free. The
                    // pending spawn-ready event becomes a no-op (the state
                    // is no longer Provisioning).
                    if let Some(p) = ctx.replicas.iter().rposition(|r| {
                        r.state == ReplicaState::Provisioning
                            && (pool.is_none() || r.pool == pool)
                    }) {
                        ctx.retire(p, at);
                        shrink -= 1;
                        continue;
                    }
                    let active: Vec<usize> = ctx
                        .replicas
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| {
                            r.state == ReplicaState::Active
                                && (pool.is_none() || r.pool == pool)
                        })
                        .map(|(i, _)| i)
                        .collect();
                    // never drain the last routable replica of the scope:
                    // the pool must stay able to place re-routed, future,
                    // and fabric-delivered work
                    if active.len() <= 1 {
                        break;
                    }
                    let victim = self.pick_victim(ctx, &active);
                    ctx.begin_drain(victim, at)?;
                    shrink -= 1;
                }
            }
        }
        // keep the periodic chain alive while there is anything left to
        // decide about: feedback policies must be able to scale in during
        // the drain tail after the last arrival — including requests still
        // riding the transfer fabric. Once arrivals are exhausted and the
        // cluster is idle the chain ends, which bounds the event stream.
        if kernel.pending_decisions() == 0
            && (kernel.pending_arrivals() > 0
                || kernel.pending_transfers() > 0
                || ctx.has_live_work())
        {
            kernel.push(
                at + ctx.cfg.cluster.autoscale.interval,
                EventPayload::Decision,
            );
        }
        Ok(())
    }

    /// Pick the scale-in victim among `active` (non-empty).
    fn pick_victim(&self, ctx: &ClusterCtx, active: &[usize]) -> usize {
        if ctx.cfg.cluster.migration_kv_per_token > 0.0 {
            // migration-cost-aware: smallest predicted drain cost, ties to
            // the highest index (retire the newest replica first)
            let scores: Vec<f64> = active
                .iter()
                .map(|&i| ctx.scale_in_drain_cost(i, self.z_migration))
                .collect();
            let mut best = 0usize;
            for k in 1..active.len() {
                let better = scores[k] < scores[best]
                    || (scores[k] == scores[best] && active[k] > active[best]);
                if better {
                    best = k;
                }
            }
            active[best]
        } else {
            // legacy rule: fewest live requests, ties to the highest index
            *active
                .iter()
                .min_by_key(|&&i| (ctx.replicas[i].coord.live_count(), usize::MAX - i))
                .expect("non-empty active set")
        }
    }
}

impl ClusterComponent for AutoscaleDriver {
    fn name(&self) -> &'static str {
        "autoscale-driver"
    }

    fn on_start(&mut self, ctx: &mut ClusterCtx, kernel: &mut EventQueue) -> anyhow::Result<()> {
        if let Err(e) = ctx.cfg.cluster.autoscale.validate() {
            anyhow::bail!("{e}");
        }
        if let Err(e) = ctx.cfg.cluster.validate() {
            anyhow::bail!("{e}");
        }
        let Some((_, pol)) = self.policies.first() else {
            return Ok(());
        };
        // seed the periodic chain; each fired decision extends it. Scripted
        // steps fire exactly at their configured times, even past the last
        // arrival (a late scale-in still frees capacity during the drain
        // tail). A scripted step landing on the periodic seed must fire
        // once, not twice. Per-pool instances share one config, so one
        // instance's scripted times cover them all (each decision event
        // runs every policy).
        let mut times = vec![ctx.cfg.cluster.autoscale.interval];
        times.extend(pol.scheduled_times());
        times.sort_by(|a, b| a.partial_cmp(b).expect("NaN decision time"));
        times.dedup();
        for t in times {
            kernel.push(t, EventPayload::Decision);
        }
        Ok(())
    }

    fn on_event(
        &mut self,
        ev: KernelEvent,
        ctx: &mut ClusterCtx,
        kernel: &mut EventQueue,
    ) -> anyhow::Result<Option<KernelEvent>> {
        match ev.payload {
            EventPayload::SpawnReady { replica } => {
                ctx.apply_spawn_ready(replica, ev.at);
                Ok(None)
            }
            EventPayload::Decision => {
                self.on_decision(ev.at, ctx, kernel)?;
                Ok(None)
            }
            _ => Ok(Some(ev)),
        }
    }
}
