//! [`FailureInjector`]: scheduled outages — single replicas and
//! correlated failure domains.

use crate::cluster::ctx::ClusterCtx;
use crate::cluster::kernel::{EventPayload, EventQueue, KernelEvent};
use crate::config::AutoscaleKind;

use super::ClusterComponent;

/// Scheduled replica outages: single-replica failure/recovery windows and
/// correlated failure domains.
///
/// Single-replica semantics are unchanged from the pre-component cluster:
/// overlapping or touching windows on one replica merge into their union
/// (otherwise the earliest recovery of a nested outage would resurrect the
/// replica while a longer outage is still running, undercounting
/// downtime).
///
/// A **domain outage** takes every member of a
/// [`FailureDomain`](crate::config::FailureDomain) down in *one* event:
/// all members are failed first — including ones still `Provisioning` —
/// and only then is the pooled lost work re-dispatched, so the storm
/// routes over the true survivor set (a sibling that dies in the same
/// instant can never be handed work it is about to lose again). Domain
/// windows may not overlap any other outage window on the same replica —
/// that is a hard configuration error, because "who recovers this replica"
/// would otherwise be ambiguous. At equal instants, single-replica events
/// fire before domain events (kernel push order).
#[derive(Default)]
pub struct FailureInjector {
    /// Per-domain member lists, normalized (sorted, deduped) once at
    /// `on_start` so every fire/recover walks members in one
    /// deterministic order without re-allocating per event.
    members: Vec<Vec<usize>>,
}

impl ClusterComponent for FailureInjector {
    fn name(&self) -> &'static str {
        "failure-injector"
    }

    fn on_start(&mut self, ctx: &mut ClusterCtx, kernel: &mut EventQueue) -> anyhow::Result<()> {
        let n = ctx.replicas.len();
        // with autoscaling on, an outage may target a replica the scaler
        // will have spawned by then (indices are deterministic); the check
        // that it actually exists moves to the instant the event fires
        let elastic = ctx.cfg.cluster.autoscale.kind != AutoscaleKind::Off;
        let mut max_idx = n;
        for f in &ctx.cfg.cluster.failures {
            if f.replica >= n && !elastic {
                anyhow::bail!(
                    "failure event references replica {} but the cluster has \
                     {n} replicas",
                    f.replica
                );
            }
            if let Err(e) = f.validate() {
                anyhow::bail!("{e}");
            }
            max_idx = max_idx.max(f.replica + 1);
        }
        // validate domains + their outage schedule
        let domains = &ctx.cfg.cluster.failure_domains;
        for (d, dom) in domains.iter().enumerate() {
            if dom.replicas.is_empty() {
                anyhow::bail!("failure domain {d} ({}) has no member replicas", dom.name);
            }
            for &m in &dom.replicas {
                if m >= n && !elastic {
                    anyhow::bail!(
                        "failure domain {d} ({}) references replica {m} but the \
                         cluster has {n} replicas",
                        dom.name
                    );
                }
                max_idx = max_idx.max(m + 1);
            }
        }
        for df in &ctx.cfg.cluster.domain_failures {
            if df.domain >= domains.len() {
                anyhow::bail!(
                    "domain failure event references domain {} but only {} \
                     failure domains are configured",
                    df.domain,
                    domains.len()
                );
            }
            if let Err(e) = df.validate() {
                anyhow::bail!("{e}");
            }
        }
        self.members = domains
            .iter()
            .map(|d| {
                let mut m = d.replicas.clone();
                m.sort_unstable();
                m.dedup();
                m
            })
            .collect();
        // merge overlapping single-replica windows into their union
        let mut by_replica: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_idx];
        for f in &ctx.cfg.cluster.failures {
            by_replica[f.replica].push((f.at, f.at + f.duration));
        }
        let mut merged_by_replica: Vec<Vec<(f64, f64)>> = Vec::with_capacity(max_idx);
        for mut windows in by_replica {
            windows.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut merged: Vec<(f64, f64)> = Vec::new();
            for (start, end) in windows {
                match merged.last_mut() {
                    Some(last) if start <= last.1 => last.1 = last.1.max(end),
                    _ => merged.push((start, end)),
                }
            }
            merged_by_replica.push(merged);
        }
        // domain windows may not overlap any other outage window on the
        // same replica (individual or another domain's): recovery ownership
        // would be ambiguous
        let mut domain_windows: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_idx];
        for df in &ctx.cfg.cluster.domain_failures {
            let window = (df.at, df.at + df.duration);
            for &m in &self.members[df.domain] {
                let overlaps = merged_by_replica[m]
                    .iter()
                    .chain(domain_windows[m].iter())
                    .any(|&(s, e)| window.0 < e && s < window.1);
                if overlaps {
                    anyhow::bail!(
                        "domain failure (domain {} at t={}) overlaps another \
                         outage window on replica {m}; outage windows on one \
                         replica must not overlap across failure domains",
                        df.domain,
                        df.at
                    );
                }
                domain_windows[m].push(window);
            }
        }
        // emit single-replica events in the legacy (at, class, replica)
        // order so the kernel's insertion-order tie-break reproduces the
        // pre-component event stream byte for byte
        let mut singles: Vec<(f64, u8, usize)> = Vec::new();
        for (replica, merged) in merged_by_replica.iter().enumerate() {
            for &(start, end) in merged {
                singles.push((start, 2, replica)); // fail
                singles.push((end, 1, replica)); // recover
            }
        }
        singles.sort_by(|a, b| a.partial_cmp(b).expect("NaN event time"));
        for (at, class, replica) in singles {
            let payload = if class == 2 {
                EventPayload::Fail { replica }
            } else {
                EventPayload::Recover { replica }
            };
            kernel.push(at, payload);
        }
        // then domain events, in (at, class, domain) order
        let mut dom_events: Vec<(f64, u8, usize)> = Vec::new();
        for df in &ctx.cfg.cluster.domain_failures {
            dom_events.push((df.at, 2, df.domain));
            dom_events.push((df.at + df.duration, 1, df.domain));
        }
        dom_events.sort_by(|a, b| a.partial_cmp(b).expect("NaN event time"));
        for (at, class, domain) in dom_events {
            let payload = if class == 2 {
                EventPayload::DomainFail { domain }
            } else {
                EventPayload::DomainRecover { domain }
            };
            kernel.push(at, payload);
        }
        Ok(())
    }

    fn on_event(
        &mut self,
        ev: KernelEvent,
        ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<Option<KernelEvent>> {
        match ev.payload {
            EventPayload::Fail { replica } => {
                let lost = ctx.fail_replica(replica, ev.at)?;
                ctx.redispatch(lost, ev.at)?;
                Ok(None)
            }
            EventPayload::Recover { replica } => {
                ctx.apply_recovery(replica, ev.at);
                Ok(None)
            }
            EventPayload::DomainFail { domain } => {
                // fail every member first, pooling the lost work, then
                // re-dispatch the whole storm over the true survivor set
                let mut lost = Vec::new();
                for &m in &self.members[domain] {
                    lost.extend(ctx.fail_replica(m, ev.at)?);
                }
                ctx.domain_outages += 1;
                ctx.redispatch(lost, ev.at)?;
                Ok(None)
            }
            EventPayload::DomainRecover { domain } => {
                for &m in &self.members[domain] {
                    ctx.apply_recovery(m, ev.at);
                }
                Ok(None)
            }
            _ => Ok(Some(ev)),
        }
    }
}
