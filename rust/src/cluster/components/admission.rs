//! [`SloAdmission`]: the placement/admission seam every dispatch path
//! consults.

use crate::cluster::ctx::{ClusterCtx, FastPathOutcome, WarmPricing};
use crate::cluster::replica::InFlight;
use crate::cluster::router::FastPath;
use crate::core::Request;
use crate::metrics::DispatchScope;

use super::ClusterComponent;

/// The placement/admission concern: routing a request onto a replica under
/// the coordinator's (possibly SLO-class-aware) admission verdict, with
/// migration-exemption semantics.
///
/// Three rules, one home:
///
/// * A *fresh* arrival (and crash re-dispatch, which shares its admission
///   semantics) is routed and submitted normally — the target's
///   [`Coordinator::admits`](crate::serve::Coordinator::admits) verdict is
///   consulted before submission so the has-room view can never disagree
///   with `submit()`.
/// * A *migration* (scale-in drain fallback, and stealing / partial moves,
///   which call the coordinator's exempt paths directly) must never
///   convert an already-admitted request into a rejection.
/// * A scale-in drain with no admitting target falls back to re-admitting
///   on the (draining) victim, which always fits: the request occupied one
///   of the victim's admission slots moments ago and nothing was admitted
///   there since.
///
/// Dispatches through a router with a declared [`FastPath`] — fresh
/// intake *and* drain re-admission, each against the index instance
/// covering its pool — are answered from the incremental indexes
/// (`ClusterCtx::index_route` / `ClusterCtx::affinity_route`) without
/// building any views; the full rescan below is kept verbatim as the
/// fallback for failed dominance bounds and as the `use_indexes = false`
/// differential oracle.
pub struct SloAdmission;

/// Resolved placement handed to the shared admission tail: where the
/// request goes and the prediction bookkeeping that travels with it.
struct Placement {
    target: usize,
    moved: bool,
    warm_saving: f64,
    pcost: f64,
    pvar: f64,
    weight: f64,
    rank: f64,
}

impl SloAdmission {
    /// Routing core shared by fresh dispatch and the scale-in drain path.
    /// With `keep_on: Some(victim)` a routed target without admission
    /// headroom — or an empty routable set — falls back to re-admitting on
    /// the (draining) `victim`. Returns true when the request landed
    /// somewhere other than the fallback.
    pub fn place(
        &self,
        ctx: &mut ClusterCtx,
        req: Request,
        not_before: f64,
        keep_on: Option<usize>,
    ) -> anyhow::Result<bool> {
        let pred = ctx.predictor.predict(&req);
        let rank = ctx.predictor.predict_rank(&req);
        let cost_dist = ctx.cost.cost_dist(req.input_len, &pred);
        let pcost = cost_dist.mean();
        let pvar = cost_dist.variance();
        let weight = if ctx.cfg.slo.class_aware {
            ctx.cfg.slo.specs.spec(req.slo).weight
        } else {
            1.0
        };
        // under disaggregation fresh arrivals (and crash re-dispatch, which
        // restarts from scratch and so needs prefill again) enter through
        // the prefill pool; a scale-in drain re-routes within its victim's
        // own pool. Colocated serving resolves both to "every routable
        // replica".
        let pool = match keep_on {
            Some(victim) => ctx.replicas[victim].pool,
            None => ctx.intake_pool(),
        };
        let scope = if keep_on.is_some() { DispatchScope::Drain } else { DispatchScope::Intake };
        // fast path: intake *and* drain re-admission through an
        // index-backed router skip the view build + rescan entirely,
        // dispatching from the index instance covering `pool`.
        let fp = ctx.router.fast_path(&req);
        let attempted =
            ctx.use_indexes && fp != FastPath::Rescan && ctx.scoped_indexes(pool).is_some();
        let fast_target = if attempted {
            match fp {
                FastPath::Affinity => {
                    ctx.affinity_route(&req, pcost, pool, WarmPricing::Admission(&pred))
                }
                _ => ctx.index_route(fp, pool, false),
            }
        } else {
            None
        };
        if let Some(i) = fast_target {
            ctx.count_fastpath(scope, FastPathOutcome::Hit);
            // the coordinator's admission verdict, mirroring the rescan
            // path: a drain re-admission without headroom falls back to
            // the (draining) victim
            let has_room = ctx.replicas[i].coord.admits(req.slo);
            let (target, moved) = if has_room || keep_on.is_none() {
                (i, true)
            } else {
                (keep_on.expect("fallback without a drain victim"), false)
            };
            // per-request warmth probe on the chosen replica only —
            // identical arithmetic to the per-view probe below, and
            // read-only, so probing one replica instead of all of them
            // changes nothing observable. The fallback victim books no
            // saving, exactly like the rescan path.
            let mut warm_saving = 0.0;
            if moved && !req.prefix_key.is_empty() {
                let warm = ctx.replicas[target]
                    .coord
                    .kv
                    .cached_prefix_tokens(&req.prefix_key, req.input_len as usize)
                    as u32;
                if warm > 0 {
                    let warm_cost = ctx
                        .cost
                        .cost_dist(req.input_len.saturating_sub(warm), &pred)
                        .mean();
                    warm_saving = (pcost - warm_cost).max(0.0);
                }
            }
            return Ok(Self::admit(
                ctx,
                req,
                not_before,
                keep_on,
                Placement { target, moved, warm_saving, pcost, pvar, weight, rank },
            ));
        }
        ctx.count_fastpath(
            scope,
            if attempted { FastPathOutcome::Fallback } else { FastPathOutcome::Rescan },
        );
        // per-request warmth: probe each routable replica's prefix index so
        // cache-affinity scoring (and the backlog debit below) sees how
        // much prefill this request would skip there. The probe is
        // read-only; requests without a prefix chain skip it entirely.
        let views = {
            let mut vs = ctx.views_for(pool);
            if !req.prefix_key.is_empty() {
                for v in &mut vs {
                    let warm = ctx.replicas[v.id]
                        .coord
                        .kv
                        .cached_prefix_tokens(&req.prefix_key, req.input_len as usize)
                        as u32;
                    if warm > 0 {
                        v.warm_prefix_tokens = warm;
                        let warm_cost = ctx
                            .cost
                            .cost_dist(req.input_len.saturating_sub(warm), &pred)
                            .mean();
                        v.warm_cost_saving = (pcost - warm_cost).max(0.0);
                    }
                }
            }
            vs
        };
        let mut target = None;
        let mut warm_saving = 0.0;
        if views.is_empty() {
            if keep_on.is_none() {
                anyhow::bail!(
                    "cannot route request {}: none of the {} replicas is routable{}",
                    req.id,
                    ctx.replicas.len(),
                    pool.map(|p| format!(" in the {} pool", p.name()))
                        .unwrap_or_default()
                );
            }
        } else {
            let slot = ctx.router.route(&req, pcost, &views);
            if slot >= views.len() {
                anyhow::bail!(
                    "router {} returned position {slot} but only {} replicas are \
                     routable",
                    ctx.router.name(),
                    views.len()
                );
            }
            let i = views[slot].id;
            // the coordinator's own (possibly class-aware) admission verdict,
            // so the has-room view can never disagree with submit()
            let has_room = ctx.replicas[i].coord.admits(req.slo);
            if has_room || keep_on.is_none() {
                target = Some(i);
                warm_saving = views[slot].warm_cost_saving;
            }
        }
        let moved = target.is_some();
        let i = target
            .or(keep_on)
            .expect("place: empty routable set without fallback already bailed");
        Ok(Self::admit(
            ctx,
            req,
            not_before,
            keep_on,
            Placement { target: i, moved, warm_saving, pcost, pvar, weight, rank },
        ))
    }

    /// Shared admission tail of both routing paths: advance the target's
    /// clock, submit (exempt for the drain fallback), and book the
    /// predicted-cost moments on acceptance.
    fn admit(
        ctx: &mut ClusterCtx,
        req: Request,
        not_before: f64,
        keep_on: Option<usize>,
        p: Placement,
    ) -> bool {
        let Placement { target: i, moved, warm_saving, pcost, pvar, weight, rank } = p;
        let id = req.id;
        if ctx.trace_dispatch {
            ctx.dispatch_trace.push((id, i));
        }
        ctx.replicas[i].coord.advance_to(req.arrival.max(not_before));
        // the drain fallback is a *migration*: the request already passed
        // admission on the victim, so re-admitting it there is exempt
        let accepted = if moved {
            ctx.replicas[i].coord.submit(req.clone())
        } else {
            ctx.replicas[i].coord.submit_exempt(req.clone())
        };
        debug_assert!(accepted || keep_on.is_none(), "drain re-admission must fit");
        if accepted {
            // a landing is where prefix caching can begin: keep the
            // warm-site superset invariant the affinity fast path relies on
            ctx.note_warm_site(&req, i);
            // the warm replica serves this request cheaper than the cold
            // prediction says: book the debited cost so the backlog the
            // routers/autoscaler see reflects the post-hit work (released
            // symmetrically on completion — InFlight carries the same value)
            let eff_cost = (pcost - warm_saving).max(0.0);
            ctx.in_flight.insert(
                id,
                InFlight { replica: i, cost: eff_cost, var: pvar, weight, rank, req },
            );
            ctx.backlog[i] += eff_cost;
            ctx.backlog_var[i] += pvar;
            ctx.backlog_weighted += weight * eff_cost;
            ctx.backlog_weighted_var += weight * weight * pvar;
            ctx.routed[i] += 1;
            ctx.steal_dirty = true; // fresh queued work: steal verdicts change
        }
        // the clock advance alone changes the busy index even on refusal
        ctx.sync_replica(i);
        // refusals are counted by the coordinator itself (sole owner of the
        // rejected counter; see ClusterCtx::rejected)
        moved && accepted
    }
}

impl ClusterComponent for SloAdmission {
    fn name(&self) -> &'static str {
        "slo-admission"
    }
    // no timed events: every placement path consults `place` synchronously
}
