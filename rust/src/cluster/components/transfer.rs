//! [`TransferFabric`]: the bandwidth-limited KV-transfer fabric between
//! the prefill and decode pools.
//!
//! Under disaggregated serving
//! ([`ClusterConfig::pools`](crate::config::ClusterConfig) non-empty) the
//! prefill pool runs each prompt to its first token and no further; the
//! fabric is how the request — generated prefix, first-token timestamp,
//! warm-prefix chain and all — reaches the decode pool:
//!
//! 1. **Extraction** (`on_quiescent`): every partially-generated request
//!    on an Active or Draining prefill replica is drained off it
//!    ([`Coordinator::drain_prefilled`](crate::serve::Coordinator::drain_prefilled),
//!    id order) the moment the orchestrator observes it.
//! 2. **Queueing**: each handoff occupies one fabric link for
//!    `resident KV tokens / transfer_bandwidth` seconds, starting when
//!    the earliest-free link frees up (ties to the lowest link index).
//!    A burst of prefill completions therefore drains at
//!    `transfer_links × transfer_bandwidth` aggregate throughput, and a
//!    congested fabric delays deliveries — exactly the serialization a
//!    real interconnect imposes.
//! 3. **Delivery** (`on_event`): the completion is a timed
//!    [`EventPayload::TransferDone`] kernel event, so same-seed runs stay
//!    byte-identical. Delivery routes over the decode pool through the
//!    cluster's dedicated decode router (KV-fit filtered, warm-prefix
//!    probed) and resumes the request via
//!    [`Coordinator::submit_migrated`](crate::serve::Coordinator::submit_migrated)
//!    — the recompute re-prefill the target pays models the
//!    KV-reconstruction work after the wire transfer.
//!
//! While a request rides the fabric it is on *no* replica: its per-replica
//! backlog share is released at extraction and re-booked on the delivery
//! target, while the cluster-wide weighted moments keep carrying it (the
//! autoscaler still owes it capacity). If the decode pool has no routable
//! replica at delivery time (e.g. a full-pool outage), the fabric degrades
//! to delivering anywhere routable rather than losing an admitted request
//! — conservation outranks pool discipline.
//!
//! In colocated mode the component is inert: no links, no extraction, and
//! no `TransferDone` event is ever pushed.

use crate::cluster::ctx::{ClusterCtx, FastPathOutcome, WarmPricing};
use crate::cluster::kernel::{EventPayload, EventQueue, KernelEvent};
use crate::cluster::replica::ReplicaState;
use crate::cluster::router::{FastPath, ReplicaView};
use crate::config::PoolRole;
use crate::metrics::DispatchScope;
use crate::serve::MigratedRequest;

use super::ClusterComponent;

/// The KV-transfer fabric between the prefill and decode pools. See the
/// module docs; built via [`TransferFabric::new`] from
/// [`ClusterConfig`](crate::config::ClusterConfig)'s `transfer_bandwidth`
/// / `transfer_links` knobs.
pub struct TransferFabric {
    /// Earliest instant each link is free (empty in colocated mode, which
    /// turns every hook into a no-op).
    link_free: Vec<f64>,
}

impl TransferFabric {
    pub fn new(cfg: &crate::config::ExperimentConfig) -> TransferFabric {
        let links = if cfg.cluster.disagg() {
            cfg.cluster.transfer_links.max(1)
        } else {
            0
        };
        TransferFabric { link_free: vec![0.0; links] }
    }

    /// KV blocks the handoff needs on its decode target (prompt + prefix
    /// + 1 for the next token — the coordinator's own block math).
    fn blocks_for(m: &MigratedRequest) -> usize {
        ((m.req.input_len + m.generated) as usize + 1)
            .div_ceil(crate::serve::KV_BLOCK_TOKENS)
    }

    /// Queue one handoff on the earliest-free link and schedule its
    /// delivery event. Returns the delivery instant.
    fn enqueue(
        &mut self,
        ctx: &mut ClusterCtx,
        kernel: &mut EventQueue,
        source: usize,
        m: MigratedRequest,
        at: f64,
    ) -> f64 {
        let tokens = (m.req.input_len + m.generated) as u64;
        let delay = tokens as f64 / ctx.cfg.cluster.transfer_bandwidth;
        let link = self
            .link_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
            .map(|(l, _)| l)
            .expect("fabric has at least one link in disagg mode");
        let start = at.max(self.link_free[link]);
        let done = start + delay;
        self.link_free[link] = done;
        ctx.transfer_busy += delay;
        ctx.transfer_log.push((at, done, tokens));
        ctx.in_transfer.insert(m.req.id);
        // the work left its prefill replica: release the per-replica share
        // (the weighted cluster-wide moments keep carrying it — the
        // autoscaler still owes the request capacity while it's on the
        // wire; the delivery books it onto the decode target)
        if let Some(f) = ctx.in_flight.get(&m.req.id) {
            let (cost, var) = (f.cost, f.var);
            ctx.backlog[source] = (ctx.backlog[source] - cost).max(0.0);
            ctx.backlog_var[source] = (ctx.backlog_var[source] - var).max(0.0);
        }
        kernel.push(done, EventPayload::TransferDone(m));
        done
    }

    /// Deliver one completed transfer into the decode pool.
    fn deliver(
        &mut self,
        ctx: &mut ClusterCtx,
        m: MigratedRequest,
        at: f64,
    ) -> anyhow::Result<()> {
        let id = m.req.id;
        let tokens = (m.req.input_len + m.generated) as u64;
        ctx.in_transfer.remove(&id);
        let needed = Self::blocks_for(&m);
        let (pcost, pvar) = match ctx.in_flight.get(&id) {
            Some(f) => (f.cost, f.var),
            None => (0.0, 0.0),
        };
        // fast path: dispatch from the decode-scope index when the
        // per-request KV-fit filter is vacuous there — every in-scope
        // replica holds at least `needed` blocks (the scope min), so the
        // filtered eligible set below would equal the scope exactly — and
        // the scope is non-empty (a populated scope also rules the
        // degraded any-pool fallback out)
        let fp = ctx
            .decode_router
            .as_ref()
            .expect("decode router exists whenever the fabric is live")
            .fast_path(&m.req);
        let mut attempted = false;
        if ctx.use_indexes && fp != FastPath::Rescan {
            if let Some(idx) = ctx.scoped_indexes_mut(Some(PoolRole::Decode)) {
                attempted =
                    !idx.roster().is_empty() && needed <= idx.aggregates().kv_total_min;
            }
        }
        let fast_target = if attempted {
            match fp {
                FastPath::Affinity => ctx.affinity_route(
                    &m.req,
                    pcost,
                    Some(PoolRole::Decode),
                    WarmPricing::Consumed,
                ),
                _ => ctx.index_route(fp, Some(PoolRole::Decode), true),
            }
        } else {
            None
        };
        let target = if let Some(t) = fast_target {
            ctx.count_fastpath(DispatchScope::Decode, FastPathOutcome::Hit);
            t
        } else {
            ctx.count_fastpath(
                DispatchScope::Decode,
                if attempted { FastPathOutcome::Fallback } else { FastPathOutcome::Rescan },
            );
            let fitting = |vs: Vec<ReplicaView>| -> Vec<ReplicaView> {
                vs.into_iter().filter(|v| v.kv_total_blocks >= needed).collect()
            };
            let mut eligible = fitting(ctx.views_for(Some(PoolRole::Decode)));
            if eligible.is_empty() {
                // degraded mode (decode pool down or too small): conservation
                // outranks pool discipline — deliver anywhere routable
                eligible = fitting(ctx.views());
            }
            if eligible.is_empty() {
                anyhow::bail!(
                    "cannot deliver transfer of request {id} at t={at}: no \
                     routable replica can hold its {needed} KV blocks"
                );
            }
            // warm-prefix probing, as every other migration path does: a
            // decode replica already holding this session's shared prefix
            // re-prefills less after the handoff
            if !m.req.prefix_key.is_empty() {
                for v in &mut eligible {
                    let warm = ctx.replicas[v.id]
                        .coord
                        .kv
                        .cached_prefix_tokens(&m.req.prefix_key, m.req.input_len as usize)
                        as u32;
                    if warm > 0 {
                        v.warm_prefix_tokens = warm;
                        v.warm_cost_saving = ctx.cost.consumed(warm, 0);
                    }
                }
            }
            let router = ctx
                .decode_router
                .as_mut()
                .expect("decode router exists whenever the fabric is live");
            let slot = router.route(&m.req, pcost, &eligible);
            if slot >= eligible.len() {
                anyhow::bail!(
                    "decode router {} returned position {slot} but only {} \
                     replicas are eligible",
                    router.name(),
                    eligible.len()
                );
            }
            eligible[slot].id
        };
        // the delivery instant is already ≥ the source clock at extraction
        // (the transfer takes positive time), so the prefix the target
        // resumes cannot predate its own generation
        ctx.replicas[target].coord.advance_to(at);
        // a landing is where prefix caching can begin: keep the warm-site
        // superset invariant the affinity fast path relies on
        ctx.note_warm_site(&m.req, target);
        let accepted = ctx.replicas[target].coord.submit_migrated(m);
        debug_assert!(accepted, "fabric delivery is admission-exempt");
        if accepted {
            if let Some(entry) = ctx.in_flight.get_mut(&id) {
                entry.replica = target;
                ctx.backlog[target] += pcost;
                ctx.backlog_var[target] += pvar;
            }
            ctx.transfers += 1;
            ctx.transfer_tokens += tokens;
            ctx.steal_dirty = true;
        }
        // the advance_to above moved the target's clock even on refusal
        ctx.sync_replica(target);
        Ok(())
    }
}

impl ClusterComponent for TransferFabric {
    fn name(&self) -> &'static str {
        "transfer-fabric"
    }

    fn on_quiescent(
        &mut self,
        ctx: &mut ClusterCtx,
        kernel: &mut EventQueue,
    ) -> anyhow::Result<()> {
        if self.link_free.is_empty() {
            return Ok(()); // colocated: no fabric
        }
        // a partial can only appear through a step/submit on a prefill
        // replica, and every such mutation syncs that replica — which sets
        // the dirty flag. So when nothing prefill-side changed since the
        // last sweep, this scan would extract nothing: skip it.
        if ctx.use_indexes && !ctx.indexes.fabric_dirty {
            return Ok(());
        }
        // index order over replicas, id order within one replica's drain —
        // the whole extraction sequence is deterministic, so link
        // assignment and event seq numbers are too
        for i in 0..ctx.replicas.len() {
            let r = &ctx.replicas[i];
            let steppable =
                matches!(r.state, ReplicaState::Active | ReplicaState::Draining);
            if !steppable || r.pool != Some(PoolRole::Prefill) {
                continue;
            }
            // cheap O(live) gate before partial_meta()'s allocation + sort
            if !r.coord.has_partials() {
                continue;
            }
            let at = r.coord.now();
            let moved = ctx.replicas[i].coord.drain_prefilled();
            for m in moved {
                self.enqueue(ctx, kernel, i, m, at);
            }
            ctx.steal_dirty = true;
            // live set and backlog moved off this prefill replica
            ctx.sync_replica(i);
        }
        // the sweep's own syncs re-dirtied the flag; everything it could
        // observe has been extracted, so clear it until the next
        // prefill-side change
        if ctx.use_indexes {
            ctx.indexes.fabric_dirty = false;
        }
        Ok(())
    }

    fn on_event(
        &mut self,
        ev: KernelEvent,
        ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<Option<KernelEvent>> {
        match ev.payload {
            EventPayload::TransferDone(m) => {
                self.deliver(ctx, m, ev.at)?;
                Ok(None)
            }
            _ => Ok(Some(ev)),
        }
    }
}
