//! The cluster component protocol: one implementation per serving concern.
//!
//! A [`ClusterComponent`] observes the shared [`ClusterCtx`] and talks to
//! the rest of the cluster **through the event kernel**: it pushes
//! [`EventPayload`](crate::cluster::kernel::EventPayload)s in `on_start`,
//! handles the ones it owns in `on_event`, and may act at quiescent points
//! (between events) in `on_quiescent`. Components never call each other;
//! everything they share lives in the context, so adding a concern is a
//! new component, not another branch woven into the orchestrator loop.
//!
//! The protocol, as driven by [`EventCluster`](crate::cluster::EventCluster):
//!
//! 1. `on_start(ctx, kernel)` once per component, in registration order —
//!    validate configuration (fail fast, before any work is done) and push
//!    the initial event schedule.
//! 2. Each popped kernel event is offered to the components in
//!    registration order; `on_event` either consumes it (returns `None`)
//!    or passes it along (returns it back). An event no component consumes
//!    is a hard error — silently dropped events are how schedulers rot.
//! 3. `on_quiescent(ctx, kernel)` for every component at the top of every
//!    loop iteration (the cluster is between events; replicas may be
//!    stepped next). Quiescent work may schedule follow-up events — the
//!    transfer fabric turns each prefill completion it observes into a
//!    timed delivery.
//!
//! Six concerns, six implementations:
//!
//! * [`ArrivalSource`] — feeds the workload's arrival stream into the
//!   kernel and routes each arrival when its event fires.
//! * [`FailureInjector`] — scheduled single-replica outages *and*
//!   correlated failure domains (rack/zone groups that fail as one event,
//!   pooling every member's lost work into a single re-dispatch storm).
//! * [`AutoscaleDriver`] — the autoscaler decision chain: periodic +
//!   scripted decision points, scale-out spawns (provisioning delays as
//!   spawn-ready events), and scale-in victim selection — either the
//!   legacy fewest-live rule or, when `migration_kv_per_token > 0`,
//!   migration-cost-aware scoring over each candidate's predicted
//!   remaining work. Under disaggregation it runs one policy instance per
//!   pool with SLO-aware pool sizing (see [`crate::cluster::disagg`]).
//! * [`WorkStealer`] — quiescent-point migration of never-scheduled queued
//!   work from backlogged replicas to idle ones, gated on transfer cost
//!   (and confined within a pool under disaggregated serving).
//! * [`TransferFabric`] — the disaggregation KV-transfer fabric: drains
//!   prompts that reached first token off the prefill pool, queues them on
//!   bandwidth-limited links, and delivers each as a timed
//!   [`EventPayload::TransferDone`](crate::cluster::kernel::EventPayload)
//!   into the decode pool. Inert in colocated mode.
//! * [`SloAdmission`] — the placement/admission seam. Unlike the others
//!   it owns no timed events: every placement path (fresh arrivals,
//!   crash re-dispatch, scale-in drains) consults it synchronously,
//!   because admission is a per-request verdict, not a scheduled
//!   occurrence. It is registered like any component so the concern has
//!   exactly one home.

mod admission;
mod arrivals;
mod driver;
mod failures;
mod stealing;
mod transfer;

pub use admission::SloAdmission;
pub use arrivals::ArrivalSource;
pub use driver::AutoscaleDriver;
pub use failures::FailureInjector;
pub use stealing::WorkStealer;
pub use transfer::TransferFabric;

use crate::cluster::ctx::ClusterCtx;
use crate::cluster::kernel::{EventQueue, KernelEvent};

/// One serving concern of the event-driven cluster. See the module docs
/// for the protocol; all hooks default to no-ops so a component only
/// implements the phases it participates in.
pub trait ClusterComponent {
    /// Stable name for error messages and docs.
    fn name(&self) -> &'static str;

    /// Called once before the event loop: validate configuration and push
    /// the initial event schedule.
    fn on_start(&mut self, _ctx: &mut ClusterCtx, _kernel: &mut EventQueue) -> anyhow::Result<()> {
        Ok(())
    }

    /// Offered each popped event in registration order. Consume it and
    /// return `Ok(None)`, or hand it back (`Ok(Some(ev))`) for the next
    /// component. Ownership moves with the event so an arrival's
    /// [`Request`](crate::core::Request) payload is never cloned.
    fn on_event(
        &mut self,
        ev: KernelEvent,
        _ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<Option<KernelEvent>> {
        Ok(Some(ev))
    }

    /// Called at the top of every orchestrator iteration, between events.
    /// Gets the kernel so quiescent-point observations can schedule timed
    /// follow-ups (the transfer fabric's bandwidth-delayed deliveries).
    fn on_quiescent(
        &mut self,
        _ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<()> {
        Ok(())
    }
}
