//! [`WorkStealer`]: transfer-cost-gated idle-replica work stealing.

use crate::cluster::ctx::ClusterCtx;
use crate::cluster::kernel::EventQueue;
use crate::config::PoolRole;
use crate::core::RequestId;

use super::ClusterComponent;

/// Idle-replica work stealing at quiescent points: while some routable
/// replica sits idle and another has more than one live request including
/// never-scheduled (queued) ones, migrate up to half of the victim's
/// queued requests to the idle replica. Queued requests hold no KV or
/// engine state, so the only migration cost is shipping the prompt — each
/// candidate is gated on a benefit check: the speed-normalized predicted
/// backlog it stops waiting behind must exceed a transfer penalty
/// proportional to its prompt length
/// (`ClusterConfig::steal_transfer_per_token`; 0 restores unconditional
/// stealing). Rejected candidates are counted in
/// [`ClusterCtx::steals_skipped`]. The thief's clock is advanced to the
/// victim's so no request runs before the moment it was provably
/// stealable. Under disaggregated serving stealing is confined within a
/// pool: a decode replica must not steal never-prefilled prompts (they
/// belong to the prefill pool), and vice versa.
pub struct WorkStealer;

impl ClusterComponent for WorkStealer {
    fn name(&self) -> &'static str {
        "work-stealer"
    }

    fn on_quiescent(
        &mut self,
        ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<()> {
        if !ctx.steal_dirty {
            return Ok(()); // nothing changed since the last fruitless pass
        }
        // the pass below runs to quiescence (it loops until no profitable
        // steal remains), so afterwards only a state change can make a new
        // pass worthwhile — the mutators set the flag again
        ctx.steal_dirty = false;
        // with no idle routable replica there is no thief: the index makes
        // that an O(1) verdict instead of a full roster scan
        if ctx.use_indexes && ctx.indexes.idle_thieves() == 0 {
            debug_assert!(
                !ctx.replicas.iter().any(|r| r.routable() && r.coord.is_idle()),
                "idle-thief index diverged from the roster"
            );
            return Ok(());
        }
        let transfer = ctx.cfg.cluster.steal_transfer_per_token;
        'pass: loop {
            // every idle replica is a candidate thief (lowest index first);
            // under disaggregation an idle thief in one pool must not end
            // the pass for the other pool, so all of them get a turn
            let thieves: Vec<usize> = ctx
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.routable() && r.coord.is_idle())
                .map(|(t, _)| t)
                .collect();
            if thieves.is_empty() {
                return Ok(());
            }
            // one thief per pool: an idle thief's own backlog is ~0, so
            // within a pool the gate verdict is the same for every idle
            // replica — colocated serving (one pool of `None`) keeps its
            // historical single-thief pass
            let mut tried: Vec<Option<PoolRole>> = Vec::new();
            for thief in thieves {
                let pool = ctx.replicas[thief].pool;
                if tried.contains(&pool) {
                    continue;
                }
                tried.push(pool);
                // candidate victims, most-queued first (ties to the lowest
                // index for determinism); later victims are tried when the
                // most-backlogged one has no gate-passing candidate, so a
                // small cheap queue cannot shadow a profitable one
                let mut victims: Vec<(usize, usize)> = ctx
                    .replicas
                    .iter()
                    .enumerate()
                    .filter(|(j, r)| {
                        *j != thief
                            && r.routable()
                            && r.pool == ctx.replicas[thief].pool
                            && r.coord.live_count() >= 2
                    })
                    .map(|(j, r)| (j, r.coord.queued_count()))
                    .filter(|&(_, queued)| queued > 0)
                    .collect();
                victims.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                if victims.is_empty() {
                    continue; // this thief's pool has nothing to steal
                }
                // cap at the thief's admission window (it is idle, so its
                // live set is empty): stolen submissions must never be
                // refused, or a request that was safely queued would count
                // as rejected
                let capacity = match ctx.replicas[thief].coord.max_queue {
                    0 => usize::MAX,
                    cap => cap,
                };
                for (v, v_queued) in victims {
                    let take = v_queued.div_ceil(2).min(capacity);
                    let speed_v = ctx.replicas[v].speed.max(1e-9);
                    let speed_t = ctx.replicas[thief].speed.max(1e-9);
                    // running tallies so each candidate is judged against
                    // the backlog as it would stand after the moves chosen
                    // so far. The benefit is the completion-time delta: the
                    // queue *and own service* it would pay on the victim,
                    // minus the queue it joins plus its own (speed-adjusted)
                    // service on the thief — so shipping work to a much
                    // slower replica is charged for the slower execution,
                    // not just the transfer.
                    let mut backlog_v = ctx.backlog[v];
                    let mut backlog_t = ctx.backlog[thief];
                    let meta = ctx.replicas[v].coord.queued_meta();
                    let mut chosen: Vec<RequestId> = Vec::with_capacity(take);
                    for &(id, input_len, _) in meta.iter().take(take) {
                        let own = ctx.in_flight.get(&id).map(|f| f.cost).unwrap_or(0.0);
                        let benefit = backlog_v / speed_v - (backlog_t + own) / speed_t;
                        // abandoning warm prefix state is a real cost:
                        // tokens cached on the victim but not on the thief
                        // would have to be re-prefilled after the move, so
                        // they join the prompt in the transfer penalty
                        let warm_lost = {
                            let chain = ctx.replicas[v]
                                .coord
                                .queued_request(id)
                                .map(|r| r.prefix_key.clone())
                                .unwrap_or_default();
                            if chain.is_empty() {
                                0
                            } else {
                                let on_victim = ctx.replicas[v]
                                    .coord
                                    .kv
                                    .cached_prefix_tokens(&chain, input_len as usize);
                                let on_thief = ctx.replicas[thief]
                                    .coord
                                    .kv
                                    .cached_prefix_tokens(&chain, input_len as usize);
                                on_victim.saturating_sub(on_thief)
                            }
                        };
                        if transfer > 0.0
                            && benefit <= transfer * (input_len as f64 + warm_lost as f64)
                        {
                            ctx.steal_rejected.insert(id);
                            continue;
                        }
                        chosen.push(id);
                        backlog_v = (backlog_v - own).max(0.0);
                        backlog_t += own;
                    }
                    if chosen.is_empty() {
                        continue; // nothing profitable here: try the next victim
                    }
                    let victim_now = ctx.replicas[v].coord.now();
                    let moved = ctx.replicas[v].coord.drain_ids(&chosen);
                    if moved.is_empty() {
                        return Ok(());
                    }
                    ctx.replicas[thief].coord.advance_to(victim_now);
                    for req in moved {
                        let id = req.id;
                        // a landing is where prefix caching can begin: keep
                        // the warm-site superset invariant the affinity fast
                        // path relies on
                        ctx.note_warm_site(&req, thief);
                        // stealing is a migration: the request already
                        // passed admission on the victim, so the thief must
                        // not re-apply (class-aware) admission and refuse it
                        let accepted = ctx.replicas[thief].coord.submit_exempt(req);
                        debug_assert!(accepted, "idle thief must accept within its window");
                        if !accepted {
                            continue;
                        }
                        ctx.stolen += 1;
                        if let Some(entry) = ctx.in_flight.get_mut(&id) {
                            let (pcost, pvar) = (entry.cost, entry.var);
                            let from = entry.replica;
                            entry.replica = thief;
                            ctx.backlog[from] = (ctx.backlog[from] - pcost).max(0.0);
                            ctx.backlog_var[from] = (ctx.backlog_var[from] - pvar).max(0.0);
                            ctx.backlog[thief] += pcost;
                            ctx.backlog_var[thief] += pvar;
                        }
                    }
                    // clocks, live sets, and backlogs moved on both sides
                    ctx.sync_replica(v);
                    ctx.sync_replica(thief);
                    // the thief is busy now; look for another idle replica
                    continue 'pass;
                }
                // no victim offered this thief a profitable steal. An idle
                // thief's own backlog is ~0, so within its pool the verdict
                // would be the same for every other idle replica of any
                // speed: move on to thieves in other pools.
            }
            // every idle thief came up empty: stop the pass
            return Ok(());
        }
    }
}
