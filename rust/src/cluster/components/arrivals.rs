//! [`ArrivalSource`]: the workload's arrival stream as kernel events.

use crate::cluster::ctx::ClusterCtx;
use crate::cluster::kernel::{EventPayload, EventQueue, KernelEvent};
use crate::core::Request;

use super::ClusterComponent;

/// Feeds the workload into the kernel: every request becomes an `Arrival`
/// event (pushed in (arrival, id) order, so the kernel's insertion-order
/// tie-break reproduces the exact legacy arrival interleaving), and each
/// arrival is routed through [`SloAdmission`](super::SloAdmission) when
/// its event fires.
pub struct ArrivalSource {
    requests: Vec<Request>,
}

impl ArrivalSource {
    pub fn new(requests: Vec<Request>) -> ArrivalSource {
        ArrivalSource { requests }
    }
}

impl ClusterComponent for ArrivalSource {
    fn name(&self) -> &'static str {
        "arrival-source"
    }

    fn on_start(&mut self, _ctx: &mut ClusterCtx, kernel: &mut EventQueue) -> anyhow::Result<()> {
        let mut requests = std::mem::take(&mut self.requests);
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        for req in requests {
            let at = req.arrival;
            kernel.push(at, EventPayload::Arrival(req));
        }
        Ok(())
    }

    fn on_event(
        &mut self,
        ev: KernelEvent,
        ctx: &mut ClusterCtx,
        _kernel: &mut EventQueue,
    ) -> anyhow::Result<Option<KernelEvent>> {
        match ev.payload {
            EventPayload::Arrival(req) => {
                let at = ev.at;
                ctx.dispatch(req, at)?;
                Ok(None)
            }
            _ => Ok(Some(ev)),
        }
    }
}
