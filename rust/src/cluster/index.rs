//! Incrementally-maintained router score indexes.
//!
//! The scored routers (`least-loaded`, `least-kv`, `cost-aware`,
//! `quantile-cost`) historically re-scored every routable replica on every
//! dispatch — an O(replicas) rescan in the hottest path of the cluster
//! kernel. [`RouterIndexes`] replaces those rescans with per-metric
//! lazy-deletion min-heaps that are updated from `ClusterCtx` deltas
//! (dispatch, completion, failure, drain, scale events), so a dispatch
//! costs O(log replicas) amortized.
//!
//! Routers whose score carries a *per-request* term (cache-affinity
//! warmth, the class-aware Interactive placement) are served by two
//! extensions on top of the replica-keyed heaps:
//!
//! * **Pure conditional metrics.** The class-aware Interactive score
//!   (`TightQuantile`) and its KV-headroom-filtered variant
//!   (`TightHeadroom`) are replica-keyed once the request class is known,
//!   so they get ordinary heaps plus a maintained count of
//!   headroom-eligible replicas to pick between them.
//! * **Shortlist + dominance bound.** Scores of the form
//!   `base(replica) − adjustment(replica, request)` with a bounded
//!   adjustment (cache-affinity warm savings) are resolved by
//!   [`RouterIndexes::shortlist`]: pop the top-K candidates by base
//!   score, let the caller apply the exact per-request adjustment to only
//!   those, and return the best *non-candidate* base score so the caller
//!   can prove no replica outside the shortlist can win — falling back to
//!   the full rescan when the bound fails.
//!
//! # Determinism invariant
//!
//! **Index order must equal `argmin` rescan order, exactly.** The routers
//! pick the *first* strict minimum over views sorted ascending by replica
//! id (`router::argmin` uses `<`, so ties go to the lowest id). The heaps
//! reproduce that order with a key of `(score, id)` under
//! `f64::total_cmp`: equal scores order by ascending id, and the popped
//! minimum is exactly the replica the rescan would have chosen. Two
//! consequences the implementation must respect:
//!
//! * **No NaN keys.** `total_cmp` orders NaN, `<` never matches it; the
//!   score expressions here replicate the routers' arithmetic
//!   operation-for-operation, which is NaN-free by construction (divisors
//!   are clamped, variances floored at zero).
//! * **`-0.0` is canonicalized to `+0.0`** (`canon`). `total_cmp` orders
//!   `-0.0 < +0.0`, but the rescan's `<` treats them as equal (tie → the
//!   lowest id). Canonicalizing at keying time makes the heap agree with
//!   the rescan on such ties.
//!
//! # Lazy deletion
//!
//! Heap entries are never removed in place. Each replica keeps a current
//! `Probe` snapshot; an entry popped off a heap is valid only if the
//! replica is still a member of that heap and the entry's key equals the
//! replica's current score — otherwise it is stale and discarded. Stale
//! entries are bounded by compaction: when a heap grows past 4x the
//! replica count (and past a small floor) it is rebuilt from the probe
//! snapshots, keeping the amortized cost O(log replicas) per update.
//!
//! Each instance covers exactly one dispatch scope. The intake instance
//! (all replicas colocated, the prefill pool under disaggregation) serves
//! fresh admission; under disaggregation a second instance scoped to the
//! decode pool serves the transfer fabric's delivery router, drain
//! re-admission, and migration target selection. Both are synced in
//! lockstep from the same `ClusterCtx::sync_replica` delta seam. The
//! retained rescan code doubles as the differential oracle when
//! `ClusterCtx::use_indexes` is false.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::PoolRole;

use super::replica::ReplicaState;

/// The scored-router metrics that have an incremental index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Live request count (`least-loaded`).
    Live,
    /// KV-cache utilization fraction (`least-kv`).
    Kv,
    /// Mean backlog over speed (`cost-aware`).
    Cost,
    /// Quantile backlog over speed (`quantile-cost`).
    Quantile,
    /// Tight-quantile backlog over speed (class-aware Interactive
    /// placement), unfiltered — the fallback pool when no replica has
    /// KV headroom.
    TightQuantile,
    /// Same score as [`Metric::TightQuantile`] but membership also
    /// requires KV occupancy at or under the class-aware headroom bound.
    TightHeadroom,
}

impl Metric {
    pub(crate) const ALL: [Metric; 6] = [
        Metric::Live,
        Metric::Kv,
        Metric::Cost,
        Metric::Quantile,
        Metric::TightQuantile,
        Metric::TightHeadroom,
    ];

    /// Heap slot for this metric.
    fn index(self) -> usize {
        match self {
            Metric::Live => 0,
            Metric::Kv => 1,
            Metric::Cost => 2,
            Metric::Quantile => 3,
            Metric::TightQuantile => 4,
            Metric::TightHeadroom => 5,
        }
    }

    /// Score slot: `TightHeadroom` shares `TightQuantile`'s score, the
    /// two heaps differ only in membership.
    fn score_index(self) -> usize {
        match self {
            Metric::TightHeadroom => 4,
            m => m.index(),
        }
    }
}

/// Snapshot of the per-replica fields the indexes derive scores from.
/// Built by `ClusterCtx::sample_of` and fed through [`RouterIndexes::sync`]
/// whenever a replica changes.
#[derive(Clone, Copy)]
pub(crate) struct Sample {
    pub(crate) state: ReplicaState,
    pub(crate) pool: Option<PoolRole>,
    pub(crate) is_idle: bool,
    pub(crate) now: f64,
    pub(crate) live: usize,
    pub(crate) kv_used_blocks: usize,
    pub(crate) kv_total_blocks: usize,
    pub(crate) speed: f64,
    pub(crate) backlog: f64,
    pub(crate) backlog_var: f64,
}

/// Current derived state of one replica: scope membership, busy/idle
/// standing, clock, headroom eligibility, raw capacity fields for the
/// scope aggregates, and the metric scores. Heap entries are validated
/// against this snapshot (lazy deletion).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Probe {
    in_scope: bool,
    busy: bool,
    idle_thief: bool,
    /// KV occupancy at or under the class-aware headroom bound.
    headroom: bool,
    now: f64,
    /// Raw speed (aggregate input; scores already fold in the divisor
    /// clamp).
    speed: f64,
    /// Raw KV capacity in blocks (aggregate input for fit filters).
    kv_total: usize,
    scores: [f64; 5],
}

/// Scope-wide reductions over in-scope replicas, recomputed lazily when a
/// membership/speed/capacity delta lands. Fit filters and the shortlist
/// dominance bound consult these instead of rescanning.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct Aggregates {
    /// `max(speed.max(1e-9))` over in-scope replicas; `0.0` when the
    /// scope is empty.
    pub(crate) speed_max: f64,
    /// Min KV capacity (blocks) over in-scope replicas; `usize::MAX`
    /// when empty. A per-request fit filter `kv_total >= needed` is
    /// vacuous iff `needed <= kv_total_min`.
    pub(crate) kv_total_min: usize,
    /// Max KV capacity (blocks) over in-scope replicas; `0` when empty.
    pub(crate) kv_total_max: usize,
}

/// `(key, id)` heap entry. `Ord` is reversed (BinaryHeap is a max-heap) so
/// the top is the minimum key, ties broken by the **lowest** id — the
/// exact `argmin` rescan order.
struct ScoreEntry {
    key: f64,
    id: usize,
}

impl PartialEq for ScoreEntry {
    fn eq(&self, other: &ScoreEntry) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ScoreEntry {}

impl PartialOrd for ScoreEntry {
    fn partial_cmp(&self, other: &ScoreEntry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ScoreEntry {
    fn cmp(&self, other: &ScoreEntry) -> Ordering {
        other
            .key
            .total_cmp(&self.key)
            .then(other.id.cmp(&self.id))
    }
}

/// Canonicalize `-0.0` to `+0.0` so `total_cmp` agrees with the rescan's
/// `<` on zero-valued ties (see the module docs).
pub(crate) fn canon(x: f64) -> f64 {
    if x == 0.0 {
        0.0
    } else {
        x
    }
}

/// The incremental index set for one dispatch scope.
pub struct RouterIndexes {
    /// The indexed dispatch scope: `None` colocated, `Some(pool)` for one
    /// pool under disaggregation. Fixed for the run.
    scope: Option<PoolRole>,
    /// z-score the quantile metric is keyed with; a router carrying a
    /// different z falls back to the rescan path.
    quantile_z: f64,
    /// z-score the tight-quantile (class-aware Interactive) metrics are
    /// keyed with.
    tight_z: f64,
    /// KV-occupancy bound for [`Metric::TightHeadroom`] membership.
    kv_headroom: f64,
    /// Per-replica derived snapshots, indexed by replica id.
    probes: Vec<Probe>,
    /// One lazy-deletion min-heap per [`Metric`].
    score_heaps: [BinaryHeap<ScoreEntry>; 6],
    /// Min-heap over busy replicas' clocks (`earliest_busy`).
    busy_heap: BinaryHeap<ScoreEntry>,
    /// Ascending ids of in-scope replicas (round-robin roster), rebuilt
    /// lazily when membership changes.
    roster: Vec<usize>,
    roster_dirty: bool,
    /// Count of routable idle replicas (work-stealer early exit).
    idle_thieves: usize,
    /// Count of in-scope replicas with KV headroom — decides whether the
    /// class-aware Interactive placement uses the filtered heap or the
    /// full-scope fallback.
    headroom_count: usize,
    /// Scope aggregates, recomputed lazily (see [`Aggregates`]).
    agg: Aggregates,
    agg_dirty: bool,
    /// Scratch for [`RouterIndexes::shortlist`] pops (avoids per-dispatch
    /// allocation).
    scratch: Vec<ScoreEntry>,
    /// Set when a prefill-side replica changed since the transfer fabric
    /// last swept; lets the fabric skip quiescent scans with no new
    /// partials.
    pub(crate) fabric_dirty: bool,
}

impl RouterIndexes {
    pub(crate) fn new(
        scope: Option<PoolRole>,
        quantile_z: f64,
        tight_z: f64,
        kv_headroom: f64,
    ) -> RouterIndexes {
        RouterIndexes {
            scope,
            quantile_z,
            tight_z,
            kv_headroom,
            probes: Vec::new(),
            score_heaps: Default::default(),
            busy_heap: BinaryHeap::new(),
            roster: Vec::new(),
            roster_dirty: false,
            idle_thieves: 0,
            headroom_count: 0,
            agg: Aggregates { speed_max: 0.0, kv_total_min: usize::MAX, kv_total_max: 0 },
            agg_dirty: false,
            scratch: Vec::new(),
            fabric_dirty: true,
        }
    }

    pub(crate) fn quantile_z(&self) -> f64 {
        self.quantile_z
    }

    pub(crate) fn tight_z(&self) -> f64 {
        self.tight_z
    }

    pub(crate) fn idle_thieves(&self) -> usize {
        self.idle_thieves
    }

    /// In-scope replicas currently under the KV-headroom bound.
    pub(crate) fn headroom_count(&self) -> usize {
        self.headroom_count
    }

    /// Whether replica `id` is currently inside this index's scope.
    pub(crate) fn in_scope(&self, id: usize) -> bool {
        self.probes.get(id).is_some_and(|p| p.in_scope)
    }

    /// Heap membership for `m`: in scope, plus the KV-headroom bound for
    /// [`Metric::TightHeadroom`].
    fn member(p: &Probe, m: Metric) -> bool {
        p.in_scope && (m != Metric::TightHeadroom || p.headroom)
    }

    fn probe_of(&self, s: &Sample) -> Probe {
        let in_scope =
            s.state == ReplicaState::Active && (self.scope.is_none() || s.pool == self.scope);
        let busy = matches!(s.state, ReplicaState::Active | ReplicaState::Draining) && !s.is_idle;
        let idle_thief = s.state == ReplicaState::Active && s.is_idle;
        // score arithmetic replicated operation-for-operation from the
        // routers (see the module docs' determinism invariant)
        let kv = if s.kv_total_blocks == 0 {
            0.0
        } else {
            s.kv_used_blocks as f64 / s.kv_total_blocks as f64
        };
        let cost = s.backlog / s.speed.max(1e-9);
        let q = s.backlog + self.quantile_z * s.backlog_var.max(0.0).sqrt();
        let quant = q / s.speed.max(1e-9);
        let tq = s.backlog + self.tight_z * s.backlog_var.max(0.0).sqrt();
        let tight = tq / s.speed.max(1e-9);
        Probe {
            in_scope,
            busy,
            idle_thief,
            headroom: kv <= self.kv_headroom,
            now: canon(s.now),
            speed: s.speed,
            kv_total: s.kv_total_blocks,
            scores: [canon(s.live as f64), canon(kv), canon(cost), canon(quant), canon(tight)],
        }
    }

    /// Register a freshly-appended replica (id = current probe count).
    pub(crate) fn add_replica(&mut self, s: &Sample) {
        let id = self.probes.len();
        let p = self.probe_of(s);
        for m in Metric::ALL {
            if Self::member(&p, m) {
                self.push_score(m, ScoreEntry { key: p.scores[m.score_index()], id });
            }
        }
        if p.in_scope {
            self.roster_dirty = true;
            self.agg_dirty = true;
        }
        if Self::member(&p, Metric::TightHeadroom) {
            self.headroom_count += 1;
        }
        if p.busy {
            self.push_busy(ScoreEntry { key: p.now, id });
        }
        if p.idle_thief {
            self.idle_thieves += 1;
        }
        if s.pool == Some(PoolRole::Prefill) {
            self.fabric_dirty = true;
        }
        self.probes.push(p);
    }

    /// Refresh replica `i` from a new sample, pushing heap entries for any
    /// changed keys. Stale old entries are left behind (lazy deletion).
    pub(crate) fn sync(&mut self, i: usize, s: &Sample) {
        let p = self.probe_of(s);
        let old = self.probes[i];
        if p == old {
            return;
        }
        if p.in_scope != old.in_scope {
            self.roster_dirty = true;
        }
        if p.in_scope != old.in_scope
            || (p.in_scope && (p.speed != old.speed || p.kv_total != old.kv_total))
        {
            self.agg_dirty = true;
        }
        for m in Metric::ALL {
            let si = m.score_index();
            let was = Self::member(&old, m);
            let is = Self::member(&p, m);
            if is && (!was || p.scores[si] != old.scores[si]) {
                self.push_score(m, ScoreEntry { key: p.scores[si], id: i });
            }
        }
        match (
            Self::member(&old, Metric::TightHeadroom),
            Self::member(&p, Metric::TightHeadroom),
        ) {
            (false, true) => self.headroom_count += 1,
            (true, false) => self.headroom_count -= 1,
            _ => {}
        }
        if p.busy && (!old.busy || p.now != old.now) {
            self.push_busy(ScoreEntry { key: p.now, id: i });
        }
        match (old.idle_thief, p.idle_thief) {
            (false, true) => self.idle_thieves += 1,
            (true, false) => self.idle_thieves -= 1,
            _ => {}
        }
        if s.pool == Some(PoolRole::Prefill) {
            self.fabric_dirty = true;
        }
        self.probes[i] = p;
    }

    /// The member replica with the minimum score for `m` (ties → lowest
    /// id), or `None` when the heap's membership is empty. Pops stale
    /// entries.
    pub(crate) fn best(&mut self, m: Metric) -> Option<usize> {
        let h = m.index();
        let si = m.score_index();
        while let Some(top) = self.score_heaps[h].peek() {
            let p = &self.probes[top.id];
            if Self::member(p, m) && p.scores[si] == top.key {
                return Some(top.id);
            }
            self.score_heaps[h].pop();
        }
        None
    }

    /// Top-`k` member replicas by `m`'s base score, in ascending
    /// `(score, id)` order, appended to `out` — skipping ids for which
    /// `is_extra` holds (the caller already has those as candidates, they
    /// must not consume shortlist slots nor be reported as the runner-up).
    /// Returns the best non-extra `(base_score, id)` *outside* the
    /// shortlist, or `None` when the shortlist (plus extras) exhausts the
    /// scope. Every valid popped entry is pushed back, so the heap
    /// invariant (each member has a valid entry) is preserved; duplicate
    /// valid entries encountered along the way are dropped (free
    /// compaction).
    pub(crate) fn shortlist(
        &mut self,
        m: Metric,
        k: usize,
        is_extra: impl Fn(usize) -> bool,
        out: &mut Vec<usize>,
    ) -> Option<(f64, usize)> {
        let h = m.index();
        let si = m.score_index();
        let mut next: Option<(f64, usize)> = None;
        let mut picked = 0usize;
        self.scratch.clear();
        while let Some(top) = self.score_heaps[h].pop() {
            let p = &self.probes[top.id];
            if !(Self::member(p, m) && p.scores[si] == top.key) {
                continue; // stale: lazy deletion
            }
            if self.scratch.iter().any(|e| e.id == top.id) {
                continue; // duplicate valid entry: keep one copy only
            }
            if !is_extra(top.id) {
                if picked >= k {
                    next = Some((top.key, top.id));
                    self.scratch.push(top);
                    break;
                }
                picked += 1;
                out.push(top.id);
            }
            self.scratch.push(top);
        }
        let entries = std::mem::take(&mut self.scratch);
        for e in entries {
            self.score_heaps[h].push(e);
        }
        next
    }

    /// Scope aggregates (speed max, KV capacity min/max), recomputed if a
    /// relevant delta landed since the last call.
    pub(crate) fn aggregates(&mut self) -> Aggregates {
        if self.agg_dirty {
            let mut agg =
                Aggregates { speed_max: 0.0, kv_total_min: usize::MAX, kv_total_max: 0 };
            for p in self.probes.iter().filter(|p| p.in_scope) {
                agg.speed_max = agg.speed_max.max(p.speed.max(1e-9));
                agg.kv_total_min = agg.kv_total_min.min(p.kv_total);
                agg.kv_total_max = agg.kv_total_max.max(p.kv_total);
            }
            self.agg = agg;
            self.agg_dirty = false;
        }
        self.agg
    }

    /// The busy replica with the earliest clock (ties → lowest id), or
    /// `None` when everything is idle. Pops stale entries.
    pub(crate) fn earliest_busy(&mut self) -> Option<(usize, f64)> {
        while let Some(top) = self.busy_heap.peek() {
            let p = &self.probes[top.id];
            if p.busy && p.now == top.key {
                return Some((top.id, top.key));
            }
            self.busy_heap.pop();
        }
        None
    }

    /// Ascending ids of in-scope replicas (the round-robin roster).
    pub(crate) fn roster(&mut self) -> &[usize] {
        if self.roster_dirty {
            self.roster.clear();
            self.roster
                .extend(self.probes.iter().enumerate().filter(|(_, p)| p.in_scope).map(|(i, _)| i));
            self.roster_dirty = false;
        }
        &self.roster
    }

    fn push_score(&mut self, m: Metric, e: ScoreEntry) {
        let h = m.index();
        self.score_heaps[h].push(e);
        if self.score_heaps[h].len() > 64 && self.score_heaps[h].len() > 4 * self.probes.len() {
            let si = m.score_index();
            let rebuilt: BinaryHeap<ScoreEntry> = self
                .probes
                .iter()
                .enumerate()
                .filter(|(_, p)| Self::member(p, m))
                .map(|(id, p)| ScoreEntry { key: p.scores[si], id })
                .collect();
            self.score_heaps[h] = rebuilt;
        }
    }

    fn push_busy(&mut self, e: ScoreEntry) {
        self.busy_heap.push(e);
        if self.busy_heap.len() > 64 && self.busy_heap.len() > 4 * self.probes.len() {
            let rebuilt: BinaryHeap<ScoreEntry> = self
                .probes
                .iter()
                .enumerate()
                .filter(|(_, p)| p.busy)
                .map(|(id, p)| ScoreEntry { key: p.now, id })
                .collect();
            self.busy_heap = rebuilt;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const TIGHT_Z: f64 = 1.6448536269514722;
    const HEADROOM: f64 = 0.85;

    fn index(scope: Option<PoolRole>, z: f64) -> RouterIndexes {
        RouterIndexes::new(scope, z, TIGHT_Z, HEADROOM)
    }

    fn sample(state: ReplicaState, pool: Option<PoolRole>) -> Sample {
        Sample {
            state,
            pool,
            is_idle: true,
            now: 0.0,
            live: 0,
            kv_used_blocks: 0,
            kv_total_blocks: 100,
            speed: 1.0,
            backlog: 0.0,
            backlog_var: 0.0,
        }
    }

    /// Rescan-oracle score of one sample, mirroring the router arithmetic.
    fn score_of(z: f64, s: &Sample, m: Metric) -> f64 {
        match m {
            Metric::Live => s.live as f64,
            Metric::Kv => {
                if s.kv_total_blocks == 0 {
                    0.0
                } else {
                    s.kv_used_blocks as f64 / s.kv_total_blocks as f64
                }
            }
            Metric::Cost => s.backlog / s.speed.max(1e-9),
            Metric::Quantile => {
                (s.backlog + z * s.backlog_var.max(0.0).sqrt()) / s.speed.max(1e-9)
            }
            Metric::TightQuantile | Metric::TightHeadroom => {
                (s.backlog + TIGHT_Z * s.backlog_var.max(0.0).sqrt()) / s.speed.max(1e-9)
            }
        }
    }

    fn member_of(scope: Option<PoolRole>, s: &Sample, m: Metric) -> bool {
        let in_scope = s.state == ReplicaState::Active && (scope.is_none() || s.pool == scope);
        let kv = score_of(0.0, s, Metric::Kv);
        in_scope && (m != Metric::TightHeadroom || kv <= HEADROOM)
    }

    /// Naive strict-`<` argmin over member samples — the rescan oracle.
    fn naive_best(z: f64, scope: Option<PoolRole>, samples: &[Sample], m: Metric) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in samples.iter().enumerate() {
            if !member_of(scope, s, m) {
                continue;
            }
            let sc = score_of(z, s, m);
            if best.map_or(true, |(_, b)| sc < b) {
                best = Some((i, sc));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Naive shortlist oracle: members sorted ascending `(canon(score), id)`,
    /// extras skipped; first `k` non-extras plus the `(k+1)`-th as runner-up.
    fn naive_shortlist(
        z: f64,
        scope: Option<PoolRole>,
        samples: &[Sample],
        m: Metric,
        k: usize,
        extras: &[usize],
    ) -> (Vec<usize>, Option<(f64, usize)>) {
        let mut members: Vec<(f64, usize)> = samples
            .iter()
            .enumerate()
            .filter(|(_, s)| member_of(scope, s, m))
            .map(|(i, s)| (canon(score_of(z, s, m)), i))
            .collect();
        members.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut out = Vec::new();
        let mut next = None;
        for (sc, i) in members {
            if extras.contains(&i) {
                continue;
            }
            if out.len() < k {
                out.push(i);
            } else {
                next = Some((sc, i));
                break;
            }
        }
        (out, next)
    }

    #[test]
    fn ties_go_to_the_lowest_id() {
        let z = 1.2815515655446004;
        let mut idx = index(None, z);
        for _ in 0..4 {
            idx.add_replica(&sample(ReplicaState::Active, None));
        }
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(0), "{m:?} tie must go to the lowest id");
        }
        // raise replica 0's scores; the tie among 1..4 must go to 1
        let mut s = sample(ReplicaState::Active, None);
        s.live = 5;
        s.kv_used_blocks = 50;
        s.backlog = 3.0;
        idx.sync(0, &s);
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(1), "{m:?} tie must go to the lowest id");
        }
    }

    #[test]
    fn canon_folds_negative_zero() {
        assert_eq!(canon(-0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(canon(1.5), 1.5);
        assert_eq!(canon(-1.5), -1.5);
    }

    #[test]
    fn busy_heap_ties_go_to_the_lowest_id() {
        let mut idx = index(None, 0.0);
        for _ in 0..3 {
            let mut s = sample(ReplicaState::Active, None);
            s.is_idle = false;
            s.now = 2.0;
            idx.add_replica(&s);
        }
        assert_eq!(idx.earliest_busy(), Some((0, 2.0)));
        let mut s = sample(ReplicaState::Active, None);
        s.is_idle = false;
        s.now = 5.0;
        idx.sync(0, &s);
        assert_eq!(idx.earliest_busy(), Some((1, 2.0)));
    }

    #[test]
    fn out_of_scope_replicas_are_invisible() {
        let mut idx = index(Some(PoolRole::Prefill), 0.0);
        idx.add_replica(&sample(ReplicaState::Active, Some(PoolRole::Decode)));
        idx.add_replica(&sample(ReplicaState::Active, Some(PoolRole::Prefill)));
        idx.add_replica(&sample(ReplicaState::Draining, Some(PoolRole::Prefill)));
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(1));
        }
        assert_eq!(idx.roster(), &[1]);
    }

    #[test]
    fn headroom_heap_excludes_hot_replicas() {
        let mut idx = index(None, 0.0);
        // replica 0: over the headroom bound but lower tight score
        let mut s = sample(ReplicaState::Active, None);
        s.kv_used_blocks = 90; // occupancy 0.9 > 0.85
        idx.add_replica(&s);
        let mut s = sample(ReplicaState::Active, None);
        s.backlog = 5.0;
        idx.add_replica(&s);
        assert_eq!(idx.best(Metric::TightQuantile), Some(0));
        assert_eq!(idx.best(Metric::TightHeadroom), Some(1));
        assert_eq!(idx.headroom_count(), 1);
        // cool replica 0 back under the bound
        let mut s = sample(ReplicaState::Active, None);
        s.kv_used_blocks = 10;
        idx.sync(0, &s);
        assert_eq!(idx.best(Metric::TightHeadroom), Some(0));
        assert_eq!(idx.headroom_count(), 2);
    }

    #[test]
    fn shortlist_skips_extras_and_reports_runner_up() {
        let mut idx = index(None, 0.0);
        for b in [4.0, 1.0, 3.0, 2.0, 5.0] {
            let mut s = sample(ReplicaState::Active, None);
            s.backlog = b;
            idx.add_replica(&s);
        }
        // base order by Cost: 1 (1.0), 3 (2.0), 2 (3.0), 0 (4.0), 4 (5.0)
        let mut out = Vec::new();
        let next = idx.shortlist(Metric::Cost, 2, |id| id == 3, &mut out);
        assert_eq!(out, vec![1, 2], "extras must not consume shortlist slots");
        assert_eq!(next, Some((4.0, 0)));
        // the pops must not have corrupted the heap
        assert_eq!(idx.best(Metric::Cost), Some(1));
        let mut out = Vec::new();
        let next = idx.shortlist(Metric::Cost, 10, |_| false, &mut out);
        assert_eq!(out, vec![1, 3, 2, 0, 4]);
        assert_eq!(next, None, "shortlist covering the scope has no runner-up");
    }

    /// Random delta interleavings: after every sync the index must agree
    /// with the rescan oracle *and* with a rebuilt-from-scratch index, for
    /// both intake scopes.
    #[test]
    fn random_deltas_match_rescan_and_rebuild() {
        for (case, scope) in [(0u64, None), (1u64, Some(PoolRole::Prefill))] {
            let z = 1.2815515655446004;
            let mut rng = Rng::new(0xD17A + case);
            let n = 10usize;
            let mut samples: Vec<Sample> = (0..n)
                .map(|i| {
                    let pool = match scope {
                        None => None,
                        Some(_) => Some(if i % 2 == 0 {
                            PoolRole::Prefill
                        } else {
                            PoolRole::Decode
                        }),
                    };
                    sample(ReplicaState::Active, pool)
                })
                .collect();
            let mut idx = index(scope, z);
            for s in &samples {
                idx.add_replica(s);
            }
            for step in 0..2000 {
                let i = rng.below(samples.len() as u64) as usize;
                let s = &mut samples[i];
                match rng.below(9) {
                    0 => {
                        s.state = match rng.below(4) {
                            0 => ReplicaState::Active,
                            1 => ReplicaState::Draining,
                            2 => ReplicaState::Down,
                            _ => ReplicaState::Provisioning,
                        };
                    }
                    1 => s.is_idle = !s.is_idle,
                    2 => s.now += rng.below(100) as f64 / 10.0,
                    3 => s.live = rng.below(40) as usize,
                    4 => s.backlog = rng.below(1000) as f64 / 7.0,
                    5 => s.backlog_var = rng.below(500) as f64 / 3.0,
                    6 => s.kv_used_blocks = rng.below(100) as usize,
                    7 => s.kv_total_blocks = 50 + rng.below(100) as usize,
                    _ => s.speed = 0.25 + rng.below(8) as f64 / 4.0,
                }
                let snap = samples[i];
                idx.sync(i, &snap);
                if step % 50 == 0 {
                    // occasionally grow the fleet, like a scale-out spawn
                    let pool = match scope {
                        None => None,
                        Some(p) => Some(p),
                    };
                    let fresh = sample(ReplicaState::Provisioning, pool);
                    samples.push(fresh);
                    idx.add_replica(&fresh);
                }
                // oracle checks
                for m in Metric::ALL {
                    assert_eq!(
                        idx.best(m),
                        naive_best(z, scope, &samples, m),
                        "metric {m:?} diverged at step {step}"
                    );
                }
                let naive_busy = samples
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        matches!(s.state, ReplicaState::Active | ReplicaState::Draining)
                            && !s.is_idle
                    })
                    .map(|(i, s)| (i, canon(s.now)))
                    .fold(None::<(usize, f64)>, |best, (i, t)| {
                        if best.map_or(true, |(_, bt)| t < bt) {
                            Some((i, t))
                        } else {
                            best
                        }
                    });
                assert_eq!(idx.earliest_busy(), naive_busy, "busy diverged at step {step}");
                let naive_thieves = samples
                    .iter()
                    .filter(|s| s.state == ReplicaState::Active && s.is_idle)
                    .count();
                assert_eq!(idx.idle_thieves(), naive_thieves, "thieves diverged at step {step}");
                let naive_headroom = samples
                    .iter()
                    .filter(|s| member_of(scope, s, Metric::TightHeadroom))
                    .count();
                assert_eq!(
                    idx.headroom_count(),
                    naive_headroom,
                    "headroom count diverged at step {step}"
                );
                let naive_roster: Vec<usize> = samples
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        s.state == ReplicaState::Active
                            && (scope.is_none() || s.pool == scope)
                    })
                    .map(|(i, _)| i)
                    .collect();
                assert_eq!(idx.roster(), naive_roster.as_slice(), "roster diverged at step {step}");
                // aggregates oracle
                let mut naive_agg =
                    Aggregates { speed_max: 0.0, kv_total_min: usize::MAX, kv_total_max: 0 };
                for (_, s) in samples.iter().enumerate().filter(|(i, _)| naive_roster.contains(i))
                {
                    naive_agg.speed_max = naive_agg.speed_max.max(s.speed.max(1e-9));
                    naive_agg.kv_total_min = naive_agg.kv_total_min.min(s.kv_total_blocks);
                    naive_agg.kv_total_max = naive_agg.kv_total_max.max(s.kv_total_blocks);
                }
                assert_eq!(idx.aggregates(), naive_agg, "aggregates diverged at step {step}");
                // shortlist oracle (random k and extras)
                let k = 1 + rng.below(4) as usize;
                let extras: Vec<usize> = (0..samples.len())
                    .filter(|_| rng.below(8) == 0)
                    .collect();
                let mut got = Vec::new();
                let got_next =
                    idx.shortlist(Metric::Cost, k, |id| extras.contains(&id), &mut got);
                let (want, want_next) =
                    naive_shortlist(z, scope, &samples, Metric::Cost, k, &extras);
                assert_eq!(got, want, "shortlist diverged at step {step}");
                assert_eq!(got_next, want_next, "shortlist runner-up diverged at step {step}");
                // rebuild-from-scratch must agree with the incremental state
                if step % 100 == 0 {
                    let mut rebuilt = index(scope, z);
                    for s in &samples {
                        rebuilt.add_replica(s);
                    }
                    for m in Metric::ALL {
                        assert_eq!(idx.best(m), rebuilt.best(m));
                    }
                    assert_eq!(idx.earliest_busy(), rebuilt.earliest_busy());
                    assert_eq!(idx.idle_thieves(), rebuilt.idle_thieves());
                    assert_eq!(idx.headroom_count(), rebuilt.headroom_count());
                    assert_eq!(idx.roster(), rebuilt.roster());
                    assert_eq!(idx.aggregates(), rebuilt.aggregates());
                }
            }
        }
    }
}
