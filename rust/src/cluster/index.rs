//! Incrementally-maintained router score indexes.
//!
//! The scored routers (`least-loaded`, `least-kv`, `cost-aware`,
//! `quantile-cost`) historically re-scored every routable replica on every
//! dispatch — an O(replicas) rescan in the hottest path of the cluster
//! kernel. [`RouterIndexes`] replaces those rescans with per-metric
//! lazy-deletion min-heaps that are updated from `ClusterCtx` deltas
//! (dispatch, completion, failure, drain, scale events), so a dispatch
//! costs O(log replicas) amortized.
//!
//! # Determinism invariant
//!
//! **Index order must equal `argmin` rescan order, exactly.** The routers
//! pick the *first* strict minimum over views sorted ascending by replica
//! id (`router::argmin` uses `<`, so ties go to the lowest id). The heaps
//! reproduce that order with a key of `(score, id)` under
//! `f64::total_cmp`: equal scores order by ascending id, and the popped
//! minimum is exactly the replica the rescan would have chosen. Two
//! consequences the implementation must respect:
//!
//! * **No NaN keys.** `total_cmp` orders NaN, `<` never matches it; the
//!   score expressions here replicate the routers' arithmetic
//!   operation-for-operation, which is NaN-free by construction (divisors
//!   are clamped, variances floored at zero).
//! * **`-0.0` is canonicalized to `+0.0`** (`canon`). `total_cmp` orders
//!   `-0.0 < +0.0`, but the rescan's `<` treats them as equal (tie → the
//!   lowest id). Canonicalizing at keying time makes the heap agree with
//!   the rescan on such ties.
//!
//! # Lazy deletion
//!
//! Heap entries are never removed in place. Each replica keeps a current
//! `Probe` snapshot; an entry popped off a heap is valid only if the
//! replica is still in scope and the entry's key equals the replica's
//! current score — otherwise it is stale and discarded. Stale entries are
//! bounded by compaction: when a heap grows past 4x the replica count (and
//! past a small floor) it is rebuilt from the probe snapshots, keeping the
//! amortized cost O(log replicas) per update.
//!
//! The indexes cover exactly one scope — the intake pool (all replicas
//! colocated, the prefill pool under disaggregation) — because that is the
//! only scope dispatch-rate-hot paths query. Cold paths (drain
//! re-admission, migration, autoscale views) keep the retained rescan
//! code, which doubles as the differential oracle when
//! `ClusterCtx::use_indexes` is false.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::PoolRole;

use super::replica::ReplicaState;

/// The scored-router metrics that have an incremental index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Live request count (`least-loaded`).
    Live,
    /// KV-cache utilization fraction (`least-kv`).
    Kv,
    /// Mean backlog over speed (`cost-aware`).
    Cost,
    /// Quantile backlog over speed (`quantile-cost`).
    Quantile,
}

impl Metric {
    pub(crate) const ALL: [Metric; 4] = [Metric::Live, Metric::Kv, Metric::Cost, Metric::Quantile];

    fn index(self) -> usize {
        match self {
            Metric::Live => 0,
            Metric::Kv => 1,
            Metric::Cost => 2,
            Metric::Quantile => 3,
        }
    }
}

/// Snapshot of the per-replica fields the indexes derive scores from.
/// Built by `ClusterCtx::sample_of` and fed through [`RouterIndexes::sync`]
/// whenever a replica changes.
#[derive(Clone, Copy)]
pub(crate) struct Sample {
    pub(crate) state: ReplicaState,
    pub(crate) pool: Option<PoolRole>,
    pub(crate) is_idle: bool,
    pub(crate) now: f64,
    pub(crate) live: usize,
    pub(crate) kv_used_blocks: usize,
    pub(crate) kv_total_blocks: usize,
    pub(crate) speed: f64,
    pub(crate) backlog: f64,
    pub(crate) backlog_var: f64,
}

/// Current derived state of one replica: scope membership, busy/idle
/// standing, clock, and the four metric scores. Heap entries are validated
/// against this snapshot (lazy deletion).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Probe {
    in_scope: bool,
    busy: bool,
    idle_thief: bool,
    now: f64,
    scores: [f64; 4],
}

/// `(key, id)` heap entry. `Ord` is reversed (BinaryHeap is a max-heap) so
/// the top is the minimum key, ties broken by the **lowest** id — the
/// exact `argmin` rescan order.
struct ScoreEntry {
    key: f64,
    id: usize,
}

impl PartialEq for ScoreEntry {
    fn eq(&self, other: &ScoreEntry) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ScoreEntry {}

impl PartialOrd for ScoreEntry {
    fn partial_cmp(&self, other: &ScoreEntry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ScoreEntry {
    fn cmp(&self, other: &ScoreEntry) -> Ordering {
        other
            .key
            .total_cmp(&self.key)
            .then(other.id.cmp(&self.id))
    }
}

/// Canonicalize `-0.0` to `+0.0` so `total_cmp` agrees with the rescan's
/// `<` on zero-valued ties (see the module docs).
fn canon(x: f64) -> f64 {
    if x == 0.0 {
        0.0
    } else {
        x
    }
}

/// The incremental index set for one cluster run.
pub struct RouterIndexes {
    /// The indexed dispatch scope: `None` colocated, `Some(Prefill)` under
    /// disaggregation. Fixed for the run.
    intake: Option<PoolRole>,
    /// z-score the quantile metric is keyed with; a router carrying a
    /// different z falls back to the rescan path.
    quantile_z: f64,
    /// Per-replica derived snapshots, indexed by replica id.
    probes: Vec<Probe>,
    /// One lazy-deletion min-heap per [`Metric`].
    score_heaps: [BinaryHeap<ScoreEntry>; 4],
    /// Min-heap over busy replicas' clocks (`earliest_busy`).
    busy_heap: BinaryHeap<ScoreEntry>,
    /// Ascending ids of in-scope replicas (round-robin roster), rebuilt
    /// lazily when membership changes.
    roster: Vec<usize>,
    roster_dirty: bool,
    /// Count of routable idle replicas (work-stealer early exit).
    idle_thieves: usize,
    /// Set when a prefill-side replica changed since the transfer fabric
    /// last swept; lets the fabric skip quiescent scans with no new
    /// partials.
    pub(crate) fabric_dirty: bool,
}

impl RouterIndexes {
    pub(crate) fn new(intake: Option<PoolRole>, quantile_z: f64) -> RouterIndexes {
        RouterIndexes {
            intake,
            quantile_z,
            probes: Vec::new(),
            score_heaps: Default::default(),
            busy_heap: BinaryHeap::new(),
            roster: Vec::new(),
            roster_dirty: false,
            idle_thieves: 0,
            fabric_dirty: true,
        }
    }

    pub(crate) fn quantile_z(&self) -> f64 {
        self.quantile_z
    }

    pub(crate) fn idle_thieves(&self) -> usize {
        self.idle_thieves
    }

    fn probe_of(&self, s: &Sample) -> Probe {
        let in_scope =
            s.state == ReplicaState::Active && (self.intake.is_none() || s.pool == self.intake);
        let busy = matches!(s.state, ReplicaState::Active | ReplicaState::Draining) && !s.is_idle;
        let idle_thief = s.state == ReplicaState::Active && s.is_idle;
        // score arithmetic replicated operation-for-operation from the
        // routers (see the module docs' determinism invariant)
        let kv = if s.kv_total_blocks == 0 {
            0.0
        } else {
            s.kv_used_blocks as f64 / s.kv_total_blocks as f64
        };
        let cost = s.backlog / s.speed.max(1e-9);
        let q = s.backlog + self.quantile_z * s.backlog_var.max(0.0).sqrt();
        let quant = q / s.speed.max(1e-9);
        Probe {
            in_scope,
            busy,
            idle_thief,
            now: canon(s.now),
            scores: [canon(s.live as f64), canon(kv), canon(cost), canon(quant)],
        }
    }

    /// Register a freshly-appended replica (id = current probe count).
    pub(crate) fn add_replica(&mut self, s: &Sample) {
        let id = self.probes.len();
        let p = self.probe_of(s);
        if p.in_scope {
            for m in Metric::ALL {
                self.push_score(m.index(), ScoreEntry { key: p.scores[m.index()], id });
            }
            self.roster_dirty = true;
        }
        if p.busy {
            self.push_busy(ScoreEntry { key: p.now, id });
        }
        if p.idle_thief {
            self.idle_thieves += 1;
        }
        if s.pool == Some(PoolRole::Prefill) {
            self.fabric_dirty = true;
        }
        self.probes.push(p);
    }

    /// Refresh replica `i` from a new sample, pushing heap entries for any
    /// changed keys. Stale old entries are left behind (lazy deletion).
    pub(crate) fn sync(&mut self, i: usize, s: &Sample) {
        let p = self.probe_of(s);
        let old = self.probes[i];
        if p == old {
            return;
        }
        if p.in_scope != old.in_scope {
            self.roster_dirty = true;
        }
        for m in Metric::ALL {
            let k = m.index();
            let newly_in = p.in_scope && !old.in_scope;
            if p.in_scope && (newly_in || p.scores[k] != old.scores[k]) {
                self.push_score(k, ScoreEntry { key: p.scores[k], id: i });
            }
        }
        if p.busy && (!old.busy || p.now != old.now) {
            self.push_busy(ScoreEntry { key: p.now, id: i });
        }
        match (old.idle_thief, p.idle_thief) {
            (false, true) => self.idle_thieves += 1,
            (true, false) => self.idle_thieves -= 1,
            _ => {}
        }
        if s.pool == Some(PoolRole::Prefill) {
            self.fabric_dirty = true;
        }
        self.probes[i] = p;
    }

    /// The in-scope replica with the minimum score for `m` (ties → lowest
    /// id), or `None` when the scope is empty. Pops stale entries.
    pub(crate) fn best(&mut self, m: Metric) -> Option<usize> {
        let k = m.index();
        while let Some(top) = self.score_heaps[k].peek() {
            let p = &self.probes[top.id];
            if p.in_scope && p.scores[k] == top.key {
                return Some(top.id);
            }
            self.score_heaps[k].pop();
        }
        None
    }

    /// The busy replica with the earliest clock (ties → lowest id), or
    /// `None` when everything is idle. Pops stale entries.
    pub(crate) fn earliest_busy(&mut self) -> Option<(usize, f64)> {
        while let Some(top) = self.busy_heap.peek() {
            let p = &self.probes[top.id];
            if p.busy && p.now == top.key {
                return Some((top.id, top.key));
            }
            self.busy_heap.pop();
        }
        None
    }

    /// Ascending ids of in-scope replicas (the round-robin roster).
    pub(crate) fn roster(&mut self) -> &[usize] {
        if self.roster_dirty {
            self.roster.clear();
            self.roster
                .extend(self.probes.iter().enumerate().filter(|(_, p)| p.in_scope).map(|(i, _)| i));
            self.roster_dirty = false;
        }
        &self.roster
    }

    fn push_score(&mut self, k: usize, e: ScoreEntry) {
        self.score_heaps[k].push(e);
        if self.score_heaps[k].len() > 64 && self.score_heaps[k].len() > 4 * self.probes.len() {
            let rebuilt: BinaryHeap<ScoreEntry> = self
                .probes
                .iter()
                .enumerate()
                .filter(|(_, p)| p.in_scope)
                .map(|(id, p)| ScoreEntry { key: p.scores[k], id })
                .collect();
            self.score_heaps[k] = rebuilt;
        }
    }

    fn push_busy(&mut self, e: ScoreEntry) {
        self.busy_heap.push(e);
        if self.busy_heap.len() > 64 && self.busy_heap.len() > 4 * self.probes.len() {
            let rebuilt: BinaryHeap<ScoreEntry> = self
                .probes
                .iter()
                .enumerate()
                .filter(|(_, p)| p.busy)
                .map(|(id, p)| ScoreEntry { key: p.now, id })
                .collect();
            self.busy_heap = rebuilt;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(state: ReplicaState, pool: Option<PoolRole>) -> Sample {
        Sample {
            state,
            pool,
            is_idle: true,
            now: 0.0,
            live: 0,
            kv_used_blocks: 0,
            kv_total_blocks: 100,
            speed: 1.0,
            backlog: 0.0,
            backlog_var: 0.0,
        }
    }

    /// Rescan-oracle score of one sample, mirroring the router arithmetic.
    fn score_of(z: f64, s: &Sample, m: Metric) -> f64 {
        match m {
            Metric::Live => s.live as f64,
            Metric::Kv => {
                if s.kv_total_blocks == 0 {
                    0.0
                } else {
                    s.kv_used_blocks as f64 / s.kv_total_blocks as f64
                }
            }
            Metric::Cost => s.backlog / s.speed.max(1e-9),
            Metric::Quantile => {
                (s.backlog + z * s.backlog_var.max(0.0).sqrt()) / s.speed.max(1e-9)
            }
        }
    }

    /// Naive strict-`<` argmin over in-scope samples — the rescan oracle.
    fn naive_best(z: f64, intake: Option<PoolRole>, samples: &[Sample], m: Metric) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in samples.iter().enumerate() {
            let in_scope =
                s.state == ReplicaState::Active && (intake.is_none() || s.pool == intake);
            if !in_scope {
                continue;
            }
            let sc = score_of(z, s, m);
            if best.map_or(true, |(_, b)| sc < b) {
                best = Some((i, sc));
            }
        }
        best.map(|(i, _)| i)
    }

    #[test]
    fn ties_go_to_the_lowest_id() {
        let z = 1.2815515655446004;
        let mut idx = RouterIndexes::new(None, z);
        for _ in 0..4 {
            idx.add_replica(&sample(ReplicaState::Active, None));
        }
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(0), "{m:?} tie must go to the lowest id");
        }
        // raise replica 0's scores; the tie among 1..4 must go to 1
        let mut s = sample(ReplicaState::Active, None);
        s.live = 5;
        s.kv_used_blocks = 50;
        s.backlog = 3.0;
        idx.sync(0, &s);
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(1), "{m:?} tie must go to the lowest id");
        }
    }

    #[test]
    fn canon_folds_negative_zero() {
        assert_eq!(canon(-0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(canon(1.5), 1.5);
        assert_eq!(canon(-1.5), -1.5);
    }

    #[test]
    fn busy_heap_ties_go_to_the_lowest_id() {
        let mut idx = RouterIndexes::new(None, 0.0);
        for _ in 0..3 {
            let mut s = sample(ReplicaState::Active, None);
            s.is_idle = false;
            s.now = 2.0;
            idx.add_replica(&s);
        }
        assert_eq!(idx.earliest_busy(), Some((0, 2.0)));
        let mut s = sample(ReplicaState::Active, None);
        s.is_idle = false;
        s.now = 5.0;
        idx.sync(0, &s);
        assert_eq!(idx.earliest_busy(), Some((1, 2.0)));
    }

    #[test]
    fn out_of_scope_replicas_are_invisible() {
        let mut idx = RouterIndexes::new(Some(PoolRole::Prefill), 0.0);
        idx.add_replica(&sample(ReplicaState::Active, Some(PoolRole::Decode)));
        idx.add_replica(&sample(ReplicaState::Active, Some(PoolRole::Prefill)));
        idx.add_replica(&sample(ReplicaState::Draining, Some(PoolRole::Prefill)));
        for m in Metric::ALL {
            assert_eq!(idx.best(m), Some(1));
        }
        assert_eq!(idx.roster(), &[1]);
    }

    /// Random delta interleavings: after every sync the index must agree
    /// with the rescan oracle *and* with a rebuilt-from-scratch index, for
    /// both intake scopes.
    #[test]
    fn random_deltas_match_rescan_and_rebuild() {
        for (case, intake) in [(0u64, None), (1u64, Some(PoolRole::Prefill))] {
            let z = 1.2815515655446004;
            let mut rng = Rng::new(0xD17A + case);
            let n = 10usize;
            let mut samples: Vec<Sample> = (0..n)
                .map(|i| {
                    let pool = match intake {
                        None => None,
                        Some(_) => Some(if i % 2 == 0 {
                            PoolRole::Prefill
                        } else {
                            PoolRole::Decode
                        }),
                    };
                    sample(ReplicaState::Active, pool)
                })
                .collect();
            let mut idx = RouterIndexes::new(intake, z);
            for s in &samples {
                idx.add_replica(s);
            }
            for step in 0..2000 {
                let i = rng.below(samples.len() as u64) as usize;
                let s = &mut samples[i];
                match rng.below(8) {
                    0 => {
                        s.state = match rng.below(4) {
                            0 => ReplicaState::Active,
                            1 => ReplicaState::Draining,
                            2 => ReplicaState::Down,
                            _ => ReplicaState::Provisioning,
                        };
                    }
                    1 => s.is_idle = !s.is_idle,
                    2 => s.now += rng.below(100) as f64 / 10.0,
                    3 => s.live = rng.below(40) as usize,
                    4 => s.backlog = rng.below(1000) as f64 / 7.0,
                    5 => s.backlog_var = rng.below(500) as f64 / 3.0,
                    6 => s.kv_used_blocks = rng.below(100) as usize,
                    _ => s.speed = 0.25 + rng.below(8) as f64 / 4.0,
                }
                let snap = samples[i];
                idx.sync(i, &snap);
                if step % 50 == 0 {
                    // occasionally grow the fleet, like a scale-out spawn
                    let pool = match intake {
                        None => None,
                        Some(p) => Some(p),
                    };
                    let fresh = sample(ReplicaState::Provisioning, pool);
                    samples.push(fresh);
                    idx.add_replica(&fresh);
                }
                // oracle checks
                for m in Metric::ALL {
                    assert_eq!(
                        idx.best(m),
                        naive_best(z, intake, &samples, m),
                        "metric {m:?} diverged at step {step}"
                    );
                }
                let naive_busy = samples
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        matches!(s.state, ReplicaState::Active | ReplicaState::Draining)
                            && !s.is_idle
                    })
                    .map(|(i, s)| (i, canon(s.now)))
                    .fold(None::<(usize, f64)>, |best, (i, t)| {
                        if best.map_or(true, |(_, bt)| t < bt) {
                            Some((i, t))
                        } else {
                            best
                        }
                    });
                assert_eq!(idx.earliest_busy(), naive_busy, "busy diverged at step {step}");
                let naive_thieves = samples
                    .iter()
                    .filter(|s| s.state == ReplicaState::Active && s.is_idle)
                    .count();
                assert_eq!(idx.idle_thieves(), naive_thieves, "thieves diverged at step {step}");
                let naive_roster: Vec<usize> = samples
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        s.state == ReplicaState::Active
                            && (intake.is_none() || s.pool == intake)
                    })
                    .map(|(i, _)| i)
                    .collect();
                assert_eq!(idx.roster(), naive_roster.as_slice(), "roster diverged at step {step}");
                // rebuild-from-scratch must agree with the incremental state
                if step % 100 == 0 {
                    let mut rebuilt = RouterIndexes::new(intake, z);
                    for s in &samples {
                        rebuilt.add_replica(s);
                    }
                    for m in Metric::ALL {
                        assert_eq!(idx.best(m), rebuilt.best(m));
                    }
                    assert_eq!(idx.earliest_busy(), rebuilt.earliest_busy());
                    assert_eq!(idx.idle_thieves(), rebuilt.idle_thieves());
                    assert_eq!(idx.roster(), rebuilt.roster());
                }
            }
        }
    }
}
