//! Replica lifecycle: the per-replica serving unit and its state machine.
//!
//! A [`ClusterReplica`] owns one full [`Coordinator`]`<`[`SimEngine`]`>`
//! (continuous batching, KV accounting, preemption) plus the cluster-side
//! lifecycle bookkeeping: its [`ReplicaState`], outage spans, provisioning
//! instants, and how much of its history has already been reconciled into
//! cluster-level counters. State transitions themselves are driven by the
//! components in [`crate::cluster::components`] through
//! [`ClusterCtx`](crate::cluster::ClusterCtx) — this module only defines
//! what a replica *is*, not when it changes.

use crate::config::PoolRole;
use crate::core::{Request, RequestId};
use crate::engine::SimEngine;
use crate::serve::Coordinator;

/// Lifecycle state of one replica inside the event-driven cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Spawned by a scale-out decision, still inside its provisioning
    /// delay: not routable, holds no work.
    Provisioning,
    /// Routable and serving.
    Active,
    /// Failed: not routable, holds no work (drained at failure time), will
    /// rejoin at its recovery event.
    Down,
    /// Scale-in victim: not routable, queued work already re-routed,
    /// finishing its running/preempted requests in place.
    Draining,
    /// Retired for good (scale-in complete, or failed while draining).
    Retired,
}

/// One serving replica inside the event-driven cluster.
pub struct ClusterReplica {
    pub coord: Coordinator<SimEngine>,
    /// Speed multiplier this replica was built with.
    pub speed: f64,
    /// Lifecycle state; only [`ReplicaState::Active`] replicas are
    /// routable, only Active/Draining ones can hold live work.
    pub state: ReplicaState,
    /// Pool role under disaggregated serving (`None` = colocated: the
    /// replica runs both phases). Fixed at spawn; a replica never changes
    /// pools.
    pub pool: Option<PoolRole>,
    /// Virtual time the current outage began (meaningful while Down).
    pub(crate) down_since: f64,
    /// Accumulated downtime over completed outages (seconds).
    pub downtime: f64,
    /// Virtual time this replica was provisioned (0 for the initial fleet).
    pub spawned_at: f64,
    /// Virtual time this replica's provisioning delay elapses (0 for the
    /// initial fleet, which starts Active). A recovery before this instant
    /// resumes provisioning rather than activating the replica early.
    pub(crate) ready_at: f64,
    /// Virtual time the replica retired, if it did.
    pub retired_at: Option<f64>,
    /// Outcomes already drained into cluster-level bookkeeping.
    pub(crate) seen_outcomes: usize,
    /// Timeout-aborts already reconciled into cluster-level bookkeeping.
    pub(crate) seen_aborted: u64,
}

impl ClusterReplica {
    /// Whether routers may send new work here.
    pub fn routable(&self) -> bool {
        self.state == ReplicaState::Active
    }

    /// Provisioned lifetime up to `horizon`, excluding downtime — the
    /// replica-seconds this replica is charged for. A replica added or
    /// retired mid-run is charged only for its [spawned_at, retired_at)
    /// span; an outage still open at `horizon` is charged to `horizon`.
    pub fn replica_seconds(&self, horizon: f64) -> f64 {
        let end = self.retired_at.unwrap_or(horizon);
        let open_outage = if self.state == ReplicaState::Down {
            (end - self.down_since).max(0.0)
        } else {
            0.0
        };
        (end - self.spawned_at - self.downtime - open_outage).max(0.0)
    }
}

/// Cluster-side bookkeeping for one in-flight request: where it was routed
/// and the first two moments of its predicted cost distribution.
pub(crate) struct InFlight {
    pub(crate) replica: usize,
    /// Predicted E[total cost] (cost-model units).
    pub(crate) cost: f64,
    /// Predicted Var[total cost].
    pub(crate) var: f64,
    /// SLO weight of this request's class (1.0 under class-blind serving);
    /// scales its contribution to the weighted forecast backlog the
    /// uncertainty-aware autoscaler provisions for.
    pub(crate) weight: f64,
    /// Rank score the shared predictor assigned at placement time (larger =
    /// longer expected output); paired with the realised output length at
    /// completion to score the shared predictor's ordering quality.
    pub(crate) rank: f64,
    /// Original request (kept for re-dispatch and predictor learning).
    pub(crate) req: Request,
}

/// Slab-backed in-flight table: `RequestId -> InFlight` with slot
/// recycling. The hot dispatch/completion path inserts and removes one
/// entry per request; a plain `HashMap<RequestId, InFlight>` pays an
/// allocation (and eventual rehash churn) per insert, while the slab
/// reuses freed slots via a free list and only the small id→slot index
/// rehashes. Iteration order is arbitrary — callers that need determinism
/// must sort, exactly as they did with the `HashMap` it replaced.
#[derive(Default)]
pub(crate) struct InFlightTable {
    slots: Vec<Option<InFlight>>,
    free: Vec<u32>,
    index: std::collections::HashMap<RequestId, u32>,
}

impl InFlightTable {
    pub(crate) fn len(&self) -> usize {
        self.index.len()
    }

    pub(crate) fn insert(&mut self, id: RequestId, f: InFlight) {
        if let Some(&slot) = self.index.get(&id) {
            self.slots[slot as usize] = Some(f);
            return;
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize] = Some(f);
                s
            }
            None => {
                self.slots.push(Some(f));
                (self.slots.len() - 1) as u32
            }
        };
        self.index.insert(id, slot);
    }

    pub(crate) fn remove(&mut self, id: &RequestId) -> Option<InFlight> {
        let slot = self.index.remove(id)?;
        self.free.push(slot);
        self.slots[slot as usize].take()
    }

    pub(crate) fn get(&self, id: &RequestId) -> Option<&InFlight> {
        let slot = *self.index.get(id)?;
        self.slots[slot as usize].as_ref()
    }

    pub(crate) fn get_mut(&mut self, id: &RequestId) -> Option<&mut InFlight> {
        let slot = *self.index.get(id)?;
        self.slots[slot as usize].as_mut()
    }

    /// Iterate the live request ids in arbitrary order (callers sort).
    pub(crate) fn keys(&self) -> impl Iterator<Item = &RequestId> {
        self.index.keys()
    }

    /// Iterate `(id, entry)` pairs in arbitrary order (callers sort).
    pub(crate) fn iter(&self) -> impl Iterator<Item = (&RequestId, &InFlight)> {
        self.index.iter().map(|(id, &slot)| {
            (
                id,
                self.slots[slot as usize]
                    .as_ref()
                    .expect("indexed slot is occupied"),
            )
        })
    }
}
