//! Replica lifecycle: the per-replica serving unit and its state machine.
//!
//! A [`ClusterReplica`] owns one full [`Coordinator`]`<`[`SimEngine`]`>`
//! (continuous batching, KV accounting, preemption) plus the cluster-side
//! lifecycle bookkeeping: its [`ReplicaState`], outage spans, provisioning
//! instants, and how much of its history has already been reconciled into
//! cluster-level counters. State transitions themselves are driven by the
//! components in [`crate::cluster::components`] through
//! [`ClusterCtx`](crate::cluster::ClusterCtx) — this module only defines
//! what a replica *is*, not when it changes.

use crate::config::PoolRole;
use crate::core::Request;
use crate::engine::SimEngine;
use crate::serve::Coordinator;

/// Lifecycle state of one replica inside the event-driven cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Spawned by a scale-out decision, still inside its provisioning
    /// delay: not routable, holds no work.
    Provisioning,
    /// Routable and serving.
    Active,
    /// Failed: not routable, holds no work (drained at failure time), will
    /// rejoin at its recovery event.
    Down,
    /// Scale-in victim: not routable, queued work already re-routed,
    /// finishing its running/preempted requests in place.
    Draining,
    /// Retired for good (scale-in complete, or failed while draining).
    Retired,
}

/// One serving replica inside the event-driven cluster.
pub struct ClusterReplica {
    pub coord: Coordinator<SimEngine>,
    /// Speed multiplier this replica was built with.
    pub speed: f64,
    /// Lifecycle state; only [`ReplicaState::Active`] replicas are
    /// routable, only Active/Draining ones can hold live work.
    pub state: ReplicaState,
    /// Pool role under disaggregated serving (`None` = colocated: the
    /// replica runs both phases). Fixed at spawn; a replica never changes
    /// pools.
    pub pool: Option<PoolRole>,
    /// Virtual time the current outage began (meaningful while Down).
    pub(crate) down_since: f64,
    /// Accumulated downtime over completed outages (seconds).
    pub downtime: f64,
    /// Virtual time this replica was provisioned (0 for the initial fleet).
    pub spawned_at: f64,
    /// Virtual time this replica's provisioning delay elapses (0 for the
    /// initial fleet, which starts Active). A recovery before this instant
    /// resumes provisioning rather than activating the replica early.
    pub(crate) ready_at: f64,
    /// Virtual time the replica retired, if it did.
    pub retired_at: Option<f64>,
    /// Outcomes already drained into cluster-level bookkeeping.
    pub(crate) seen_outcomes: usize,
    /// Timeout-aborts already reconciled into cluster-level bookkeeping.
    pub(crate) seen_aborted: u64,
}

impl ClusterReplica {
    /// Whether routers may send new work here.
    pub fn routable(&self) -> bool {
        self.state == ReplicaState::Active
    }

    /// Provisioned lifetime up to `horizon`, excluding downtime — the
    /// replica-seconds this replica is charged for. A replica added or
    /// retired mid-run is charged only for its [spawned_at, retired_at)
    /// span; an outage still open at `horizon` is charged to `horizon`.
    pub fn replica_seconds(&self, horizon: f64) -> f64 {
        let end = self.retired_at.unwrap_or(horizon);
        let open_outage = if self.state == ReplicaState::Down {
            (end - self.down_since).max(0.0)
        } else {
            0.0
        };
        (end - self.spawned_at - self.downtime - open_outage).max(0.0)
    }
}

/// Cluster-side bookkeeping for one in-flight request: where it was routed
/// and the first two moments of its predicted cost distribution.
pub(crate) struct InFlight {
    pub(crate) replica: usize,
    /// Predicted E[total cost] (cost-model units).
    pub(crate) cost: f64,
    /// Predicted Var[total cost].
    pub(crate) var: f64,
    /// SLO weight of this request's class (1.0 under class-blind serving);
    /// scales its contribution to the weighted forecast backlog the
    /// uncertainty-aware autoscaler provisions for.
    pub(crate) weight: f64,
    /// Rank score the shared predictor assigned at placement time (larger =
    /// longer expected output); paired with the realised output length at
    /// completion to score the shared predictor's ordering quality.
    pub(crate) rank: f64,
    /// Original request (kept for re-dispatch and predictor learning).
    pub(crate) req: Request,
}
