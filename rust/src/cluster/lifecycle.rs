//! Replica lifecycle + scale-in mechanics of [`ClusterCtx`].
//!
//! A second `impl ClusterCtx` block (the state itself lives in
//! [`crate::cluster::ctx`]): taking replicas down and re-dispatching the
//! lost work, recovery and provisioning completion, the autoscaler's
//! snapshot/spawn/drain/retire mechanism, and migration-cost-aware
//! scale-in — pricing a quantile of each partially-generated request's
//! predicted remaining cost against its KV transfer cost, both when
//! *choosing* the victim and when *draining* it. The components in
//! [`crate::cluster::components`] decide when these mechanics fire.

use crate::autoscale::{AutoscaleView, ScaleAction, ScalingEvent};
use crate::config::PoolRole;
use crate::core::{Request, RequestId};
use crate::metrics::DispatchScope;
use crate::util::stats::normal_quantile_clamped;

use super::components::SloAdmission;
use super::ctx::{ClusterCtx, FastPathOutcome, WarmPricing};
use super::replica::{ClusterReplica, ReplicaState};
use super::router::{FastPath, ReplicaView};

impl ClusterCtx {
    /// Take replica `i` down at `at`, returning the live requests it lost
    /// (crash semantics: queued, running, and preempted state is gone) with
    /// their cluster-side bookkeeping already released. Shared by
    /// single-replica and domain outages — the *caller* re-dispatches the
    /// returned work, so a domain outage can pool the losses of every
    /// member and route the whole storm over the true survivor set.
    ///
    /// A replica that was already draining for scale-in retires on the spot
    /// (it was leaving anyway; the crash just lost the work it was
    /// finishing). A replica still *provisioning* goes down holding no
    /// work: if the outage ends before the provisioning delay would have,
    /// the recovery resumes provisioning and the pending spawn-ready event
    /// still activates it exactly on schedule; if the outage outlasts the
    /// delay, the spawn-ready no-ops while down and the recovery activates
    /// it. Either way an outage can only delay, never advance, the instant
    /// capacity arrives. Failures on retired or already-down replicas are
    /// no-ops; one naming a replica that was never provisioned is a hard
    /// configuration error.
    pub(crate) fn fail_replica(&mut self, i: usize, at: f64) -> anyhow::Result<Vec<Request>> {
        if i >= self.replicas.len() {
            anyhow::bail!(
                "failure event at t={at} references replica {i}, but only \
                 {} replicas have been provisioned by then",
                self.replicas.len()
            );
        }
        let was_draining = match self.replicas[i].state {
            ReplicaState::Active => false,
            ReplicaState::Draining => true,
            ReplicaState::Provisioning => {
                self.replicas[i].coord.advance_to(at);
                self.record(at, i, ScaleAction::Fail);
                self.replicas[i].state = ReplicaState::Down;
                self.replicas[i].down_since = at;
                self.sync_replica(i);
                return Ok(Vec::new());
            }
            _ => return Ok(Vec::new()),
        };
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Fail);
        self.steal_dirty = true;
        if was_draining {
            self.retire(i, at);
        } else {
            self.replicas[i].state = ReplicaState::Down;
            self.replicas[i].down_since = at;
        }
        let lost = self.replicas[i].coord.drain_live();
        for req in &lost {
            if let Some(f) = self.in_flight.remove(&req.id) {
                debug_assert_eq!(f.replica, i, "in-flight map out of sync at failure");
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
            }
        }
        self.sync_replica(i);
        Ok(lost)
    }

    /// Re-dispatch work lost to an outage through the router over the
    /// survivors, in deterministic (arrival, id) order.
    pub(crate) fn redispatch(&mut self, mut lost: Vec<Request>, at: f64) -> anyhow::Result<()> {
        lost.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        self.re_routed += lost.len() as u64;
        for req in lost {
            self.dispatch(req, at)?;
        }
        Ok(())
    }

    /// A scheduled outage ends: the (empty) replica rejoins the routable
    /// set and its downtime is charged. A replica whose provisioning was
    /// interrupted by the outage — recovery lands before its `ready_at` —
    /// *resumes* provisioning instead: the still-pending spawn-ready event
    /// brings it up at the originally scheduled instant, so an outage can
    /// never hand the cluster capacity earlier than the provisioning delay
    /// allows. Replicas that retired while down stay retired.
    pub(crate) fn apply_recovery(&mut self, i: usize, at: f64) {
        if self.replicas[i].state != ReplicaState::Down {
            return;
        }
        self.replicas[i].downtime += at - self.replicas[i].down_since;
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Recover);
        if at < self.replicas[i].ready_at {
            self.replicas[i].state = ReplicaState::Provisioning;
            self.sync_replica(i);
            return;
        }
        self.replicas[i].state = ReplicaState::Active;
        self.steal_dirty = true; // a fresh idle thief just appeared
        self.sync_replica(i);
    }

    /// A provisioning delay elapsed: the cold replica joins the routable
    /// set.
    pub(crate) fn apply_spawn_ready(&mut self, i: usize, at: f64) {
        if self.replicas[i].state != ReplicaState::Provisioning {
            return;
        }
        self.replicas[i].state = ReplicaState::Active;
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Up);
        self.steal_dirty = true; // a fresh idle thief just appeared
        self.sync_replica(i);
    }

    /// Snapshot the cluster for the autoscaler.
    pub(crate) fn autoscale_view(&self, now: f64) -> AutoscaleView {
        let mut active = 0;
        let mut provisioning = 0;
        let mut down = 0;
        let mut draining = 0;
        let mut total_live = 0;
        let mut total_queued = 0;
        let mut occ_sum = 0.0;
        for r in &self.replicas {
            match r.state {
                ReplicaState::Active => {
                    active += 1;
                    total_live += r.coord.live_count();
                    total_queued += r.coord.queued_count();
                    let total = r.coord.kv.total_blocks();
                    if total > 0 {
                        occ_sum += r.coord.kv.used_blocks() as f64 / total as f64;
                    }
                }
                ReplicaState::Provisioning => provisioning += 1,
                ReplicaState::Down => down += 1,
                ReplicaState::Draining => draining += 1,
                ReplicaState::Retired => {}
            }
        }
        let mean_kv_occupancy = if active > 0 {
            occ_sum / active as f64
        } else {
            0.0
        };
        AutoscaleView {
            now,
            active,
            provisioning,
            down,
            draining,
            total_live,
            total_queued,
            mean_kv_occupancy,
            backlog_mean: self.backlog.iter().sum(),
            backlog_var: self.backlog_var.iter().sum(),
            backlog_weighted_mean: self.backlog_weighted,
            backlog_weighted_var: self.backlog_weighted_var,
        }
    }

    /// Append a fresh cold replica in the Provisioning state, joining pool
    /// `pool` (`None` under colocated serving). Heterogeneity vectors keep
    /// cycling at the new index, and the replica gets its own
    /// deterministic seed, so elastic runs stay exactly reproducible.
    pub(crate) fn spawn_replica(&mut self, now: f64, pool: Option<PoolRole>) -> usize {
        let i = self.replicas.len();
        let profile = self.cfg.cluster.replica_profile(&self.cfg.engine, i);
        let seed = self.cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut coord = crate::serve::build_sim_coordinator_with(&self.cfg, profile, seed);
        if self.cfg.cluster.autoscale.prewarm {
            crate::serve::prewarm_predictor(coord.predictor.as_mut(), &self.cfg);
        }
        coord.advance_to(now);
        self.replicas.push(ClusterReplica {
            coord,
            speed: self.cfg.cluster.speed_of(i),
            state: ReplicaState::Provisioning,
            pool,
            down_since: 0.0,
            downtime: 0.0,
            spawned_at: now,
            ready_at: now + self.cfg.cluster.autoscale.provision_delay,
            retired_at: None,
            seen_outcomes: 0,
            seen_aborted: 0,
        });
        self.backlog.push(0.0);
        self.backlog_var.push(0.0);
        self.routed.push(0);
        // register with the indexes unconditionally: the probe table must
        // stay in lockstep with the roster length (see index_add_replica)
        self.index_add_replica(i);
        i
    }

    /// The two terms of the migrate-vs-wait decision for one
    /// partially-generated request on replica `victim`, or `None` when the
    /// cluster no longer tracks it: `(wait_out, transfer)` where
    /// `wait_out` is the quantile-`z` predicted *remaining* cost,
    /// normalized by the victim's speed (a slow victim's tail is costed
    /// honestly), and `transfer` is `migration_kv_per_token` × resident KV
    /// tokens (prompt + generated prefix). Victim *scoring*
    /// ([`ClusterCtx::scale_in_drain_cost`]) and the per-request drain
    /// decision (`migrate_partials`) both price through this one helper so
    /// the chosen victim's score always matches what its drain will do.
    /// KV blocks a partially-generated request needs to take its next
    /// decode token on a fresh replica (prompt + prefix + 1, in
    /// [`crate::serve::KV_BLOCK_TOKENS`]-token blocks) — the same block
    /// math the coordinator's batch packer uses.
    fn blocks_for(input_len: u32, generated: u32) -> usize {
        ((input_len + generated) as usize + 1).div_ceil(crate::serve::KV_BLOCK_TOKENS)
    }

    fn migration_terms(
        &self,
        victim: usize,
        z: f64,
        id: RequestId,
        input_len: u32,
        generated: u32,
    ) -> Option<(f64, f64)> {
        let f = self.in_flight.get(&id)?;
        let speed = self.replicas[victim].speed.max(1e-9);
        let total_q = f.cost + z * f.var.max(0.0).sqrt();
        let consumed = self.cost.consumed(input_len, generated);
        let wait_out = (total_q - consumed).max(0.0) / speed;
        let transfer = self.cfg.cluster.migration_kv_per_token
            * (input_len + generated) as f64;
        Some((wait_out, transfer))
    }

    /// Estimated cost of draining replica `i` for scale-in, in
    /// speed-normalized cost-model units: each partially-generated live
    /// request contributes the *cheaper* of waiting out its predicted
    /// remaining cost (at quantile `z`) and migrating its KV
    /// (`migration_kv_per_token` × resident tokens). Never-scheduled
    /// queued work re-routes for free and contributes nothing. This is
    /// what the migration-cost-aware victim selection minimizes — a
    /// replica with mostly almost-done (or cheaply movable) work is a
    /// better victim than one holding long, expensive-to-move tails.
    pub(crate) fn scale_in_drain_cost(&self, i: usize, z: f64) -> f64 {
        let mut cost = 0.0;
        for (id, input_len, generated) in self.replicas[i].coord.partial_meta() {
            if let Some((wait_out, transfer)) =
                self.migration_terms(i, z, id, input_len, generated)
            {
                cost += wait_out.min(transfer);
            }
        }
        cost
    }

    /// Begin scale-in on `victim`: stop routing to it, re-route its
    /// never-scheduled queued work through the router (those requests hold
    /// no KV or engine state, so the migration is exact), and — when
    /// migration-cost-aware scale-in is enabled
    /// (`migration_kv_per_token > 0`) — migrate partially-generated
    /// requests whose KV transfer is predicted cheaper than waiting out
    /// their remaining generation. Whatever stays finishes in place.
    /// Unlike crash re-dispatch, a *voluntary* scale-in must be lossless: a
    /// queued request whose re-route target has no admission headroom (or
    /// when no replica is routable at all) stays on the victim, which keeps
    /// serving until its live set drains. Retires immediately when nothing
    /// is left live.
    pub(crate) fn begin_drain(&mut self, victim: usize, now: f64) -> anyhow::Result<()> {
        self.replicas[victim].state = ReplicaState::Draining;
        self.replicas[victim].coord.advance_to(now);
        self.record(now, victim, ScaleAction::Drain);
        let mut moved = self.replicas[victim].coord.drain_queued(usize::MAX);
        for req in &moved {
            if let Some(f) = self.in_flight.remove(&req.id) {
                debug_assert_eq!(f.replica, victim, "in-flight map out of sync at drain");
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
            }
        }
        moved.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        // the victim must leave the index scope *before* the re-route
        // dispatches below consult the fast paths
        self.sync_replica(victim);
        for req in moved {
            if SloAdmission.place(self, req, now, Some(victim))? {
                self.drained += 1;
            }
        }
        self.migrate_partials(victim)?;
        self.steal_dirty = true;
        if self.replicas[victim].coord.is_idle() {
            self.retire(victim, now);
        }
        Ok(())
    }

    /// Migration-cost-aware drain: move partially-generated requests off
    /// the scale-in `victim` when shipping their KV is predicted cheaper
    /// than waiting out the drain. Per candidate the comparison is the
    /// configured quantile of its predicted *remaining* cost
    /// (speed-normalized, so a slow victim's tail is costed honestly)
    /// against `migration_kv_per_token` × resident KV tokens
    /// (prompt + generated prefix). Migrated requests keep their generated
    /// prefix and first-token timestamp — the target resumes them like a
    /// preempted request (re-prefilling the prefix, the KV-reconstruction
    /// work a real migration pays), it does not restart them. No-op when
    /// the feature is off (`migration_kv_per_token == 0`) or no replica is
    /// routable.
    fn migrate_partials(&mut self, victim: usize) -> anyhow::Result<()> {
        let kv_cost = self.cfg.cluster.migration_kv_per_token;
        if kv_cost <= 0.0 {
            return Ok(());
        }
        // under disaggregation a drain migrates within the victim's pool:
        // decode partials must not land on a prefill replica (or vice
        // versa), or the pool discipline the fabric enforces would leak
        let pool = self.replicas[victim].pool;
        let views = self.views_for(pool);
        if views.is_empty() {
            return Ok(());
        }
        let z = normal_quantile_clamped(self.cfg.cluster.migration_quantile);
        let mut chosen: Vec<RequestId> = Vec::new();
        // partial_meta is id-sorted, so candidate order — and therefore
        // every routing decision below — is deterministic
        for (id, input_len, generated) in self.replicas[victim].coord.partial_meta() {
            let Some((wait_out, transfer)) =
                self.migration_terms(victim, z, id, input_len, generated)
            else {
                continue;
            };
            // only migrate where the prompt + prefix can physically fit: a
            // partial shipped to a replica with too little total KV would
            // wedge it (the victim it already runs on is proof it fits
            // *somewhere*, so un-placeable work simply finishes in place)
            let needed = Self::blocks_for(input_len, generated);
            let placeable = views.iter().any(|v| v.kv_total_blocks >= needed);
            if placeable && transfer < wait_out {
                chosen.push(id);
            }
        }
        if chosen.is_empty() {
            return Ok(());
        }
        // the victim's clock may have overshot the drain instant `now` (it
        // was stepped until every busy replica caught up to the event), and
        // its partials' prefixes include tokens generated up to that clock
        // — the target must not resume a prefix before it could exist
        let victim_now = self.replicas[victim].coord.now();
        let moved = self.replicas[victim].coord.drain_partials(&chosen);
        for m in moved {
            let id = m.req.id;
            let (pcost, pvar) = match self.in_flight.get(&id) {
                Some(f) => (f.cost, f.var),
                None => (0.0, 0.0),
            };
            let needed = Self::blocks_for(m.req.input_len, m.generated);
            // fast path: answer the target selection from the index scope
            // covering the victim's pool when the per-request KV-fit filter
            // is vacuous there — every in-scope replica holds at least
            // `needed` blocks (the scope min), so the filtered eligible set
            // below would equal the scope exactly
            let fp = self.router.fast_path(&m.req);
            let mut attempted = false;
            if self.use_indexes && fp != FastPath::Rescan {
                if let Some(idx) = self.scoped_indexes_mut(pool) {
                    attempted = !idx.roster().is_empty()
                        && needed <= idx.aggregates().kv_total_min;
                }
            }
            let fast_target = if attempted {
                match fp {
                    FastPath::Affinity => {
                        self.affinity_route(&m.req, pcost, pool, WarmPricing::Consumed)
                    }
                    _ => self.index_route(fp, pool, false),
                }
            } else {
                None
            };
            let target = match fast_target {
                Some(t) => {
                    self.count_fastpath(DispatchScope::Migration, FastPathOutcome::Hit);
                    t
                }
                None => {
                    self.count_fastpath(
                        DispatchScope::Migration,
                        if attempted {
                            FastPathOutcome::Fallback
                        } else {
                            FastPathOutcome::Rescan
                        },
                    );
                    // route over the replicas whose total KV can hold the
                    // prefix (non-empty: selection above required a fitting
                    // target)
                    let mut eligible: Vec<ReplicaView> = self
                        .views_for(pool)
                        .into_iter()
                        .filter(|v| v.kv_total_blocks >= needed)
                        .collect();
                    // warmth for the cache-affinity router: a target already
                    // holding this session's shared prefix re-prefills less
                    // after the move. The saving is priced as the
                    // consumed-cost of the warm tokens' prefill (no length
                    // distribution survives to this path, so the prefill
                    // term is the honest estimate).
                    if !m.req.prefix_key.is_empty() {
                        for v in &mut eligible {
                            let warm = self.replicas[v.id]
                                .coord
                                .kv
                                .cached_prefix_tokens(
                                    &m.req.prefix_key,
                                    m.req.input_len as usize,
                                )
                                as u32;
                            if warm > 0 {
                                v.warm_prefix_tokens = warm;
                                v.warm_cost_saving = self.cost.consumed(warm, 0);
                            }
                        }
                    }
                    if eligible.is_empty() {
                        // belt-and-braces: finish in place on the draining
                        // victim
                        let accepted = self.replicas[victim].coord.submit_migrated(m);
                        debug_assert!(accepted, "victim re-admission is exempt");
                        continue;
                    }
                    let slot = self.router.route(&m.req, pcost, &eligible);
                    if slot >= eligible.len() {
                        anyhow::bail!(
                            "router {} returned position {slot} but only {} replicas \
                             are eligible",
                            self.router.name(),
                            eligible.len()
                        );
                    }
                    eligible[slot].id
                }
            };
            self.replicas[target].coord.advance_to(victim_now);
            // a landing is where prefix caching can begin: keep the
            // warm-site superset invariant the affinity fast path relies on
            self.note_warm_site(&m.req, target);
            // a migration is admission-exempt: the request already passed
            // admission on the victim, so moving it can never reject it
            let accepted = self.replicas[target].coord.submit_migrated(m);
            debug_assert!(accepted, "migrated submission is admission-exempt");
            if !accepted {
                continue;
            }
            if let Some(entry) = self.in_flight.get_mut(&id) {
                entry.replica = target;
                self.backlog[victim] = (self.backlog[victim] - pcost).max(0.0);
                self.backlog_var[victim] = (self.backlog_var[victim] - pvar).max(0.0);
                self.backlog[target] += pcost;
                self.backlog_var[target] += pvar;
            }
            self.migrated += 1;
            self.sync_replica(target);
        }
        // one sync covers every per-move change on the victim side (live
        // set, backlog) including the finish-in-place fallback
        self.sync_replica(victim);
        Ok(())
    }

    /// Finalize a drained replica's exit.
    pub(crate) fn retire(&mut self, i: usize, at: f64) {
        let at = at.max(self.replicas[i].coord.now());
        self.replicas[i].state = ReplicaState::Retired;
        self.replicas[i].retired_at = Some(at);
        self.record(at, i, ScaleAction::Retire);
        self.sync_replica(i);
    }

    pub(crate) fn record(&mut self, at: f64, replica: usize, action: ScaleAction) {
        self.scaling_events.push(ScalingEvent { at, replica, action });
    }
}
