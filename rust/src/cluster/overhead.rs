//! Overhead measurement — the legacy fig12 mode.
//!
//! [`ClusterSim`] wallclock-measures the per-request predicting/scheduling
//! latency of the *shared* services as the cluster grows: the shared
//! predictor is modeled as an M/M/1 server fed by every node's arrivals,
//! and scheduling replays one coordinator iteration's priority evaluation
//! and sort at the configured queue depth. It answers "does the
//! centralized scheduler become the bottleneck?" — a different question
//! from the event-driven simulation in the rest of `cluster/`, which is
//! why it stays a separate mode behind `sagesched cluster --overhead`.

use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::gittins::gittins_index_at_age;
use crate::predictor::{HistoryPredictor, Predictor};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::WorkloadGen;

/// Result of one cluster-scale overhead measurement.
#[derive(Clone, Debug)]
pub struct ClusterOverhead {
    pub nodes: usize,
    pub aggregate_rps: f64,
    /// mean per-request predict latency, seconds (service + queueing)
    pub predict_latency: f64,
    /// mean per-request scheduling latency, seconds (priority eval + sort
    /// at the configured queue depth)
    pub sched_latency: f64,
    /// total per-request overhead
    pub total_latency: f64,
    /// utilization of the shared predictor service
    pub predictor_utilization: f64,
}

/// Cluster-scalability overhead simulator (wallclock-measured shared
/// predictor + scheduler service times, M/M/1 queueing at the predictor).
pub struct ClusterSim {
    pub cfg: ExperimentConfig,
    /// per-node request rate (paper: 8 RPS/node)
    pub rps_per_node: f64,
    /// scheduler queue depth to exercise (paper: up to 1,000 buffered)
    pub queue_depth: usize,
    /// number of measured prediction/scheduling operations per point
    pub samples: usize,
}

impl ClusterSim {
    pub fn new(cfg: ExperimentConfig) -> ClusterSim {
        ClusterSim { cfg, rps_per_node: 8.0, queue_depth: 1000, samples: 200 }
    }

    /// Measure predict + schedule overhead for an `n_nodes` cluster.
    pub fn measure(&self, n_nodes: usize) -> ClusterOverhead {
        let mut rng = Rng::new(self.cfg.seed ^ (n_nodes as u64) << 8);

        // --- build a warm shared history index at paper scale -------------
        let mut wl_cfg = self.cfg.workload.clone();
        wl_cfg.n_requests = self.cfg.history_capacity.min(10_000);
        let warm = WorkloadGen::new(wl_cfg, self.cfg.seed ^ 0xc1).generate();
        let mut predictor = HistoryPredictor::new(
            self.cfg.workload.embed_dim,
            self.cfg.history_capacity,
            self.cfg.similarity_threshold,
        );
        for r in &warm.requests {
            predictor.observe(r, r.true_output_len);
        }

        // --- measure predict service time ---------------------------------
        let mut probe_cfg = self.cfg.workload.clone();
        probe_cfg.n_requests = self.samples;
        let probes = WorkloadGen::new(probe_cfg, self.cfg.seed ^ 0xc2).generate();
        let mut service_times = Vec::with_capacity(self.samples);
        let mut dists: Vec<LengthDist> = Vec::with_capacity(self.samples);
        for r in &probes.requests {
            let t0 = Instant::now();
            let d = predictor.predict(r);
            service_times.push(t0.elapsed().as_secs_f64());
            dists.push(d);
        }
        let s_pred = mean(&service_times);

        // The shared predictor serves the whole cluster: arrival rate
        // lambda = nodes * rps; M/M/1 waiting time = rho/(1-rho) * s.
        let lambda = n_nodes as f64 * self.rps_per_node;
        let rho = (lambda * s_pred).min(0.99);
        let predict_latency = s_pred + s_pred * rho / (1.0 - rho);

        // --- measure scheduling latency at queue depth --------------------
        // real Gittins evaluations + a real sort over `queue_depth` entries,
        // replicating one coordinator iteration's scheduling work.
        let cost: Box<dyn CostModel> = crate::cost::make_cost_model(self.cfg.cost_model);
        let mut entries: Vec<(f64, LengthDist, u32, u32)> = (0..self.queue_depth)
            .map(|i| {
                let d = &dists[i % dists.len()];
                let input = 64 + (rng.below(512) as u32);
                let gen = rng.below(200) as u32;
                (0.0, cost.cost_dist(input, d), input, gen)
            })
            .collect();
        let mut sched_times = Vec::with_capacity(self.samples.min(50));
        for _ in 0..self.samples.min(50) {
            let t0 = Instant::now();
            for e in entries.iter_mut() {
                let consumed = cost.consumed(e.2, e.3);
                e.0 = gittins_index_at_age(&e.1, consumed);
            }
            let mut order: Vec<usize> = (0..entries.len()).collect();
            order.sort_by(|&a, &b| entries[a].0.partial_cmp(&entries[b].0).unwrap());
            std::hint::black_box(&order);
            sched_times.push(t0.elapsed().as_secs_f64());
        }
        // scheduling happens per node but the paper's centralized variant
        // scales the work with cluster size; model one scheduler handling
        // all nodes' queues round-robin. Up to 64 nodes one full-depth pass
        // covers everyone; past that the pass count grows linearly.
        let sched_latency = mean(&sched_times) * sched_scale(n_nodes);

        ClusterOverhead {
            nodes: n_nodes,
            aggregate_rps: lambda,
            predict_latency,
            sched_latency,
            total_latency: predict_latency + sched_latency,
            predictor_utilization: rho,
        }
    }

    /// Sweep cluster sizes (the paper's Fig. 12 x-axis).
    pub fn sweep(&self, sizes: &[usize]) -> Vec<ClusterOverhead> {
        sizes.iter().map(|&n| self.measure(n)).collect()
    }
}

/// Centralized-scheduler work multiplier: `(n/64).max(1)` full-depth
/// scheduling passes. Monotone non-decreasing in `n` — a small cluster pays
/// one full pass, never a fraction of one. (The previous expression,
/// `n / 64.0_f64.max(1.0)`, divided *every* cluster size by a constant 64
/// due to operator precedence, so 1-node clusters reported 64× too little
/// scheduling overhead.)
pub fn sched_scale(n_nodes: usize) -> f64 {
    (n_nodes as f64 / 64.0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_grows_with_cluster_size() {
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 2000; // keep the test quick
        let sim = ClusterSim { samples: 30, queue_depth: 200, ..ClusterSim::new(cfg) };
        let small = sim.measure(1);
        let large = sim.measure(64);
        assert!(large.total_latency > small.total_latency);
        assert!(large.predictor_utilization >= small.predictor_utilization);
    }

    #[test]
    fn sched_scale_never_discounts_small_clusters() {
        // regression for the precedence bug `n / 64.0_f64.max(1.0)`: small
        // clusters must pay one full scheduling pass, not 1/64th of one
        assert_eq!(sched_scale(1), 1.0);
        assert_eq!(sched_scale(16), 1.0);
        assert_eq!(sched_scale(64), 1.0);
        assert_eq!(sched_scale(128), 2.0);
        let mut prev = 0.0;
        for n in [1usize, 2, 8, 32, 64, 96, 128, 512] {
            let s = sched_scale(n);
            assert!(s >= prev, "sched_scale not monotone at {n}");
            assert!(s >= 1.0);
            prev = s;
        }
    }

    #[test]
    fn measured_sched_latency_comparable_across_sizes() {
        // wallclock-level regression: under the old bug a 1-node cluster
        // reported ~1/64th of the 64-node scheduling latency; fixed, both
        // pay one full-depth pass and differ only by measurement noise
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 1000;
        let sim = ClusterSim { samples: 20, queue_depth: 200, ..ClusterSim::new(cfg) };
        let one = sim.measure(1);
        let big = sim.measure(64);
        assert!(
            one.sched_latency > 0.1 * big.sched_latency,
            "1-node sched latency {} implausibly below 64-node {}",
            one.sched_latency,
            big.sched_latency
        );
    }
}
