//! Disaggregated prefill/decode serving: per-pool autoscaler snapshots.
//!
//! With [`ClusterConfig::pools`](crate::config::ClusterConfig) non-empty
//! the roster splits into a **prefill pool** (runs every prompt to its
//! first token, then hands off) and a **decode pool** (finishes the
//! generation it receives over the
//! [`TransferFabric`](crate::cluster::TransferFabric)). The two pools do
//! different work per request — one long compute-bound prefill vs many
//! short memory-bound decode steps — so a single cluster-wide forecast
//! would systematically mis-size both. This module gives the
//! [`AutoscaleDriver`](crate::cluster::AutoscaleDriver) a per-pool
//! [`AutoscaleView`] instead:
//!
//! * **State counts** (active/provisioning/down/draining, live/queued, KV
//!   occupancy) are restricted to the pool's replicas.
//! * **Forecast moments** split each in-flight request's predicted cost at
//!   the prefill/decode boundary: the prefill part is the cost model's
//!   consumed-cost of the prompt alone (`consumed(input_len, 0)`), the
//!   decode part is the remainder. A request still on a prefill replica
//!   owes its prefill part to the prefill pool *and* its decode part to
//!   the decode pool (the work is coming — forecasting it early is the
//!   whole point); a request on the fabric or already decoding owes only
//!   its decode part. All predicted-cost *variance* is decode-side: given
//!   the prompt, prefill cost is deterministic — output length is where
//!   the uncertainty lives.
//! * **SLO-aware weighting** (under `--slo-aware`): the prefill pool's
//!   weighted moments use each class's TTFT-tightness weight
//!   ([`SloSpecs::prefill_weight`](crate::slo::SloSpecs::prefill_weight) —
//!   TTFT is paid entirely on the prefill side), the decode pool's use the
//!   completion-tightness weight
//!   ([`SloSpecs::decode_weight`](crate::slo::SloSpecs::decode_weight)).
//!   The `UncertaintyAware` policy then provisions each pool for a
//!   quantile of *its* weighted forecast: a burst of tight-TTFT
//!   interactive prompts grows the prefill pool first, a backlog of long
//!   deadline-bound generations grows the decode pool. Class-blind
//!   serving weighs everything 1, as elsewhere.

use crate::autoscale::AutoscaleView;
use crate::config::PoolRole;
use crate::core::RequestId;

use super::ctx::ClusterCtx;
use super::replica::ReplicaState;

impl ClusterCtx {
    /// Snapshot one pool for its autoscale policy instance. Mirrors
    /// [`ClusterCtx::autoscale_view`] with every term restricted to (or
    /// split for) `pool`; see the module docs for the split. Iteration is
    /// id-sorted so the floating-point sums are deterministic.
    pub(crate) fn pool_autoscale_view(&self, now: f64, pool: PoolRole) -> AutoscaleView {
        let mut active = 0;
        let mut provisioning = 0;
        let mut down = 0;
        let mut draining = 0;
        let mut total_live = 0;
        let mut total_queued = 0;
        let mut occ_sum = 0.0;
        for r in &self.replicas {
            if r.pool != Some(pool) {
                continue;
            }
            match r.state {
                ReplicaState::Active => {
                    active += 1;
                    total_live += r.coord.live_count();
                    total_queued += r.coord.queued_count();
                    let total = r.coord.kv.total_blocks();
                    if total > 0 {
                        occ_sum += r.coord.kv.used_blocks() as f64 / total as f64;
                    }
                }
                ReplicaState::Provisioning => provisioning += 1,
                ReplicaState::Down => down += 1,
                ReplicaState::Draining => draining += 1,
                ReplicaState::Retired => {}
            }
        }
        let mean_kv_occupancy = if active > 0 {
            occ_sum / active as f64
        } else {
            0.0
        };
        let mut ids: Vec<RequestId> = self.in_flight.keys().copied().collect();
        ids.sort_unstable();
        let mut backlog_mean = 0.0;
        let mut backlog_var = 0.0;
        let mut backlog_weighted_mean = 0.0;
        let mut backlog_weighted_var = 0.0;
        for id in ids {
            let f = &self.in_flight[&id];
            let prefill = self.cost.consumed(f.req.input_len, 0).min(f.cost);
            let decode = (f.cost - prefill).max(0.0);
            // remaining prefill work is owed only while the request still
            // sits in the prefill pool; once it rides the fabric (or lands
            // on a decode replica) only decode work remains
            let awaiting_prefill = !self.in_transfer.contains(&id)
                && self.replicas[f.replica].pool == Some(PoolRole::Prefill);
            let (mean, var) = match pool {
                PoolRole::Prefill if awaiting_prefill => (prefill, 0.0),
                PoolRole::Prefill => (0.0, 0.0),
                PoolRole::Decode => (decode, f.var),
            };
            if mean <= 0.0 && var <= 0.0 {
                continue;
            }
            let w = if self.cfg.slo.class_aware {
                match pool {
                    PoolRole::Prefill => self.cfg.slo.specs.prefill_weight(f.req.slo),
                    PoolRole::Decode => self.cfg.slo.specs.decode_weight(f.req.slo),
                }
            } else {
                1.0
            };
            backlog_mean += mean;
            backlog_var += var;
            backlog_weighted_mean += w * mean;
            backlog_weighted_var += w * w * var;
        }
        AutoscaleView {
            now,
            active,
            provisioning,
            down,
            draining,
            total_live,
            total_queued,
            mean_kv_occupancy,
            backlog_mean,
            backlog_var,
            backlog_weighted_mean,
            backlog_weighted_var,
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::EventCluster;
    use crate::config::{ExperimentConfig, PolicyKind, PoolRole, RouterKind};
    use crate::workload::WorkloadGen;

    fn disagg_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 40;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        cfg.cluster.pools = vec![PoolRole::Prefill, PoolRole::Decode];
        cfg
    }

    #[test]
    fn pool_views_partition_the_roster() {
        let cfg = disagg_cfg();
        let cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
        let pf = cluster.pool_autoscale_view(0.0, PoolRole::Prefill);
        let dec = cluster.pool_autoscale_view(0.0, PoolRole::Decode);
        assert_eq!(pf.active, 2);
        assert_eq!(dec.active, 2);
        assert_eq!(pf.active + dec.active, cluster.autoscale_view(0.0).active);
    }

    #[test]
    fn pool_forecasts_split_cost_at_the_prefill_boundary() {
        let cfg = disagg_cfg();
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
        // dispatch a few arrivals without running: everything now waits in
        // the prefill pool, so the prefill view owes the prompt work and
        // the decode view already forecasts the decode remainder
        for req in workload.requests.into_iter().take(8) {
            let at = req.arrival;
            cluster.dispatch(req, at).unwrap();
        }
        let pf = cluster.pool_autoscale_view(1.0, PoolRole::Prefill);
        let dec = cluster.pool_autoscale_view(1.0, PoolRole::Decode);
        assert!(pf.backlog_mean > 0.0, "prompts owe prefill work");
        assert!(dec.backlog_mean > 0.0, "forecast decode work rides along");
        assert!(
            pf.backlog_var == 0.0,
            "prefill cost is deterministic given the prompt"
        );
        assert!(dec.backlog_var > 0.0, "output-length uncertainty is decode-side");
        let total = cluster.autoscale_view(1.0);
        let sum = pf.backlog_mean + dec.backlog_mean;
        assert!(
            (sum - total.backlog_mean).abs() < 1e-6,
            "pool split must conserve the cluster forecast: {sum} vs {}",
            total.backlog_mean
        );
    }

    #[test]
    fn slo_aware_pools_weigh_tightness_not_just_class() {
        use crate::slo::{SloClass, SloSpecs};
        let specs = SloSpecs::default();
        // interactive TTFT (2s) is 4x tighter than standard's (8s): the
        // prefill weight must multiply the base weight by that tightness
        let w = specs.prefill_weight(SloClass::Interactive);
        let base = specs.spec(SloClass::Interactive).weight;
        assert!((w - base * 4.0).abs() < 1e-12);
        // standard is its own reference on both sides
        assert!((specs.prefill_weight(SloClass::Standard) - 1.0).abs() < 1e-12);
        assert!((specs.decode_weight(SloClass::Standard) - 1.0).abs() < 1e-12);
        // batch deadlines are looser than standard's: weight shrinks
        assert!(specs.decode_weight(SloClass::Batch) < specs.spec(SloClass::Batch).weight);
    }
}
