//! Shared cluster state and mechanics: what every component observes.
//!
//! [`ClusterCtx`] owns the replica roster, the shared prediction service,
//! the router, and all cross-replica bookkeeping (per-replica predicted
//! backlog moments, the in-flight map, lifecycle counters, the scaling
//! timeline). The components in [`crate::cluster::components`] decide
//! *when* things happen (they pop kernel events); the context implements
//! *what* happens: routing a request in, stepping a replica and
//! reconciling its completions, taking a replica down, draining a
//! scale-in victim (including migration-cost-aware moves of
//! partially-generated work), and assembling the final
//! [`ClusterReport`].
//!
//! Everything here is deterministic given the same call sequence:
//! collections are only ever iterated in sorted or index order wherever
//! float bookkeeping (and therefore downstream routing, scaling, and the
//! report JSON) could observe the order.

use std::collections::{HashMap, HashSet};

use crate::autoscale::ScalingEvent;
use crate::config::{ExperimentConfig, PoolRole, RouterKind};
use crate::core::{Request, RequestId};
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::engine::Engine;
use crate::metrics::{
    ClusterCounters, ClusterReport, DispatchScope, FastPathStats, RunReport,
};
use crate::predictor::Predictor;
use crate::util::stats::normal_quantile_clamped;

use super::components::SloAdmission;
use super::index::{canon, Metric, RouterIndexes, Sample};
use super::replica::{ClusterReplica, InFlightTable, ReplicaState};
use super::router::{
    make_router, ClassAwareRouter, FastPath, ReplicaView, Router, TIGHT_KV_HEADROOM,
    TIGHT_QUANTILE,
};

/// How the affinity fast path prices a candidate's warm prefix, mirroring
/// the two rescan paths' arithmetic exactly.
pub(crate) enum WarmPricing<'a> {
    /// Admission-path saving: cold predicted cost minus the predicted cost
    /// with the warm tokens removed from the prefill term (needs the
    /// request's length prediction).
    Admission(&'a LengthDist),
    /// Migration/delivery saving: the cost model's prefill cost of the
    /// tokens already resident (`CostModel::consumed`).
    Consumed,
}

/// How a dispatch site resolved its placement, for the per-scope
/// fast-path coverage counters: answered from the indexes (`Hit`),
/// attempted but bailed to the rescan (`Fallback` — dominance bound or
/// fit-filter failure), or never attempted (`Rescan` — router declared
/// it, no index covers the scope, or the differential oracle is running).
#[derive(Clone, Copy, Debug)]
pub(crate) enum FastPathOutcome {
    Hit,
    Fallback,
    Rescan,
}

/// Shared state of the event-driven cluster: N coordinators on a shared
/// virtual clock behind a [`Router`], with a shared prediction service and
/// all cross-replica bookkeeping. Components mutate it through the
/// mechanics methods below; [`EventCluster`](crate::cluster::EventCluster)
/// derefs to it, so its fields and accessors are the cluster's public
/// read surface.
pub struct ClusterCtx {
    pub cfg: ExperimentConfig,
    pub replicas: Vec<ClusterReplica>,
    pub router: Box<dyn Router>,
    /// Decode-pool router under disaggregated serving: fabric handoffs
    /// route through this separate instance (its own cursor/state), never
    /// through the front-door `router`. `None` in colocated mode.
    pub decode_router: Option<Box<dyn Router>>,
    /// Shared prediction service (prices arrivals; learns from completions).
    pub predictor: Box<dyn Predictor>,
    pub(crate) cost: Box<dyn CostModel>,
    /// id -> routing + predicted-cost bookkeeping (slab-backed; iteration
    /// order is arbitrary, consumers sort).
    pub(crate) in_flight: InFlightTable,
    /// Per-replica sum of predicted cost of in-flight requests.
    pub(crate) backlog: Vec<f64>,
    /// Per-replica sum of predicted cost *variance* of in-flight requests.
    pub(crate) backlog_var: Vec<f64>,
    /// Cluster-wide SLO-weighted backlog moments: Σ w·E[cost] and
    /// Σ w²·Var[cost] over in-flight requests (w = 1 under class-blind
    /// serving, so these equal the unweighted sums). Maintained
    /// incrementally — never by iterating the in-flight map, whose order
    /// is not deterministic — and consumed by the uncertainty-aware
    /// autoscaler's weighted forecast.
    pub(crate) backlog_weighted: f64,
    pub(crate) backlog_weighted_var: f64,
    /// Per-replica routed-request counts.
    pub routed: Vec<u64>,
    /// Requests re-dispatched through the router after a replica failure.
    pub re_routed: u64,
    /// Queued requests re-routed off a scale-in victim at drain time.
    pub drained: u64,
    /// Partially-generated requests migrated off a scale-in victim (KV
    /// shipped, generated prefix preserved) instead of waiting out the
    /// drain.
    pub migrated: u64,
    /// Queued requests migrated to an idle replica by work stealing.
    pub stolen: u64,
    /// Prefill→decode handoffs delivered over the KV-transfer fabric.
    pub transfers: u64,
    /// Resident KV tokens (prompt + generated prefix) moved over the
    /// fabric.
    pub transfer_tokens: u64,
    /// Total link-busy seconds across all fabric links (utilization
    /// numerator; the denominator is `links * horizon` at report time).
    pub(crate) transfer_busy: f64,
    /// Per-handoff fabric timeline: (enqueue instant, delivery instant,
    /// resident KV tokens). Delivery never precedes
    /// `enqueue + tokens / bandwidth` — the conservation/property tests
    /// assert this invariant directly.
    pub transfer_log: Vec<(f64, f64, u64)>,
    /// Requests currently in flight on the fabric (drained off their
    /// prefill replica, not yet delivered to a decode replica). Their
    /// `in_flight` entry still names the source replica, so the
    /// timeout-abort reconciliation in `step_replica` must not mistake
    /// them for gone.
    pub(crate) in_transfer: HashSet<RequestId>,
    /// Failure-domain outages that fired (each may take several replicas
    /// down in one event).
    pub domain_outages: u64,
    /// Windowed Kendall's tau of the *shared* predictor's placement-time
    /// rank scores against realised output lengths, over completions
    /// cluster-wide. Overwrites the (non-summable) per-replica aggregate in
    /// [`ClusterCtx::report`].
    pub pred_tau: crate::util::stats::KendallTau,
    /// Request ids whose completion was already fed to the shared
    /// predictor. Completions are drained exactly once per replica via
    /// `seen_outcomes` watermarks and the `in_flight` entry is removed on
    /// first sight, but re-dispatch paths (failure re-route, scale-in
    /// drain, stealing) re-insert entries under the same id — this set
    /// guarantees one observation per request no matter how many replicas
    /// touched it.
    pub(crate) observed: HashSet<RequestId>,
    /// Steal candidates rejected by the transfer-cost benefit gate at
    /// least once.
    pub(crate) steal_rejected: HashSet<RequestId>,
    /// Whether anything that could change a steal verdict (queue contents,
    /// backlogs, replica states) has happened since the last fruitless
    /// stealing pass. The benefit gate makes "idle thief, nothing
    /// profitable" a *persistent* state; without this flag every event-loop
    /// iteration would rescan and re-sort the queues just to reach the same
    /// verdict.
    pub(crate) steal_dirty: bool,
    /// Replica lifecycle timeline (provision/up/drain/retire/fail/recover).
    pub scaling_events: Vec<ScalingEvent>,
    /// Incrementally-maintained router score indexes over the intake pool
    /// (see `cluster/index.rs` for the determinism invariant).
    pub(crate) indexes: RouterIndexes,
    /// Decode-pool twin of `indexes` under disaggregated serving: the
    /// transfer fabric's delivery router, decode-side drain re-admission,
    /// and decode-side migration all dispatch from it. Synced in lockstep
    /// with the intake instance from the same delta seam. `None` in
    /// colocated mode (the intake instance already covers every replica).
    pub(crate) decode_indexes: Option<RouterIndexes>,
    /// Prefix head key → replica ids where a request carrying that head
    /// has landed. A *superset* of the replicas whose KV cache holds the
    /// head block (landing is when caching can begin; entries are lazily
    /// pruned when an affinity probe proves the head cold), which is what
    /// the affinity fast path needs: any replica with a nonzero warm
    /// saving for a request is guaranteed to be listed under the
    /// request's head, so every unlisted replica can be bounded by its
    /// base score alone.
    pub(crate) warm_sites: HashMap<u64, Vec<usize>>,
    /// Fast-path coverage counters per dispatch scope (hits, dominance/
    /// filter fallbacks, declared rescans). Observability only — never an
    /// input to any routing decision.
    pub fastpath: FastPathStats,
    /// Differential-oracle toggle: when false, every dispatch and
    /// quiescent scan uses the retained full-rescan code paths the indexes
    /// replaced — byte-identical behaviour, pre-optimization cost. Set it
    /// before the run starts; flipping it mid-run leaves the indexes stale.
    pub use_indexes: bool,
    /// When set, every dispatch appends `(request id, replica)` to
    /// [`ClusterCtx::dispatch_trace`] — the equivalence suite compares
    /// these traces between indexed and oracle runs.
    pub trace_dispatch: bool,
    /// Dispatch sequence recorded under [`ClusterCtx::trace_dispatch`].
    pub dispatch_trace: Vec<(RequestId, usize)>,
    /// Kernel events popped this run (perf accounting).
    pub kernel_events: u64,
    /// Replica scheduling iterations this run (perf accounting).
    pub replica_steps: u64,
    /// Scratch buffers reused across `step_replica` calls (allocation-churn
    /// control in the hottest path).
    scratch_completions: Vec<(RequestId, u32)>,
    scratch_gone: Vec<RequestId>,
    /// Scratch buffers reused across affinity fast-path dispatches.
    scratch_shortlist: Vec<usize>,
    scratch_warm: Vec<usize>,
}

impl ClusterCtx {
    /// Build the shared state for a fresh cluster from `cfg` (replica
    /// count / heterogeneity from `cfg.cluster`), with an explicit router.
    pub(crate) fn new(cfg: &ExperimentConfig, router: RouterKind) -> ClusterCtx {
        let n = cfg.cluster.replicas.max(1);
        let replicas: Vec<ClusterReplica> = (0..n)
            .map(|i| {
                let profile = cfg.cluster.replica_profile(&cfg.engine, i);
                let seed = cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                ClusterReplica {
                    coord: crate::serve::build_sim_coordinator_with(cfg, profile, seed),
                    speed: cfg.cluster.speed_of(i),
                    state: ReplicaState::Active,
                    pool: cfg.cluster.pool_of(i),
                    down_since: 0.0,
                    downtime: 0.0,
                    spawned_at: 0.0,
                    ready_at: 0.0,
                    retired_at: None,
                    seen_outcomes: 0,
                    seen_aborted: 0,
                }
            })
            .collect();
        let predictor = crate::predictor::make_predictor(
            cfg.predictor,
            cfg.workload.embed_dim,
            cfg.history_capacity,
            cfg.similarity_threshold,
            cfg.seed ^ 0xc175_7e12,
        );
        let mut boxed = make_router(router, cfg.cluster.router_quantile);
        if cfg.slo.class_aware {
            boxed = Box::new(ClassAwareRouter::new(boxed));
        }
        let decode_router = cfg.cluster.disagg().then(|| {
            let kind = cfg.cluster.decode_router.unwrap_or(router);
            let mut boxed = make_router(kind, cfg.cluster.router_quantile);
            if cfg.slo.class_aware {
                boxed = Box::new(ClassAwareRouter::new(boxed));
            }
            boxed
        });
        let mut ctx = ClusterCtx {
            cfg: cfg.clone(),
            backlog: vec![0.0; n],
            backlog_var: vec![0.0; n],
            backlog_weighted: 0.0,
            backlog_weighted_var: 0.0,
            routed: vec![0; n],
            re_routed: 0,
            drained: 0,
            migrated: 0,
            stolen: 0,
            transfers: 0,
            transfer_tokens: 0,
            transfer_busy: 0.0,
            transfer_log: Vec::new(),
            in_transfer: HashSet::new(),
            domain_outages: 0,
            pred_tau: crate::util::stats::KendallTau::new(256),
            observed: HashSet::new(),
            steal_rejected: HashSet::new(),
            steal_dirty: true,
            scaling_events: Vec::new(),
            indexes: RouterIndexes::new(
                cfg.cluster.disagg().then_some(PoolRole::Prefill),
                normal_quantile_clamped(cfg.cluster.router_quantile),
                normal_quantile_clamped(TIGHT_QUANTILE),
                TIGHT_KV_HEADROOM,
            ),
            decode_indexes: cfg.cluster.disagg().then(|| {
                RouterIndexes::new(
                    Some(PoolRole::Decode),
                    normal_quantile_clamped(cfg.cluster.router_quantile),
                    normal_quantile_clamped(TIGHT_QUANTILE),
                    TIGHT_KV_HEADROOM,
                )
            }),
            warm_sites: HashMap::new(),
            fastpath: FastPathStats::default(),
            use_indexes: true,
            trace_dispatch: false,
            dispatch_trace: Vec::new(),
            kernel_events: 0,
            replica_steps: 0,
            scratch_completions: Vec::new(),
            scratch_gone: Vec::new(),
            scratch_shortlist: Vec::new(),
            scratch_warm: Vec::new(),
            replicas,
            router: boxed,
            decode_router,
            predictor,
            cost: crate::cost::make_cost_model(cfg.cost_model),
            in_flight: InFlightTable::default(),
        };
        for i in 0..ctx.replicas.len() {
            ctx.index_add_replica(i);
        }
        ctx
    }

    // =======================================================================
    // Read surface (tests, reports, examples)
    // =======================================================================

    /// Requests refused at admission, cluster-wide. Each coordinator owns
    /// its own count (it is the sole place a refusal happens), so summing
    /// here counts every rejection exactly once.
    pub fn rejected(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.rejected).sum()
    }

    /// Requests aborted by queue timeout, cluster-wide.
    pub fn aborted(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.aborted).sum()
    }

    /// Per-SLO-class admission rejections, cluster-wide (indexed by
    /// [`SloClass::index`](crate::slo::SloClass::index)).
    pub fn rejected_by_class(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for r in &self.replicas {
            for (k, &n) in r.coord.rejected_by_class.iter().enumerate() {
                out[k] += n;
            }
        }
        out
    }

    /// Per-SLO-class queue-timeout aborts, cluster-wide (indexed by
    /// [`SloClass::index`](crate::slo::SloClass::index)).
    pub fn aborted_by_class(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for r in &self.replicas {
            for (k, &n) in r.coord.aborted_by_class.iter().enumerate() {
                out[k] += n;
            }
        }
        out
    }

    /// Requests the cluster still tracks as in flight (0 after a completed
    /// run — anything else means bookkeeping leaked).
    pub fn in_flight_count(&self) -> usize {
        self.in_flight.len()
    }

    /// Sum of per-replica predicted-cost backlogs (≈0 after a drained run).
    pub fn total_backlog(&self) -> f64 {
        self.backlog.iter().sum()
    }

    /// Cluster-wide SLO-weighted backlog mean (≈0 after a drained run;
    /// equals [`ClusterCtx::total_backlog`] under class-blind serving up
    /// to float accumulation order).
    pub fn weighted_backlog(&self) -> f64 {
        self.backlog_weighted
    }

    /// Steal candidates the transfer-cost benefit gate rejected (distinct
    /// requests; one later stolen after backlog shifts still counts here).
    pub fn steals_skipped(&self) -> u64 {
        self.steal_rejected.len() as u64
    }

    /// Pre-warm the shared predictor and every replica's local predictor
    /// with the offline corpus (`cfg.history_prewarm`).
    pub fn prewarm(&mut self) {
        crate::serve::prewarm_predictor(self.predictor.as_mut(), &self.cfg);
        for r in &mut self.replicas {
            crate::serve::prewarm_predictor(r.coord.predictor.as_mut(), &self.cfg);
        }
    }

    /// Total completions across replicas.
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.coord.outcomes().len()).sum()
    }

    /// Merged outcome stream (unsorted).
    pub fn merged_outcomes(&self) -> Vec<crate::core::RequestOutcome> {
        let mut out = Vec::with_capacity(self.completed());
        for r in &self.replicas {
            out.extend_from_slice(r.coord.outcomes());
        }
        out
    }

    /// Cluster-level report (aggregate + per-replica + lifecycle counters +
    /// scaling timeline).
    pub fn report(&self, warmup_fraction: f64) -> ClusterReport {
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|r| r.coord.report(warmup_fraction))
            .collect();
        // an outage still open at report time is charged up to the
        // cluster-wide clock horizon; a *retired* replica is simply gone —
        // it must not count as "down" for the remainder of the run, and a
        // replica added mid-run is charged only from its provisioning time
        let horizon = self
            .replicas
            .iter()
            .map(|r| r.coord.now())
            .fold(0.0, f64::max);
        let downtime: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| {
                r.downtime
                    + if r.state == ReplicaState::Down {
                        (horizon - r.down_since).max(0.0)
                    } else {
                        0.0
                    }
            })
            .collect();
        let replica_seconds: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| r.replica_seconds(horizon))
            .collect();
        // per-pool replica-seconds (prefill, decode): the equal-hardware
        // denominator the disaggregation benches compare against; empty
        // under colocated serving (no replica carries a role)
        let pool_replica_seconds: Vec<f64> = if self.cfg.cluster.disagg() {
            let mut by_pool = vec![0.0; PoolRole::ALL.len()];
            for (r, secs) in self.replicas.iter().zip(&replica_seconds) {
                if let Some(p) = r.pool {
                    by_pool[p.index()] += secs;
                }
            }
            by_pool
        } else {
            Vec::new()
        };
        let links = self.cfg.cluster.transfer_links.max(1) as f64;
        let transfer_utilization = if self.cfg.cluster.disagg() && horizon > 0.0 {
            self.transfer_busy / (links * horizon)
        } else {
            0.0
        };
        let mut report = ClusterReport::new(
            self.router.name().to_string(),
            per_replica,
            ClusterCounters {
                routed: self.routed.clone(),
                re_routed: self.re_routed,
                drained: self.drained,
                migrated: self.migrated,
                stolen: self.stolen,
                steals_skipped: self.steals_skipped(),
                transfers: self.transfers,
                transfer_tokens: self.transfer_tokens,
                transfer_utilization,
                pool_replica_seconds,
                domain_outages: self.domain_outages,
                downtime,
                replica_seconds,
                scaling_events: self.scaling_events.clone(),
                fastpath: self.fastpath,
            },
            &self.merged_outcomes(),
            warmup_fraction,
            &self.cfg.slo.specs,
        );
        // per-replica taus measure the replicas' *local* predictors and are
        // not summable; the aggregate reports the shared routing
        // predictor's cluster-wide ordering quality instead (the hit/miss
        // counters stay per-replica sums — those *are* additive)
        report.aggregate.pred_tau = self.pred_tau.tau();
        report.aggregate.pred_tau_n = self.pred_tau.len() as u64;
        report
    }

    // =======================================================================
    // Routing + stepping mechanics
    // =======================================================================

    /// Routable snapshot: one view per *routable* (Active) replica.
    /// `ReplicaView::id` carries the true replica index, which no longer
    /// matches the position in the returned slice once any replica is down,
    /// provisioning, or draining — routers return positions, the dispatcher
    /// maps them back through `id`.
    pub(crate) fn views(&self) -> Vec<ReplicaView> {
        self.views_for(None)
    }

    /// Routable snapshot restricted to one pool (`None` = every routable
    /// replica). Under disaggregated serving fresh work routes over
    /// `Some(Prefill)` and fabric handoffs over `Some(Decode)`; colocated
    /// replicas carry no role, so a pool filter there yields no views.
    pub(crate) fn views_for(&self, pool: Option<PoolRole>) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.routable() && (pool.is_none() || r.pool == pool))
            .map(|(i, r)| ReplicaView {
                id: i,
                live: r.coord.live_count(),
                kv_used_blocks: r.coord.kv.used_blocks(),
                kv_total_blocks: r.coord.kv.total_blocks(),
                now: r.coord.now(),
                speed: r.speed,
                max_batch: r.coord.engine.max_batch(),
                predicted_backlog: self.backlog[i],
                predicted_backlog_var: self.backlog_var[i],
                // warmth is per-request: the admission path overwrites
                // these after probing each replica's prefix index
                warm_prefix_tokens: 0,
                warm_cost_saving: 0.0,
            })
            .collect()
    }

    /// Pool fresh work routes over: the prefill pool under disaggregated
    /// serving (crash re-dispatch included — a lost request restarts from
    /// scratch, so it needs prefill again), every routable replica
    /// otherwise.
    pub(crate) fn intake_pool(&self) -> Option<PoolRole> {
        self.cfg.cluster.disagg().then_some(PoolRole::Prefill)
    }

    /// Index and clock of the busy replica with the smallest virtual time,
    /// if any replica has live work. Only Active and Draining replicas can
    /// hold live work (Down replicas are drained at failure time,
    /// Provisioning/Retired ones never held any), so only those are
    /// stepped — a Draining replica keeps running until its last live
    /// request finishes. Answered from the busy-clock index; the retained
    /// roster scan is the oracle under `use_indexes = false` (and the
    /// debug-build cross-check).
    pub(crate) fn earliest_busy(&mut self) -> Option<(usize, f64)> {
        if !self.use_indexes {
            return self.earliest_busy_scan();
        }
        let best = self.indexes.earliest_busy();
        debug_assert_eq!(
            best,
            self.earliest_busy_scan(),
            "busy index diverged from the roster scan"
        );
        best
    }

    /// Full-roster scan behind [`ClusterCtx::earliest_busy`].
    fn earliest_busy_scan(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            let steppable = matches!(r.state, ReplicaState::Active | ReplicaState::Draining);
            if !steppable || r.coord.is_idle() {
                continue;
            }
            let t = r.coord.now();
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((i, t));
            }
        }
        best
    }

    /// Snapshot the per-replica fields the indexes score from.
    fn sample_of(&self, i: usize) -> Sample {
        let r = &self.replicas[i];
        Sample {
            state: r.state,
            pool: r.pool,
            is_idle: r.coord.is_idle(),
            now: r.coord.now(),
            live: r.coord.live_count(),
            kv_used_blocks: r.coord.kv.used_blocks(),
            kv_total_blocks: r.coord.kv.total_blocks(),
            speed: r.speed,
            backlog: self.backlog[i],
            backlog_var: self.backlog_var[i],
        }
    }

    /// Refresh replica `i`'s index entries after anything that may have
    /// changed its state, clock, live set, KV usage, or backlog moments.
    /// Every mutation site calls this; missing one is caught by the
    /// debug-build cross-checks and the differential-equivalence suite.
    pub(crate) fn sync_replica(&mut self, i: usize) {
        if !self.use_indexes {
            return;
        }
        let s = self.sample_of(i);
        self.indexes.sync(i, &s);
        if let Some(d) = self.decode_indexes.as_mut() {
            d.sync(i, &s);
        }
    }

    /// Register a freshly-appended replica with the indexes. NOT gated on
    /// `use_indexes`: the probe table must stay in lockstep with the
    /// roster length even while the oracle runs, or enabling traces later
    /// would index out of bounds.
    pub(crate) fn index_add_replica(&mut self, i: usize) {
        let s = self.sample_of(i);
        self.indexes.add_replica(&s);
        if let Some(d) = self.decode_indexes.as_mut() {
            d.add_replica(&s);
        }
    }

    /// The index instance covering dispatch scope `pool`: the intake
    /// instance for the intake pool, the decode twin for the decode pool
    /// under disaggregation, `None` for any scope no index covers (the
    /// caller rescans).
    pub(crate) fn scoped_indexes(&self, pool: Option<PoolRole>) -> Option<&RouterIndexes> {
        if pool == self.intake_pool() {
            Some(&self.indexes)
        } else if pool == Some(PoolRole::Decode) {
            self.decode_indexes.as_ref()
        } else {
            None
        }
    }

    /// Mutable twin of [`ClusterCtx::scoped_indexes`].
    pub(crate) fn scoped_indexes_mut(
        &mut self,
        pool: Option<PoolRole>,
    ) -> Option<&mut RouterIndexes> {
        if pool == self.intake_pool() {
            Some(&mut self.indexes)
        } else if pool == Some(PoolRole::Decode) {
            self.decode_indexes.as_mut()
        } else {
            None
        }
    }

    /// Bump one of the per-scope fast-path coverage counters.
    pub(crate) fn count_fastpath(&mut self, scope: DispatchScope, outcome: FastPathOutcome) {
        let c = self.fastpath.scope_mut(scope);
        match outcome {
            FastPathOutcome::Hit => c.hits += 1,
            FastPathOutcome::Fallback => c.fallbacks += 1,
            FastPathOutcome::Rescan => c.rescans += 1,
        }
    }

    /// Answer a declared [`FastPath`] from the index covering dispatch
    /// scope `pool`: the replica id the rescan would pick, or `None` when
    /// the fast path does not apply (no index covers the scope, a
    /// z-mismatch, or the scope is empty — the caller falls through to the
    /// rescan, which produces the canonical error/fallback). `decode` names
    /// which router's round-robin cursor a [`FastPath::RoundRobin`] answer
    /// advances. [`FastPath::Affinity`] is not answered here — call
    /// [`ClusterCtx::affinity_route`], which needs the request's cost and
    /// pricing. Debug builds cross-check every answer against the rescan
    /// oracle.
    pub(crate) fn index_route(
        &mut self,
        fp: FastPath,
        pool: Option<PoolRole>,
        decode: bool,
    ) -> Option<usize> {
        if self.scoped_indexes(pool).is_none() {
            return None;
        }
        let choice = match fp {
            FastPath::Rescan | FastPath::Affinity => None,
            FastPath::RoundRobin => {
                #[cfg(debug_assertions)]
                {
                    let ids: Vec<usize> =
                        self.views_for(pool).iter().map(|v| v.id).collect();
                    debug_assert_eq!(
                        self.scoped_indexes_mut(pool).unwrap().roster(),
                        ids.as_slice(),
                        "round-robin roster diverged from the routable view set"
                    );
                }
                let len = self.scoped_indexes_mut(pool).unwrap().roster().len();
                if len == 0 {
                    None
                } else {
                    let slot = if decode {
                        self.decode_router
                            .as_mut()
                            .expect("decode dispatch without a decode router")
                            .advance_cursor(len)
                    } else {
                        self.router.advance_cursor(len)
                    };
                    Some(self.scoped_indexes_mut(pool).unwrap().roster()[slot])
                }
            }
            FastPath::LeastLoaded => {
                self.scoped_indexes_mut(pool).unwrap().best(Metric::Live)
            }
            FastPath::LeastKv => self.scoped_indexes_mut(pool).unwrap().best(Metric::Kv),
            FastPath::CostAware => {
                self.scoped_indexes_mut(pool).unwrap().best(Metric::Cost)
            }
            FastPath::QuantileCost { z } => {
                let idx = self.scoped_indexes_mut(pool).unwrap();
                if z == idx.quantile_z() {
                    idx.best(Metric::Quantile)
                } else {
                    None
                }
            }
            FastPath::TightQuantile { z } => {
                let idx = self.scoped_indexes_mut(pool).unwrap();
                if z == idx.tight_z() {
                    // mirror the class-aware eligibility rule: the
                    // headroom-filtered heap when any replica qualifies,
                    // the full scope otherwise
                    if idx.headroom_count() > 0 {
                        idx.best(Metric::TightHeadroom)
                    } else {
                        idx.best(Metric::TightQuantile)
                    }
                } else {
                    None
                }
            }
        };
        #[cfg(debug_assertions)]
        self.debug_check_index_route(fp, pool, choice);
        choice
    }

    /// Debug-build oracle: the scored fast paths must agree with a literal
    /// rescan of the scope's views using the routers' own arithmetic.
    #[cfg(debug_assertions)]
    fn debug_check_index_route(
        &self,
        fp: FastPath,
        pool: Option<PoolRole>,
        choice: Option<usize>,
    ) {
        use super::router::argmin;
        let Some(idx) = self.scoped_indexes(pool) else { return };
        match fp {
            // Rescan/Affinity never answered here; RoundRobin already
            // advanced the shared cursor, so re-running it would skew the
            // cycle
            FastPath::Rescan | FastPath::Affinity | FastPath::RoundRobin => return,
            FastPath::QuantileCost { z } if z != idx.quantile_z() => return,
            FastPath::TightQuantile { z } if z != idx.tight_z() => return,
            _ => {}
        }
        let views = self.views_for(pool);
        let expect = if views.is_empty() {
            None
        } else {
            let slot = match fp {
                FastPath::LeastLoaded => argmin(views.iter().map(|r| r.live)),
                FastPath::LeastKv => argmin(views.iter().map(|r| r.kv_occupancy())),
                FastPath::CostAware => {
                    argmin(views.iter().map(|r| r.predicted_backlog / r.speed.max(1e-9)))
                }
                FastPath::QuantileCost { z } => argmin(views.iter().map(|r| {
                    let q = r.predicted_backlog + z * r.predicted_backlog_var.max(0.0).sqrt();
                    q / r.speed.max(1e-9)
                })),
                FastPath::TightQuantile { z } => {
                    // the class-aware Interactive rescan, verbatim
                    let eligible: Vec<usize> = (0..views.len())
                        .filter(|&s| views[s].kv_occupancy() <= TIGHT_KV_HEADROOM)
                        .collect();
                    let pool_slots: Vec<usize> = if eligible.is_empty() {
                        (0..views.len()).collect()
                    } else {
                        eligible
                    };
                    let best = argmin(pool_slots.iter().map(|&s| {
                        let r = &views[s];
                        let q = r.predicted_backlog
                            + z * r.predicted_backlog_var.max(0.0).sqrt();
                        q / r.speed.max(1e-9)
                    }));
                    pool_slots[best]
                }
                FastPath::Rescan | FastPath::Affinity | FastPath::RoundRobin => {
                    unreachable!()
                }
            };
            Some(views[slot].id)
        };
        debug_assert_eq!(
            choice, expect,
            "index fast path diverged from the rescan oracle for {fp:?}"
        );
    }

    /// Record that a request carrying prefix head `req.prefix_key[0]`
    /// landed on replica `i` — maintaining the warm-site superset
    /// invariant (see [`ClusterCtx::warm_sites`]). Every landing path
    /// (admission, stealing, migration, fabric delivery) calls this;
    /// missing one would let a warm replica hide from the affinity fast
    /// path and diverge from the rescan oracle. Not gated on
    /// `use_indexes`: the map must be identical whichever mode runs, so a
    /// mid-run comparison of the two modes' state stays meaningful.
    pub(crate) fn note_warm_site(&mut self, req: &Request, i: usize) {
        if let Some(&head) = req.prefix_key.first() {
            let sites = self.warm_sites.entry(head).or_default();
            if !sites.contains(&i) {
                sites.push(i);
            }
        }
    }

    /// Cache-affinity dispatch from the scope's cost heap: probe only a
    /// bounded shortlist (top-K base scores) plus the request's known warm
    /// sites, and accept the winner only when a dominance bound proves no
    /// unprobed replica can beat it. Returns `None` — caller falls back to
    /// the rescan — when no index covers the scope, the scope is empty, or
    /// the bound fails.
    ///
    /// Soundness of the bound: every replica outside the probed candidate
    /// set has zero warm saving (the warm-site superset invariant), so its
    /// full score `(backlog + pcost − 0) / speed` is at least
    /// `max(backlog/speed, pcost/speed_max)` — both floors are monotone
    /// under IEEE rounding — and `backlog/speed` for every unprobed
    /// replica is at least the shortlist runner-up's base score. On a tie
    /// with the bound, the winner stands only when the bound came from the
    /// runner-up's base score and the winner's id is lower: any unprobed
    /// achiever then shares the runner-up's base score, and the heap's
    /// `(score, id)` order guarantees its id is at least the runner-up's.
    /// A tie against the `pcost/speed_max` floor proves nothing about ids,
    /// so it falls back.
    pub(crate) fn affinity_route(
        &mut self,
        req: &Request,
        pcost: f64,
        pool: Option<PoolRole>,
        pricing: WarmPricing<'_>,
    ) -> Option<usize> {
        // the bound needs pcost ≥ 0 (true for every cost model in tree;
        // guard anyway so a future signed or NaN cost cannot misroute)
        if pcost.is_nan() || pcost < 0.0 {
            return None;
        }
        let k = self.cfg.cluster.shortlist_k;
        self.scoped_indexes(pool)?;
        let mut warm = std::mem::take(&mut self.scratch_warm);
        let mut cand = std::mem::take(&mut self.scratch_shortlist);
        warm.clear();
        cand.clear();
        let head = req.prefix_key.first().copied();
        if let Some(h) = head {
            if let Some(sites) = self.warm_sites.get(&h) {
                let idx = self.scoped_indexes(pool).unwrap();
                warm.extend(sites.iter().copied().filter(|&i| idx.in_scope(i)));
            }
        }
        let idx = self.scoped_indexes_mut(pool).unwrap();
        let next = idx.shortlist(Metric::Cost, k, |id| warm.contains(&id), &mut cand);
        let agg = idx.aggregates();
        for &w in &warm {
            if !cand.contains(&w) {
                cand.push(w);
            }
        }
        // probe the candidates with the exact rescan arithmetic; collect
        // warm-site entries proven cold for lazy pruning
        let mut best: Option<(f64, usize)> = None;
        let mut pruned = false;
        for &i in &cand {
            let mut warm_tokens = 0u32;
            if !req.prefix_key.is_empty() {
                warm_tokens = self.replicas[i]
                    .coord
                    .kv
                    .cached_prefix_tokens(&req.prefix_key, req.input_len as usize)
                    as u32;
            }
            let saving = if warm_tokens > 0 {
                match &pricing {
                    WarmPricing::Admission(pred) => {
                        let warm_cost = self
                            .cost
                            .cost_dist(req.input_len.saturating_sub(warm_tokens), pred)
                            .mean();
                        (pcost - warm_cost).max(0.0)
                    }
                    WarmPricing::Consumed => self.cost.consumed(warm_tokens, 0),
                }
            } else {
                // a zero probe with at least one whole block of prompt
                // proves the head block is not resident — for *every*
                // request sharing this head — so the warm-site entry can
                // go (a future landing re-inserts it)
                if warm.contains(&i)
                    && req.input_len as usize > self.replicas[i].coord.kv.block_tokens()
                {
                    warm.retain(|&w| w != i);
                    pruned = true;
                }
                0.0
            };
            let s = saving.clamp(0.0, pcost.max(0.0));
            let full = (self.backlog[i] + pcost - s) / self.replicas[i].speed.max(1e-9);
            if best.map_or(true, |(bf, bi)| full < bf || (full == bf && i < bi)) {
                best = Some((full, i));
            }
        }
        if pruned {
            if let Some(h) = head {
                if let Some(sites) = self.warm_sites.get_mut(&h) {
                    // drop exactly the probed-and-proven-cold entries: a
                    // site outside `cand` was never probed (out of scope)
                    // and stays; a probed site stays iff still warm-listed
                    sites.retain(|i| warm.contains(i) || !cand.contains(i));
                    if sites.is_empty() {
                        self.warm_sites.remove(&h);
                    }
                }
            }
        }
        let accept = match (best, next) {
            (None, _) => false, // empty scope: rescan produces the canonical path
            (Some(_), None) => true, // candidates cover the whole scope
            (Some((best_full, best_id)), Some((base_next, id_next))) => {
                let floor = canon(pcost / agg.speed_max);
                let bound = base_next.max(floor);
                best_full < bound
                    || (best_full == bound && base_next >= floor && best_id < id_next)
            }
        };
        let choice = if accept { best.map(|(_, i)| i) } else { None };
        self.scratch_warm = warm;
        self.scratch_shortlist = cand;
        #[cfg(debug_assertions)]
        if choice.is_some() {
            self.debug_check_affinity_route(req, pcost, pool, &pricing, choice);
        }
        choice
    }

    /// Debug-build oracle for [`ClusterCtx::affinity_route`]: an accepted
    /// shortlist winner must equal the full-rescan cache-affinity pick.
    #[cfg(debug_assertions)]
    fn debug_check_affinity_route(
        &self,
        req: &Request,
        pcost: f64,
        pool: Option<PoolRole>,
        pricing: &WarmPricing<'_>,
        choice: Option<usize>,
    ) {
        let views = self.views_for(pool);
        let mut best: Option<(f64, usize)> = None;
        for v in &views {
            let mut warm_tokens = 0u32;
            if !req.prefix_key.is_empty() {
                warm_tokens = self.replicas[v.id]
                    .coord
                    .kv
                    .cached_prefix_tokens(&req.prefix_key, req.input_len as usize)
                    as u32;
            }
            let raw = if warm_tokens > 0 {
                match pricing {
                    WarmPricing::Admission(pred) => {
                        let warm_cost = self
                            .cost
                            .cost_dist(req.input_len.saturating_sub(warm_tokens), pred)
                            .mean();
                        (pcost - warm_cost).max(0.0)
                    }
                    WarmPricing::Consumed => self.cost.consumed(warm_tokens, 0),
                }
            } else {
                0.0
            };
            let saving = raw.clamp(0.0, pcost.max(0.0));
            let score = (v.predicted_backlog + pcost - saving) / v.speed.max(1e-9);
            if best.map_or(true, |(b, _)| score < b) {
                best = Some((score, v.id));
            }
        }
        debug_assert_eq!(
            choice,
            best.map(|(_, id)| id),
            "affinity shortlist diverged from the rescan oracle"
        );
    }

    /// Whether any replica still holds live (queued/running/preempted)
    /// work.
    pub(crate) fn has_live_work(&self) -> bool {
        self.replicas.iter().any(|r| !r.coord.is_idle())
    }

    /// Route and submit one request. `not_before` is the earliest virtual
    /// time the target may start it: the arrival time for fresh requests,
    /// the failure instant for re-dispatched ones (an idle survivor with a
    /// lagging clock must not serve work "before" the crash that freed it).
    /// Fails hard when no replica is alive or the router returns an
    /// out-of-range position — both are configuration/implementation errors
    /// that must not be silently patched. A refused submission counts as a
    /// rejection (crash re-dispatch and fresh arrivals share admission
    /// semantics). Placement itself — including the admission consult — is
    /// the [`SloAdmission`] component's concern.
    pub(crate) fn dispatch(&mut self, req: Request, not_before: f64) -> anyhow::Result<()> {
        SloAdmission.place(self, req, not_before, None)?;
        Ok(())
    }

    /// Run one scheduling iteration on replica `i` and drain its new
    /// completions into cluster bookkeeping (backlog release + shared
    /// predictor learning). Returns false when the step made no observable
    /// progress (clock, completions, aborts, and live set all unchanged) —
    /// with live work that means the replica is wedged (e.g. a request that
    /// can never fit its KV capacity) and the caller must not keep spinning.
    fn step_replica(&mut self, i: usize) -> anyhow::Result<bool> {
        self.replica_steps += 1;
        let (now0, live0) = {
            let c = &self.replicas[i].coord;
            (c.now(), c.live_count())
        };
        self.replicas[i].coord.step()?;
        // reuse one scratch buffer across steps: this is the hottest loop
        // in the cluster, and a fresh Vec per step is pure churn
        let mut new = std::mem::take(&mut self.scratch_completions);
        new.clear();
        {
            let r = &self.replicas[i];
            new.extend(
                r.coord.outcomes()[r.seen_outcomes..]
                    .iter()
                    .map(|o| (o.id, o.output_len)),
            );
        }
        self.replicas[i].seen_outcomes += new.len();
        let live_now = self.replicas[i].coord.live_count();
        let progressed =
            !new.is_empty() || self.replicas[i].coord.now() > now0 || live_now != live0;
        // completions / live-set changes move backlogs and can idle a
        // replica — both alter steal verdicts; a bare clock advance cannot
        if !new.is_empty() || live_now != live0 {
            self.steal_dirty = true;
        }
        for &(id, output_len) in new.iter() {
            if let Some(f) = self.in_flight.remove(&id) {
                // every migration path rewrites `replica` when an entry
                // moves, so a completion here always releases *this*
                // replica's backlog — the single-sync below relies on it
                debug_assert_eq!(f.replica, i, "completed on a replica it was not booked to");
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
                // one observation per request: re-dispatch paths re-insert
                // in-flight entries under the same id, so the removal above
                // alone does not bound how often a request can land here
                if self.observed.insert(id) {
                    self.predictor.observe(&f.req, output_len);
                    self.pred_tau.push(f.rank, output_len as f64);
                }
            }
        }
        self.scratch_completions = new;
        // Reconcile timeout-aborts: they leave the live set without an
        // outcome, so their backlog contribution must be released here or
        // the cost-aware router would shun this replica forever.
        if self.replicas[i].coord.aborted > self.replicas[i].seen_aborted {
            self.replicas[i].seen_aborted = self.replicas[i].coord.aborted;
            let mut gone = std::mem::take(&mut self.scratch_gone);
            gone.clear();
            {
                let coord = &self.replicas[i].coord;
                gone.extend(
                    self.in_flight
                        .iter()
                        .filter(|(id, entry)| {
                            entry.replica == i
                                && !coord.is_live(**id)
                                // a request on the fabric left this replica
                                // deliberately; its entry survives until
                                // delivery
                                && !self.in_transfer.contains(*id)
                        })
                        .map(|(id, _)| *id),
                );
            }
            // the table's iteration order is not deterministic; releasing
            // in id order keeps the float bookkeeping — and therefore every
            // downstream routing/scaling decision and the report JSON —
            // byte-identical across runs of the same seed
            gone.sort_unstable();
            for &id in gone.iter() {
                if let Some(f) = self.in_flight.remove(&id) {
                    self.release_backlog(f.replica, f.cost, f.var, f.weight);
                }
            }
            self.scratch_gone = gone;
        }
        self.sync_replica(i);
        Ok(progressed)
    }

    /// Release one request's contribution to a replica's predicted-cost
    /// moments and the cluster-wide weighted moments (floored at 0 against
    /// accumulated float error).
    pub(crate) fn release_backlog(&mut self, replica: usize, cost: f64, var: f64, weight: f64) {
        self.backlog[replica] = (self.backlog[replica] - cost).max(0.0);
        self.backlog_var[replica] = (self.backlog_var[replica] - var).max(0.0);
        self.backlog_weighted = (self.backlog_weighted - weight * cost).max(0.0);
        self.backlog_weighted_var =
            (self.backlog_weighted_var - weight * weight * var).max(0.0);
    }

    /// Step replica `i` and fail loudly if it is wedged instead of spinning
    /// forever. A no-progress step with live work means some request can
    /// never be scheduled (e.g. its prompt needs more KV blocks than the
    /// replica owns), which is a configuration error, not a transient.
    /// A draining replica whose last live request just finished retires
    /// here.
    pub(crate) fn check_progress(&mut self, i: usize) -> anyhow::Result<()> {
        if !self.step_replica(i)? {
            anyhow::bail!(
                "replica {i} is wedged: {} live request(s) but a scheduling \
                 iteration made no progress — its capacity (kv_capacity {} \
                 tokens, max_batch {}) cannot serve the routed workload",
                self.replicas[i].coord.live_count(),
                self.replicas[i].coord.kv.total_blocks()
                    * self.replicas[i].coord.kv.block_tokens(),
                self.replicas[i].coord.engine.max_batch(),
            );
        }
        if self.replicas[i].state == ReplicaState::Draining
            && self.replicas[i].coord.is_idle()
        {
            let at = self.replicas[i].coord.now();
            self.retire(i, at);
        }
        Ok(())
    }

    // Replica lifecycle + scale-in mechanics live in
    // `cluster/lifecycle.rs` (a second `impl ClusterCtx` block).
}

