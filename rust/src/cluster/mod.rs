//! Multi-replica cluster serving (§4.4 scalability).
//!
//! Two modes live here:
//!
//! **Event-driven cluster simulation** (the primary mode): N replicas, each
//! a full [`Coordinator`]`<`[`SimEngine`]`>` — real continuous batching,
//! KV-block accounting, preemption — driven on a shared *virtual* clock
//! behind a pluggable [`Router`]. The event loop interleaves replica
//! scheduling iterations and request arrivals in global-time order: while
//! any busy replica's clock trails the next arrival it steps that replica
//! (each step advances that replica's clock by its engine-charged seconds);
//! once every busy replica has caught up, the arrival is routed using the
//! replicas' *current* state and submitted. Replicas may be heterogeneous
//! (per-replica speed / batch-size / KV-capacity from
//! [`ClusterConfig`](crate::config::ClusterConfig)), and a *shared*
//! prediction service (one history index fronting the whole cluster, as the
//! paper's centralized scheduler has) prices each arrival for the
//! cost-aware router and learns online from every replica's completions.
//!
//! Routers: `round-robin`, `least-loaded` (live-request count), `least-kv`
//! (KV-block occupancy), and `cost-aware` (predicted outstanding cost from
//! the shared predictor's [`LengthDist`], normalized by replica speed).
//! Routers see only the *surviving* replica set and return positions into
//! it; the dispatcher maps positions back to replica ids.
//!
//! **Replica lifecycle**: [`ClusterConfig`](crate::config::ClusterConfig)
//! may schedule [`FailureEvent`](crate::config::FailureEvent)s. At failure
//! time the replica's live requests are drained (crash semantics — queued,
//! running, and preempted state is lost), cluster bookkeeping for them is
//! reconciled, and each is re-dispatched through the router over the
//! survivors (`re_routed` in [`ClusterReport`]). The replica rejoins the
//! routable set, empty, at recovery time; its downtime is reported
//! per-replica. Between events, **work stealing** lets an idle replica take
//! up to half of the most-backlogged replica's never-scheduled (queued)
//! requests — those hold no KV/engine state, so migration is free
//! (`stolen` in the report).
//!
//! Arrival pacing — including the bursty MMPP and diurnal processes under
//! which failure/re-routing is most interesting — lives in
//! [`crate::workload::arrivals`] and is configured per workload.
//!
//! **Overhead measurement** (the legacy fig12 mode, [`ClusterSim`]):
//! wallclock-measured per-request predicting/scheduling latency of the
//! shared services as the cluster grows, with M/M/1 queueing at the shared
//! predictor. Kept as a secondary mode behind `sagesched cluster
//! --overhead`.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::{ExperimentConfig, RouterKind};
use crate::core::{Request, RequestId};
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::engine::{Engine, SimEngine};
use crate::gittins::gittins_index_at_age;
use crate::metrics::{ClusterReport, RunReport};
use crate::predictor::{HistoryPredictor, Predictor};
use crate::serve::Coordinator;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::WorkloadGen;

// ===========================================================================
// Routers
// ===========================================================================

/// Snapshot of one replica's state at routing time.
#[derive(Clone, Debug)]
pub struct ReplicaView {
    /// Replica index.
    pub id: usize,
    /// Live requests (queued + running + preempted).
    pub live: usize,
    /// Allocated KV blocks.
    pub kv_used_blocks: usize,
    /// Total KV blocks.
    pub kv_total_blocks: usize,
    /// Replica-local virtual clock (seconds).
    pub now: f64,
    /// Speed multiplier of this replica (1.0 = base profile).
    pub speed: f64,
    /// Max decode batch of this replica.
    pub max_batch: usize,
    /// Sum of predicted E[total cost] of requests routed here that have not
    /// completed yet (maintained by the cluster from the shared predictor).
    pub predicted_backlog: f64,
}

impl ReplicaView {
    /// KV occupancy fraction in [0, 1].
    pub fn kv_occupancy(&self) -> f64 {
        if self.kv_total_blocks == 0 {
            0.0
        } else {
            self.kv_used_blocks as f64 / self.kv_total_blocks as f64
        }
    }
}

/// A cluster front-door routing policy. Implementations must be
/// deterministic given the same request/view sequence so cluster runs are
/// exactly reproducible.
pub trait Router: Send {
    fn kind(&self) -> RouterKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Pick a *position in the `replicas` slice* for `req` (the caller maps
    /// it back to a replica through [`ReplicaView::id`]). The slice holds
    /// only routable — alive — replicas, so positions and replica ids
    /// diverge once any replica has failed; returning `ReplicaView::id`
    /// here is a misroute. `predicted_cost` is the shared predictor's
    /// E[total service cost] for this request (cost-model units);
    /// `replicas` is never empty. Out-of-range returns are a hard dispatch
    /// error, never clamped.
    fn route(&mut self, req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize;
}

/// Cycle through replicas in submission order.
#[derive(Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::RoundRobin
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let i = self.next % replicas.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Fewest live requests; ties break to the lowest replica index.
#[derive(Default)]
pub struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastLoaded
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let loads: Vec<usize> = replicas.iter().map(|r| r.live).collect();
        route_least_loaded(&loads)
    }
}

/// Lowest KV-block occupancy fraction; ties break to the lowest index.
#[derive(Default)]
pub struct LeastKvRouter;

impl Router for LeastKvRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastKv
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let mut best = 0usize;
        let mut best_occ = f64::INFINITY;
        for (slot, r) in replicas.iter().enumerate() {
            let occ = r.kv_occupancy();
            if occ < best_occ {
                best_occ = occ;
                best = slot;
            }
        }
        best
    }
}

/// Smallest predicted outstanding cost normalized by replica speed — the
/// uncertainty-aware router: it routes by E[remaining work], not by request
/// *count*, so a replica stuck with a few predicted-long generations stops
/// attracting traffic even while its live count is low.
#[derive(Default)]
pub struct CostAwareRouter;

impl Router for CostAwareRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::CostAware
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        for (slot, r) in replicas.iter().enumerate() {
            let load = r.predicted_backlog / r.speed.max(1e-9);
            if load < best_load {
                best_load = load;
                best = slot;
            }
        }
        best
    }
}

/// Build a router from its kind.
pub fn make_router(kind: RouterKind) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
        RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        RouterKind::LeastKv => Box::new(LeastKvRouter),
        RouterKind::CostAware => Box::new(CostAwareRouter),
    }
}

/// Least-loaded routing decision across per-node live counts (exposed for
/// tests and the cluster example).
pub fn route_least_loaded(loads: &[usize]) -> usize {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(_, &l)| l)
        .map(|(i, _)| i)
        .expect("route over empty cluster")
}

// ===========================================================================
// Event-driven cluster
// ===========================================================================

/// One serving replica inside the event-driven cluster.
pub struct ClusterReplica {
    pub coord: Coordinator<SimEngine>,
    /// Speed multiplier this replica was built with.
    pub speed: f64,
    /// Whether the replica is alive (routable). Failed replicas are
    /// excluded from every router's view until their recovery event.
    pub up: bool,
    /// Virtual time the current outage began (meaningful while `!up`).
    down_since: f64,
    /// Accumulated downtime over completed outages (seconds).
    pub downtime: f64,
    /// Outcomes already drained into cluster-level bookkeeping.
    seen_outcomes: usize,
    /// Timeout-aborts already reconciled into cluster-level bookkeeping.
    seen_aborted: u64,
}

/// One replica lifecycle transition derived from
/// [`FailureEvent`](crate::config::FailureEvent)s: at `at`, replica
/// `replica` goes down (`up == false`) or rejoins (`up == true`).
#[derive(Clone, Copy, Debug)]
struct LifecycleEvent {
    at: f64,
    replica: usize,
    up: bool,
}

/// The event-driven multi-replica cluster: N coordinators on a shared
/// virtual clock behind a [`Router`], with a shared prediction service,
/// replica failure/recovery, and idle-replica work stealing.
pub struct EventCluster {
    pub cfg: ExperimentConfig,
    pub replicas: Vec<ClusterReplica>,
    pub router: Box<dyn Router>,
    /// Shared prediction service (prices arrivals; learns from completions).
    pub predictor: Box<dyn Predictor>,
    cost: Box<dyn CostModel>,
    /// id -> (replica, predicted E[total cost], original request).
    in_flight: HashMap<RequestId, (usize, f64, Request)>,
    /// Per-replica sum of predicted cost of in-flight requests.
    backlog: Vec<f64>,
    /// Per-replica routed-request counts.
    pub routed: Vec<u64>,
    /// Requests re-dispatched through the router after a replica failure.
    pub re_routed: u64,
    /// Queued requests migrated to an idle replica by work stealing.
    pub stolen: u64,
}

impl EventCluster {
    /// Build a cluster from `cfg` (replica count / router / heterogeneity
    /// from `cfg.cluster`), overriding the router with `router`.
    pub fn with_router(cfg: &ExperimentConfig, router: RouterKind) -> EventCluster {
        let n = cfg.cluster.replicas.max(1);
        let replicas: Vec<ClusterReplica> = (0..n)
            .map(|i| {
                let profile = cfg.cluster.replica_profile(&cfg.engine, i);
                let seed = cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                ClusterReplica {
                    coord: crate::serve::build_sim_coordinator_with(cfg, profile, seed),
                    speed: cfg.cluster.speed_of(i),
                    up: true,
                    down_since: 0.0,
                    downtime: 0.0,
                    seen_outcomes: 0,
                    seen_aborted: 0,
                }
            })
            .collect();
        let predictor = crate::predictor::make_predictor(
            cfg.predictor,
            cfg.workload.embed_dim,
            cfg.history_capacity,
            cfg.similarity_threshold,
            cfg.seed ^ 0xc175_7e12,
        );
        EventCluster {
            cfg: cfg.clone(),
            backlog: vec![0.0; n],
            routed: vec![0; n],
            re_routed: 0,
            stolen: 0,
            replicas,
            router: make_router(router),
            predictor,
            cost: crate::cost::make_cost_model(cfg.cost_model),
            in_flight: HashMap::new(),
        }
    }

    /// Requests refused at admission, cluster-wide. Each coordinator owns
    /// its own count (it is the sole place a refusal happens), so summing
    /// here counts every rejection exactly once.
    pub fn rejected(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.rejected).sum()
    }

    /// Requests aborted by queue timeout, cluster-wide.
    pub fn aborted(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.aborted).sum()
    }

    /// Requests the cluster still tracks as in flight (0 after a completed
    /// run — anything else means bookkeeping leaked).
    pub fn in_flight_count(&self) -> usize {
        self.in_flight.len()
    }

    /// Sum of per-replica predicted-cost backlogs (≈0 after a drained run).
    pub fn total_backlog(&self) -> f64 {
        self.backlog.iter().sum()
    }

    /// Build with the router configured in `cfg.cluster.router`.
    pub fn new(cfg: &ExperimentConfig) -> EventCluster {
        EventCluster::with_router(cfg, cfg.cluster.router)
    }

    /// Pre-warm the shared predictor and every replica's local predictor
    /// with the offline corpus (`cfg.history_prewarm`).
    pub fn prewarm(&mut self) {
        crate::serve::prewarm_predictor(self.predictor.as_mut(), &self.cfg);
        for r in &mut self.replicas {
            crate::serve::prewarm_predictor(r.coord.predictor.as_mut(), &self.cfg);
        }
    }

    /// Routable snapshot: one view per *surviving* replica. `ReplicaView::id`
    /// carries the true replica index, which no longer matches the position
    /// in the returned slice once any replica is down — routers return
    /// positions, the dispatcher maps them back through `id`.
    fn views(&self) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.up)
            .map(|(i, r)| ReplicaView {
                id: i,
                live: r.coord.live_count(),
                kv_used_blocks: r.coord.kv.used_blocks(),
                kv_total_blocks: r.coord.kv.total_blocks(),
                now: r.coord.now(),
                speed: r.speed,
                max_batch: r.coord.engine.max_batch(),
                predicted_backlog: self.backlog[i],
            })
            .collect()
    }

    /// Index and clock of the busy replica with the smallest virtual time,
    /// if any replica has live work. Down replicas hold no live work (their
    /// requests are drained at failure time) so they never get stepped.
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if !r.up || r.coord.is_idle() {
                continue;
            }
            let t = r.coord.now();
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((i, t));
            }
        }
        best
    }

    /// Route and submit one request. `not_before` is the earliest virtual
    /// time the target may start it: the arrival time for fresh requests,
    /// the failure instant for re-dispatched ones (an idle survivor with a
    /// lagging clock must not serve work "before" the crash that freed it).
    /// Fails hard when no replica is alive or the router returns an
    /// out-of-range position — both are configuration/implementation errors
    /// that must not be silently patched (the old `.min(len-1)` clamp
    /// turned router misroutes into quiet load skew).
    fn dispatch(&mut self, req: Request, not_before: f64) -> anyhow::Result<()> {
        let pred = self.predictor.predict(&req);
        let pcost = self.cost.cost_dist(req.input_len, &pred).mean();
        let views = self.views();
        if views.is_empty() {
            anyhow::bail!(
                "cannot route request {}: all {} replicas are down",
                req.id,
                self.replicas.len()
            );
        }
        let slot = self.router.route(&req, pcost, &views);
        if slot >= views.len() {
            anyhow::bail!(
                "router {} returned position {slot} but only {} replicas are \
                 routable",
                self.router.name(),
                views.len()
            );
        }
        let i = views[slot].id;
        let id = req.id;
        self.replicas[i].coord.advance_to(req.arrival.max(not_before));
        if self.replicas[i].coord.submit(req.clone()) {
            self.in_flight.insert(id, (i, pcost, req));
            self.backlog[i] += pcost;
            self.routed[i] += 1;
        }
        // refusals are counted by the coordinator itself (sole owner of the
        // rejected counter; see EventCluster::rejected)
        Ok(())
    }

    /// Run one scheduling iteration on replica `i` and drain its new
    /// completions into cluster bookkeeping (backlog release + shared
    /// predictor learning). Returns false when the step made no observable
    /// progress (clock, completions, aborts, and live set all unchanged) —
    /// with live work that means the replica is wedged (e.g. a request that
    /// can never fit its KV capacity) and the caller must not keep spinning.
    fn step_replica(&mut self, i: usize) -> anyhow::Result<bool> {
        let (now0, live0) = {
            let c = &self.replicas[i].coord;
            (c.now(), c.live_count())
        };
        self.replicas[i].coord.step()?;
        let new: Vec<(RequestId, u32)> = {
            let r = &self.replicas[i];
            r.coord.outcomes()[r.seen_outcomes..]
                .iter()
                .map(|o| (o.id, o.output_len))
                .collect()
        };
        self.replicas[i].seen_outcomes += new.len();
        let progressed = !new.is_empty()
            || self.replicas[i].coord.now() > now0
            || self.replicas[i].coord.live_count() != live0;
        for (id, output_len) in new {
            if let Some((rep, pcost, req)) = self.in_flight.remove(&id) {
                self.backlog[rep] = (self.backlog[rep] - pcost).max(0.0);
                self.predictor.observe(&req, output_len);
            }
        }
        // Reconcile timeout-aborts: they leave the live set without an
        // outcome, so their backlog contribution must be released here or
        // the cost-aware router would shun this replica forever.
        if self.replicas[i].coord.aborted > self.replicas[i].seen_aborted {
            self.replicas[i].seen_aborted = self.replicas[i].coord.aborted;
            let coord = &self.replicas[i].coord;
            let gone: Vec<RequestId> = self
                .in_flight
                .iter()
                .filter(|(id, entry)| entry.0 == i && !coord.is_live(**id))
                .map(|(id, _)| *id)
                .collect();
            for id in gone {
                if let Some((rep, pcost, _)) = self.in_flight.remove(&id) {
                    self.backlog[rep] = (self.backlog[rep] - pcost).max(0.0);
                }
            }
        }
        Ok(progressed)
    }

    /// Drive the full arrival stream to completion: global-time-ordered
    /// interleaving of replica iterations, routed arrivals, and replica
    /// failure/recovery events, then drain. Idle replicas steal queued work
    /// from backlogged peers between events.
    pub fn run(&mut self, mut requests: Vec<Request>) -> anyhow::Result<()> {
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let lifecycle = self.lifecycle_events()?;
        let mut idx = 0;
        let mut eidx = 0;
        loop {
            self.steal_work();
            let next_arrival = requests.get(idx).map(|r| r.arrival);
            let next_life = lifecycle.get(eidx).map(|e| e.at);
            // next externally-scheduled event (arrival or lifecycle
            // transition); lifecycle wins ties so same-instant arrivals
            // already route over the post-transition replica set
            let life_first = match (next_life, next_arrival) {
                (Some(tl), Some(ta)) => tl <= ta,
                (Some(_), None) => true,
                _ => false,
            };
            let next_event = match (next_life, next_arrival) {
                (Some(tl), Some(ta)) => Some(tl.min(ta)),
                (a, b) => a.or(b),
            };
            match (self.earliest_busy(), next_event) {
                // a busy replica trails the next event: advance it first
                (Some((i, t)), Some(te)) if t < te => self.check_progress(i)?,
                // all busy replicas have caught up: apply the event
                (_, Some(_)) => {
                    if life_first {
                        let ev = lifecycle[eidx];
                        eidx += 1;
                        self.apply_lifecycle(ev)?;
                    } else {
                        let r = requests[idx].clone();
                        idx += 1;
                        let at = r.arrival;
                        self.dispatch(r, at)?;
                    }
                }
                // events exhausted: drain remaining work
                (Some((i, _)), None) => self.check_progress(i)?,
                (None, None) => break,
            }
        }
        Ok(())
    }

    /// Expand the configured [`crate::config::FailureEvent`]s into a
    /// time-sorted down/up event stream. Overlapping or touching outage
    /// windows on one replica are merged into their union first — otherwise
    /// the earliest recovery of a nested outage would resurrect the replica
    /// while a longer outage is still running, undercounting downtime.
    fn lifecycle_events(&self) -> anyhow::Result<Vec<LifecycleEvent>> {
        let n = self.replicas.len();
        let mut by_replica: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n];
        for f in &self.cfg.cluster.failures {
            if f.replica >= n {
                anyhow::bail!(
                    "failure event references replica {} but the cluster has \
                     {n} replicas",
                    f.replica
                );
            }
            if let Err(e) = f.validate() {
                anyhow::bail!("{e}");
            }
            by_replica[f.replica].push((f.at, f.at + f.duration));
        }
        let mut events = Vec::with_capacity(self.cfg.cluster.failures.len() * 2);
        for (replica, mut windows) in by_replica.into_iter().enumerate() {
            windows.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut merged: Vec<(f64, f64)> = Vec::new();
            for (start, end) in windows {
                match merged.last_mut() {
                    Some(last) if start <= last.1 => last.1 = last.1.max(end),
                    _ => merged.push((start, end)),
                }
            }
            for (start, end) in merged {
                events.push(LifecycleEvent { at: start, replica, up: false });
                events.push(LifecycleEvent { at: end, replica, up: true });
            }
        }
        // recoveries before failures at equal times: a recovery on one
        // replica coinciding with a failure on another applies first, so
        // re-dispatch routes over the freshest surviving set
        events.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .unwrap()
                .then(b.up.cmp(&a.up))
                .then(a.replica.cmp(&b.replica))
        });
        Ok(events)
    }

    /// Apply one replica lifecycle transition. A failure drains everything
    /// the replica held — queued, running, and preempted requests lose their
    /// state, exactly as a crash would — releases the cluster-side
    /// backlog/in-flight bookkeeping for them, and re-dispatches each one
    /// through the router over the surviving replicas. A recovery returns
    /// the (empty) replica to the routable set and charges its downtime.
    fn apply_lifecycle(&mut self, ev: LifecycleEvent) -> anyhow::Result<()> {
        let i = ev.replica;
        if ev.up {
            if !self.replicas[i].up {
                self.replicas[i].up = true;
                self.replicas[i].downtime += ev.at - self.replicas[i].down_since;
                self.replicas[i].coord.advance_to(ev.at);
            }
            return Ok(());
        }
        if !self.replicas[i].up {
            return Ok(()); // overlapping outage: already down
        }
        self.replicas[i].up = false;
        self.replicas[i].down_since = ev.at;
        self.replicas[i].coord.advance_to(ev.at);
        let mut lost = self.replicas[i].coord.drain_live();
        for req in &lost {
            if let Some((rep, pcost, _)) = self.in_flight.remove(&req.id) {
                debug_assert_eq!(rep, i, "in-flight map out of sync at failure");
                self.backlog[rep] = (self.backlog[rep] - pcost).max(0.0);
            }
        }
        lost.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        self.re_routed += lost.len() as u64;
        for req in lost {
            self.dispatch(req, ev.at)?;
        }
        Ok(())
    }

    /// Idle-replica work stealing: while some alive replica sits idle and
    /// another has more than one live request including never-scheduled
    /// (queued) ones, migrate up to half of the victim's queued requests to
    /// the idle replica. Queued requests hold no KV or engine state, so the
    /// migration is free; the thief's clock is advanced to the victim's so
    /// no request runs before the moment it was provably stealable.
    fn steal_work(&mut self) {
        loop {
            let thief = match self
                .replicas
                .iter()
                .position(|r| r.up && r.coord.is_idle())
            {
                Some(t) => t,
                None => return,
            };
            // one queued_count() scan per replica (it walks the live vec);
            // ascending iteration with a strict `>` keeps ties on the
            // lowest index for determinism
            let mut best: Option<(usize, usize)> = None; // (replica, queued)
            for (j, r) in self.replicas.iter().enumerate() {
                if j == thief || !r.up || r.coord.live_count() < 2 {
                    continue;
                }
                let queued = r.coord.queued_count();
                if queued > 0 && best.map_or(true, |(_, bq)| queued > bq) {
                    best = Some((j, queued));
                }
            }
            let (v, v_queued) = match best {
                Some(b) => b,
                None => return,
            };
            // cap at the thief's admission window (it is idle, so its live
            // set is empty): stolen submissions must never be refused, or a
            // request that was safely queued would count as rejected
            let capacity = match self.replicas[thief].coord.max_queue {
                0 => usize::MAX,
                cap => cap,
            };
            let take = v_queued.div_ceil(2).min(capacity);
            let victim_now = self.replicas[v].coord.now();
            let moved = self.replicas[v].coord.drain_queued(take);
            if moved.is_empty() {
                return;
            }
            self.replicas[thief].coord.advance_to(victim_now);
            for req in moved {
                let id = req.id;
                let accepted = self.replicas[thief].coord.submit(req);
                debug_assert!(accepted, "idle thief must accept within its window");
                if !accepted {
                    continue;
                }
                self.stolen += 1;
                if let Some(entry) = self.in_flight.get_mut(&id) {
                    let pcost = entry.1;
                    self.backlog[entry.0] = (self.backlog[entry.0] - pcost).max(0.0);
                    self.backlog[thief] += pcost;
                    entry.0 = thief;
                }
            }
        }
    }

    /// Step replica `i` and fail loudly if it is wedged instead of spinning
    /// forever. A no-progress step with live work means some request can
    /// never be scheduled (e.g. its prompt needs more KV blocks than the
    /// replica owns), which is a configuration error, not a transient.
    fn check_progress(&mut self, i: usize) -> anyhow::Result<()> {
        if !self.step_replica(i)? {
            anyhow::bail!(
                "replica {i} is wedged: {} live request(s) but a scheduling \
                 iteration made no progress — its capacity (kv_capacity {} \
                 tokens, max_batch {}) cannot serve the routed workload",
                self.replicas[i].coord.live_count(),
                self.replicas[i].coord.kv.total_blocks()
                    * self.replicas[i].coord.kv.block_tokens(),
                self.replicas[i].coord.engine.max_batch(),
            );
        }
        Ok(())
    }

    /// Total completions across replicas.
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.coord.outcomes().len()).sum()
    }

    /// Merged outcome stream (unsorted).
    pub fn merged_outcomes(&self) -> Vec<crate::core::RequestOutcome> {
        let mut out = Vec::with_capacity(self.completed());
        for r in &self.replicas {
            out.extend_from_slice(r.coord.outcomes());
        }
        out
    }

    /// Cluster-level report (aggregate + per-replica + lifecycle counters).
    pub fn report(&self, warmup_fraction: f64) -> ClusterReport {
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|r| r.coord.report(warmup_fraction))
            .collect();
        // an outage still open at report time is charged up to the
        // cluster-wide clock horizon
        let horizon = self
            .replicas
            .iter()
            .map(|r| r.coord.now())
            .fold(0.0, f64::max);
        let downtime: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| r.downtime + if r.up { 0.0 } else { (horizon - r.down_since).max(0.0) })
            .collect();
        ClusterReport::new(
            self.router.name().to_string(),
            per_replica,
            crate::metrics::ClusterCounters {
                routed: self.routed.clone(),
                re_routed: self.re_routed,
                stolen: self.stolen,
                downtime,
            },
            &self.merged_outcomes(),
            warmup_fraction,
        )
    }
}

/// Run one event-driven cluster experiment with an explicit router over the
/// config's seeded workload. Callers comparing routers call this repeatedly
/// with the same `cfg`: the workload (same seed) is bit-identical across
/// calls, so reports are directly comparable.
pub fn run_router_experiment(
    cfg: &ExperimentConfig,
    router: RouterKind,
) -> anyhow::Result<ClusterReport> {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, router);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster.report(cfg.warmup_fraction))
}

/// Run the event-driven cluster with the router configured in
/// `cfg.cluster.router`.
pub fn run_event_cluster(cfg: &ExperimentConfig) -> anyhow::Result<ClusterReport> {
    run_router_experiment(cfg, cfg.cluster.router)
}

/// A multi-node serving simulation returning per-node reports: the cluster
/// serves `n_nodes`× the configured per-node load behind a least-loaded
/// router. Useful when sweeping cluster *size* at fixed per-node load
/// (the event-driven cluster does the work; [`run_router_experiment`] is
/// the entry point for fixed-load router comparisons).
pub fn run_cluster_experiment(
    cfg: &ExperimentConfig,
    n_nodes: usize,
) -> anyhow::Result<Vec<RunReport>> {
    let mut scaled = cfg.clone();
    scaled.workload.rps = cfg.workload.rps * n_nodes as f64;
    scaled.workload.n_requests = cfg.workload.n_requests * n_nodes;
    scaled.cluster.replicas = n_nodes;
    let workload = WorkloadGen::new(scaled.workload.clone(), scaled.seed).generate();
    let mut cluster = EventCluster::with_router(&scaled, RouterKind::LeastLoaded);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster
        .replicas
        .iter()
        .map(|r| r.coord.report(cfg.warmup_fraction))
        .collect())
}

// ===========================================================================
// Overhead measurement (legacy fig12 mode)
// ===========================================================================

/// Result of one cluster-scale overhead measurement.
#[derive(Clone, Debug)]
pub struct ClusterOverhead {
    pub nodes: usize,
    pub aggregate_rps: f64,
    /// mean per-request predict latency, seconds (service + queueing)
    pub predict_latency: f64,
    /// mean per-request scheduling latency, seconds (priority eval + sort
    /// at the configured queue depth)
    pub sched_latency: f64,
    /// total per-request overhead
    pub total_latency: f64,
    /// utilization of the shared predictor service
    pub predictor_utilization: f64,
}

/// Cluster-scalability overhead simulator (wallclock-measured shared
/// predictor + scheduler service times, M/M/1 queueing at the predictor).
pub struct ClusterSim {
    pub cfg: ExperimentConfig,
    /// per-node request rate (paper: 8 RPS/node)
    pub rps_per_node: f64,
    /// scheduler queue depth to exercise (paper: up to 1,000 buffered)
    pub queue_depth: usize,
    /// number of measured prediction/scheduling operations per point
    pub samples: usize,
}

impl ClusterSim {
    pub fn new(cfg: ExperimentConfig) -> ClusterSim {
        ClusterSim { cfg, rps_per_node: 8.0, queue_depth: 1000, samples: 200 }
    }

    /// Measure predict + schedule overhead for an `n_nodes` cluster.
    pub fn measure(&self, n_nodes: usize) -> ClusterOverhead {
        let mut rng = Rng::new(self.cfg.seed ^ (n_nodes as u64) << 8);

        // --- build a warm shared history index at paper scale -------------
        let mut wl_cfg = self.cfg.workload.clone();
        wl_cfg.n_requests = self.cfg.history_capacity.min(10_000);
        let warm = WorkloadGen::new(wl_cfg, self.cfg.seed ^ 0xc1).generate();
        let mut predictor = HistoryPredictor::new(
            self.cfg.workload.embed_dim,
            self.cfg.history_capacity,
            self.cfg.similarity_threshold,
        );
        for r in &warm.requests {
            predictor.observe(r, r.true_output_len);
        }

        // --- measure predict service time ---------------------------------
        let mut probe_cfg = self.cfg.workload.clone();
        probe_cfg.n_requests = self.samples;
        let probes = WorkloadGen::new(probe_cfg, self.cfg.seed ^ 0xc2).generate();
        let mut service_times = Vec::with_capacity(self.samples);
        let mut dists: Vec<LengthDist> = Vec::with_capacity(self.samples);
        for r in &probes.requests {
            let t0 = Instant::now();
            let d = predictor.predict(r);
            service_times.push(t0.elapsed().as_secs_f64());
            dists.push(d);
        }
        let s_pred = mean(&service_times);

        // The shared predictor serves the whole cluster: arrival rate
        // lambda = nodes * rps; M/M/1 waiting time = rho/(1-rho) * s.
        let lambda = n_nodes as f64 * self.rps_per_node;
        let rho = (lambda * s_pred).min(0.99);
        let predict_latency = s_pred + s_pred * rho / (1.0 - rho);

        // --- measure scheduling latency at queue depth --------------------
        // real Gittins evaluations + a real sort over `queue_depth` entries,
        // replicating one coordinator iteration's scheduling work.
        let cost: Box<dyn CostModel> = crate::cost::make_cost_model(self.cfg.cost_model);
        let mut entries: Vec<(f64, LengthDist, u32, u32)> = (0..self.queue_depth)
            .map(|i| {
                let d = &dists[i % dists.len()];
                let input = 64 + (rng.below(512) as u32);
                let gen = rng.below(200) as u32;
                (0.0, cost.cost_dist(input, d), input, gen)
            })
            .collect();
        let mut sched_times = Vec::with_capacity(self.samples.min(50));
        for _ in 0..self.samples.min(50) {
            let t0 = Instant::now();
            for e in entries.iter_mut() {
                let consumed = cost.consumed(e.2, e.3);
                e.0 = gittins_index_at_age(&e.1, consumed);
            }
            let mut order: Vec<usize> = (0..entries.len()).collect();
            order.sort_by(|&a, &b| entries[a].0.partial_cmp(&entries[b].0).unwrap());
            std::hint::black_box(&order);
            sched_times.push(t0.elapsed().as_secs_f64());
        }
        // scheduling happens per node but the paper's centralized variant
        // scales the work with cluster size; model one scheduler handling
        // all nodes' queues round-robin. Up to 64 nodes one full-depth pass
        // covers everyone; past that the pass count grows linearly.
        let sched_latency = mean(&sched_times) * sched_scale(n_nodes);

        ClusterOverhead {
            nodes: n_nodes,
            aggregate_rps: lambda,
            predict_latency,
            sched_latency,
            total_latency: predict_latency + sched_latency,
            predictor_utilization: rho,
        }
    }

    /// Sweep cluster sizes (the paper's Fig. 12 x-axis).
    pub fn sweep(&self, sizes: &[usize]) -> Vec<ClusterOverhead> {
        sizes.iter().map(|&n| self.measure(n)).collect()
    }
}

/// Centralized-scheduler work multiplier: `(n/64).max(1)` full-depth
/// scheduling passes. Monotone non-decreasing in `n` — a small cluster pays
/// one full pass, never a fraction of one. (The previous expression,
/// `n / 64.0_f64.max(1.0)`, divided *every* cluster size by a constant 64
/// due to operator precedence, so 1-node clusters reported 64× too little
/// scheduling overhead.)
pub fn sched_scale(n_nodes: usize) -> f64 {
    (n_nodes as f64 / 64.0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    #[test]
    fn route_picks_min() {
        assert_eq!(route_least_loaded(&[3, 1, 2]), 1);
        assert_eq!(route_least_loaded(&[0]), 0);
    }

    fn view(id: usize, live: usize, used: usize, backlog: f64, speed: f64) -> ReplicaView {
        ReplicaView {
            id,
            live,
            kv_used_blocks: used,
            kv_total_blocks: 100,
            now: 0.0,
            speed,
            max_batch: 8,
            predicted_backlog: backlog,
        }
    }

    fn any_req() -> Request {
        let mut cfg = crate::config::WorkloadConfig::default();
        cfg.n_requests = 1;
        WorkloadGen::new(cfg, 1).generate().requests.pop().unwrap()
    }

    #[test]
    fn routers_pick_expected_replicas() {
        let views = vec![
            view(0, 4, 80, 500.0, 1.0),
            view(1, 2, 90, 100.0, 1.0),
            view(2, 3, 10, 400.0, 0.1),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        // cost-aware: 500/1, 100/1, 400/0.1=4000 -> replica 1
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        assert_eq!(rr.route(&r, 1.0, &views), 0);
        assert_eq!(rr.route(&r, 1.0, &views), 1);
        assert_eq!(rr.route(&r, 1.0, &views), 2);
        assert_eq!(rr.route(&r, 1.0, &views), 0);
    }

    #[test]
    fn routers_return_positions_not_ids_over_sparse_views() {
        // the surviving view set after failures: ids 3/7/9, positions 0/1/2.
        // returning `ReplicaView::id` here (the old bug) would be out of
        // range or a misroute.
        let views = vec![
            view(3, 4, 80, 500.0, 1.0),
            view(7, 2, 90, 100.0, 1.0),
            view(9, 3, 10, 400.0, 1.0),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        for expect in [0usize, 1, 2, 0] {
            assert_eq!(rr.route(&r, 1.0, &views), expect);
        }
    }

    #[test]
    fn make_router_builds_all_kinds() {
        for kind in RouterKind::ALL {
            assert_eq!(make_router(kind).kind(), kind);
        }
    }

    #[test]
    fn event_cluster_conserves_requests() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 60;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::CostAware);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 60);
        assert_eq!(cluster.rejected(), 0);
        assert_eq!(cluster.in_flight_count(), 0);
        let report = cluster.report(0.0);
        assert_eq!(report.aggregate.measured, 60);
        assert_eq!(report.per_replica.len(), 4);
        assert_eq!(report.aggregate.completed, 60);
        assert_eq!(report.aggregate.rejected, 0);
        assert!((report.aggregate.goodput() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overhead_grows_with_cluster_size() {
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 2000; // keep the test quick
        let sim = ClusterSim { samples: 30, queue_depth: 200, ..ClusterSim::new(cfg) };
        let small = sim.measure(1);
        let large = sim.measure(64);
        assert!(large.total_latency > small.total_latency);
        assert!(large.predictor_utilization >= small.predictor_utilization);
    }

    #[test]
    fn sched_scale_never_discounts_small_clusters() {
        // regression for the precedence bug `n / 64.0_f64.max(1.0)`: small
        // clusters must pay one full scheduling pass, not 1/64th of one
        assert_eq!(sched_scale(1), 1.0);
        assert_eq!(sched_scale(16), 1.0);
        assert_eq!(sched_scale(64), 1.0);
        assert_eq!(sched_scale(128), 2.0);
        let mut prev = 0.0;
        for n in [1usize, 2, 8, 32, 64, 96, 128, 512] {
            let s = sched_scale(n);
            assert!(s >= prev, "sched_scale not monotone at {n}");
            assert!(s >= 1.0);
            prev = s;
        }
    }

    #[test]
    fn measured_sched_latency_comparable_across_sizes() {
        // wallclock-level regression: under the old bug a 1-node cluster
        // reported ~1/64th of the 64-node scheduling latency; fixed, both
        // pay one full-depth pass and differ only by measurement noise
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 1000;
        let sim = ClusterSim { samples: 20, queue_depth: 200, ..ClusterSim::new(cfg) };
        let one = sim.measure(1);
        let big = sim.measure(64);
        assert!(
            one.sched_latency > 0.1 * big.sched_latency,
            "1-node sched latency {} implausibly below 64-node {}",
            one.sched_latency,
            big.sched_latency
        );
    }

    #[test]
    fn invalid_failure_events_are_hard_errors() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 5;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![FailureEvent { replica: 9, at: 1.0, duration: 1.0 }];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("replica 9"), "got: {err}");
    }

    #[test]
    fn overlapping_outages_merge_to_their_union() {
        // regression: a short outage nested inside a long one must not
        // resurrect the replica at the short outage's recovery point
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 200;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 1.0, duration: 6.0 },
            FailureEvent { replica: 0, at: 2.0, duration: 1.0 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 200);
        let report = cluster.report(0.0);
        assert!(
            (report.downtime[0] - 6.0).abs() < 1e-9,
            "union outage is [1,7): downtime {} != 6.0",
            report.downtime[0]
        );
    }

    #[test]
    fn all_replicas_down_is_a_hard_error_not_a_silent_drop() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 20;
        cfg.workload.rps = 10.0;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 0.0, duration: 1e6 },
            FailureEvent { replica: 1, at: 0.0, duration: 1e6 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("all"), "got: {err}");
    }

    #[test]
    fn cluster_experiment_completes_all() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 40;
        cfg.warmup_fraction = 0.0;
        let reports = run_cluster_experiment(&cfg, 3).unwrap();
        assert_eq!(reports.len(), 3);
        let total: usize = reports.iter().map(|r| r.measured).sum();
        assert_eq!(total, 120);
    }
}
