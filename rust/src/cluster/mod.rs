//! Multi-replica cluster serving (§4.4 scalability).
//!
//! Two modes live here:
//!
//! **Event-driven cluster simulation** (the primary mode): N replicas, each
//! a full [`Coordinator`]`<`[`SimEngine`]`>` — real continuous batching,
//! KV-block accounting, preemption — driven on a shared *virtual* clock
//! behind a pluggable [`Router`]. The event loop interleaves replica
//! scheduling iterations and request arrivals in global-time order: while
//! any busy replica's clock trails the next arrival it steps that replica
//! (each step advances that replica's clock by its engine-charged seconds);
//! once every busy replica has caught up, the arrival is routed using the
//! replicas' *current* state and submitted. Replicas may be heterogeneous
//! (per-replica speed / batch-size / KV-capacity from
//! [`ClusterConfig`](crate::config::ClusterConfig)), and a *shared*
//! prediction service (one history index fronting the whole cluster, as the
//! paper's centralized scheduler has) prices each arrival for the
//! cost-aware router and learns online from every replica's completions.
//!
//! Routers: `round-robin`, `least-loaded` (live-request count), `least-kv`
//! (KV-block occupancy), `cost-aware` (predicted outstanding cost from
//! the shared predictor's [`LengthDist`], normalized by replica speed), and
//! `quantile-cost` (the distribution-aware variant: a configurable quantile
//! of each replica's outstanding-cost distribution instead of its mean).
//! Routers see only the *routable* replica set and return positions into
//! it; the dispatcher maps positions back to replica ids.
//!
//! **Replica lifecycle**: replicas move through
//! [`ReplicaState`]s. [`ClusterConfig`](crate::config::ClusterConfig)
//! may schedule [`FailureEvent`](crate::config::FailureEvent)s. At failure
//! time the replica's live requests are drained (crash semantics — queued,
//! running, and preempted state is lost), cluster bookkeeping for them is
//! reconciled, and each is re-dispatched through the router over the
//! survivors (`re_routed` in [`ClusterReport`]). The replica rejoins the
//! routable set, empty, at recovery time; its downtime is reported
//! per-replica. An [`AutoscalePolicy`](crate::autoscale::AutoscalePolicy)
//! (see [`crate::autoscale`]) may additionally *add* replicas mid-run
//! (spawned cold behind a provisioning delay, then routable) and *retire*
//! them (scale-in: the victim stops receiving traffic, its queued work is
//! re-routed — `drained` in the report — and it leaves once its live
//! requests finish, so no request is ever stranded). Every transition is
//! recorded on the [`ScalingEvent`] timeline, and the report charges each
//! replica only for its provisioned lifetime (`replica_seconds`), yielding
//! goodput per replica-second — the efficiency metric elastic and static
//! fleets are compared on.
//!
//! Between events, **work stealing** lets an idle replica take up to half
//! of the most-backlogged replica's never-scheduled (queued) requests —
//! those hold no KV/engine state, so migration costs only the prompt
//! transfer. Each steal is gated on a benefit check (speed-normalized
//! backlog wait saved vs a per-request transfer penalty proportional to
//! prompt length); candidates that fail the gate are counted in
//! `steals_skipped`.
//!
//! Arrival pacing — including the bursty MMPP and diurnal processes under
//! which failure/re-routing is most interesting — lives in
//! [`crate::workload::arrivals`] and is configured per workload.
//!
//! **Overhead measurement** (the legacy fig12 mode, [`ClusterSim`]):
//! wallclock-measured per-request predicting/scheduling latency of the
//! shared services as the cluster grows, with M/M/1 queueing at the shared
//! predictor. Kept as a secondary mode behind `sagesched cluster
//! --overhead`.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use crate::autoscale::{AutoscalePolicy, ScaleAction, ScalingEvent};
use crate::config::{ExperimentConfig, RouterKind};
use crate::core::{Request, RequestId};
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::engine::{Engine, SimEngine};
use crate::gittins::gittins_index_at_age;
use crate::metrics::{ClusterReport, RunReport};
use crate::predictor::{HistoryPredictor, Predictor};
use crate::serve::Coordinator;
use crate::slo::SloClass;
use crate::util::rng::Rng;
use crate::util::stats::{mean, normal_quantile_clamped};
use crate::workload::WorkloadGen;

// ===========================================================================
// Routers
// ===========================================================================

/// Snapshot of one replica's state at routing time.
#[derive(Clone, Debug)]
pub struct ReplicaView {
    /// Replica index.
    pub id: usize,
    /// Live requests (queued + running + preempted).
    pub live: usize,
    /// Allocated KV blocks.
    pub kv_used_blocks: usize,
    /// Total KV blocks.
    pub kv_total_blocks: usize,
    /// Replica-local virtual clock (seconds).
    pub now: f64,
    /// Speed multiplier of this replica (1.0 = base profile).
    pub speed: f64,
    /// Max decode batch of this replica.
    pub max_batch: usize,
    /// Sum of predicted E[total cost] of requests routed here that have not
    /// completed yet (maintained by the cluster from the shared predictor).
    pub predicted_backlog: f64,
    /// Sum of predicted Var[total cost] of the same requests — the second
    /// moment the distribution-aware router and autoscaler consume (sums of
    /// independent request costs: means and variances both add).
    pub predicted_backlog_var: f64,
}

impl ReplicaView {
    /// KV occupancy fraction in [0, 1].
    pub fn kv_occupancy(&self) -> f64 {
        if self.kv_total_blocks == 0 {
            0.0
        } else {
            self.kv_used_blocks as f64 / self.kv_total_blocks as f64
        }
    }
}

/// A cluster front-door routing policy. Implementations must be
/// deterministic given the same request/view sequence so cluster runs are
/// exactly reproducible.
pub trait Router: Send {
    fn kind(&self) -> RouterKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Pick a *position in the `replicas` slice* for `req` (the caller maps
    /// it back to a replica through [`ReplicaView::id`]). The slice holds
    /// only routable — alive — replicas, so positions and replica ids
    /// diverge once any replica has failed; returning `ReplicaView::id`
    /// here is a misroute. `predicted_cost` is the shared predictor's
    /// E[total service cost] for this request (cost-model units);
    /// `replicas` is never empty. Out-of-range returns are a hard dispatch
    /// error, never clamped.
    fn route(&mut self, req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize;
}

/// Cycle through replicas in submission order.
#[derive(Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::RoundRobin
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let i = self.next % replicas.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Fewest live requests; ties break to the lowest replica index.
#[derive(Default)]
pub struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastLoaded
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let loads: Vec<usize> = replicas.iter().map(|r| r.live).collect();
        route_least_loaded(&loads)
    }
}

/// Lowest KV-block occupancy fraction; ties break to the lowest index.
#[derive(Default)]
pub struct LeastKvRouter;

impl Router for LeastKvRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastKv
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let mut best = 0usize;
        let mut best_occ = f64::INFINITY;
        for (slot, r) in replicas.iter().enumerate() {
            let occ = r.kv_occupancy();
            if occ < best_occ {
                best_occ = occ;
                best = slot;
            }
        }
        best
    }
}

/// Smallest predicted outstanding cost normalized by replica speed — the
/// uncertainty-aware router: it routes by E[remaining work], not by request
/// *count*, so a replica stuck with a few predicted-long generations stops
/// attracting traffic even while its live count is low.
#[derive(Default)]
pub struct CostAwareRouter;

impl Router for CostAwareRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::CostAware
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        for (slot, r) in replicas.iter().enumerate() {
            let load = r.predicted_backlog / r.speed.max(1e-9);
            if load < best_load {
                best_load = load;
                best = slot;
            }
        }
        best
    }
}

/// The distribution-aware router: smallest *quantile* of the predicted
/// outstanding-cost distribution, normalized by replica speed. Per replica
/// the outstanding cost is a sum of independent per-request cost
/// distributions, so its quantile is taken under the normal approximation
/// `Q_q ≈ μ + z_q·σ` over the tracked (mean, variance) sums. Against
/// [`CostAwareRouter`] this penalizes replicas whose backlog is
/// heavy-tailed: equal means, unequal tails — the quantile router spreads
/// the tail risk, the mean router cannot see it.
pub struct QuantileCostRouter {
    /// z-score of the configured quantile.
    z: f64,
}

impl QuantileCostRouter {
    pub fn new(quantile: f64) -> QuantileCostRouter {
        QuantileCostRouter { z: normal_quantile_clamped(quantile) }
    }
}

impl Router for QuantileCostRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::QuantileCost
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        for (slot, r) in replicas.iter().enumerate() {
            let q = r.predicted_backlog + self.z * r.predicted_backlog_var.max(0.0).sqrt();
            // negative q (possible at sub-median quantiles) still orders
            // replicas correctly — clamping it would collapse the ordering
            // and skew all ties to slot 0
            let load = q / r.speed.max(1e-9);
            if load < best_load {
                best_load = load;
                best = slot;
            }
        }
        best
    }
}

/// Build a router from its kind; `quantile` parameterizes
/// [`RouterKind::QuantileCost`] (ignored by the others).
pub fn make_router(kind: RouterKind, quantile: f64) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
        RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        RouterKind::LeastKv => Box::new(LeastKvRouter),
        RouterKind::CostAware => Box::new(CostAwareRouter),
        RouterKind::QuantileCost => Box::new(QuantileCostRouter::new(quantile)),
    }
}

/// SLO-class-aware routing wrapper: tight tiers get headroom, loose tiers
/// keep the configured base router.
///
/// * `Interactive` requests are routed over the subset of replicas with KV
///   headroom (occupancy at most `kv_headroom`; the full set when none
///   qualifies), picked by the smallest *high quantile* of the outstanding
///   predicted-cost distribution normalized by speed — the
///   tail-risk-averse placement a tight TTFT budget wants. The per-tier
///   quantile is how the distribution-aware router "provisions headroom"
///   for the tier that cannot absorb a burst.
/// * `Standard` and `Batch` requests are delegated to the wrapped router
///   unchanged.
///
/// Composes with every [`RouterKind`]; it reports the inner router's kind
/// and name so A/B labels stay comparable.
pub struct ClassAwareRouter {
    inner: Box<dyn Router>,
    /// z-score of the Interactive placement quantile.
    z_tight: f64,
    /// KV-occupancy ceiling for Interactive-eligible replicas.
    kv_headroom: f64,
}

impl ClassAwareRouter {
    pub fn new(inner: Box<dyn Router>) -> ClassAwareRouter {
        ClassAwareRouter {
            inner,
            z_tight: normal_quantile_clamped(0.95),
            kv_headroom: 0.85,
        }
    }
}

impl Router for ClassAwareRouter {
    fn kind(&self) -> RouterKind {
        self.inner.kind()
    }

    fn route(&mut self, req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize {
        if req.slo != SloClass::Interactive {
            return self.inner.route(req, predicted_cost, replicas);
        }
        let eligible: Vec<usize> = (0..replicas.len())
            .filter(|&slot| replicas[slot].kv_occupancy() <= self.kv_headroom)
            .collect();
        let pool: Vec<usize> = if eligible.is_empty() {
            (0..replicas.len()).collect()
        } else {
            eligible
        };
        let mut best = pool[0];
        let mut best_load = f64::INFINITY;
        for &slot in &pool {
            let r = &replicas[slot];
            let q = r.predicted_backlog
                + self.z_tight * r.predicted_backlog_var.max(0.0).sqrt();
            let load = q / r.speed.max(1e-9);
            if load < best_load {
                best_load = load;
                best = slot;
            }
        }
        best
    }
}

/// Least-loaded routing decision across per-node live counts (exposed for
/// tests and the cluster example).
pub fn route_least_loaded(loads: &[usize]) -> usize {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(_, &l)| l)
        .map(|(i, _)| i)
        .expect("route over empty cluster")
}

// ===========================================================================
// Event-driven cluster
// ===========================================================================

/// Lifecycle state of one replica inside the event-driven cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Spawned by a scale-out decision, still inside its provisioning
    /// delay: not routable, holds no work.
    Provisioning,
    /// Routable and serving.
    Active,
    /// Failed: not routable, holds no work (drained at failure time), will
    /// rejoin at its recovery event.
    Down,
    /// Scale-in victim: not routable, queued work already re-routed,
    /// finishing its running/preempted requests in place.
    Draining,
    /// Retired for good (scale-in complete, or failed while draining).
    Retired,
}

/// One serving replica inside the event-driven cluster.
pub struct ClusterReplica {
    pub coord: Coordinator<SimEngine>,
    /// Speed multiplier this replica was built with.
    pub speed: f64,
    /// Lifecycle state; only [`ReplicaState::Active`] replicas are
    /// routable, only Active/Draining ones can hold live work.
    pub state: ReplicaState,
    /// Virtual time the current outage began (meaningful while Down).
    down_since: f64,
    /// Accumulated downtime over completed outages (seconds).
    pub downtime: f64,
    /// Virtual time this replica was provisioned (0 for the initial fleet).
    pub spawned_at: f64,
    /// Virtual time this replica's provisioning delay elapses (0 for the
    /// initial fleet, which starts Active). A recovery before this instant
    /// resumes provisioning rather than activating the replica early.
    ready_at: f64,
    /// Virtual time the replica retired, if it did.
    pub retired_at: Option<f64>,
    /// Outcomes already drained into cluster-level bookkeeping.
    seen_outcomes: usize,
    /// Timeout-aborts already reconciled into cluster-level bookkeeping.
    seen_aborted: u64,
}

impl ClusterReplica {
    /// Whether routers may send new work here.
    pub fn routable(&self) -> bool {
        self.state == ReplicaState::Active
    }

    /// Provisioned lifetime up to `horizon`, excluding downtime — the
    /// replica-seconds this replica is charged for. A replica added or
    /// retired mid-run is charged only for its [spawned_at, retired_at)
    /// span; an outage still open at `horizon` is charged to `horizon`.
    pub fn replica_seconds(&self, horizon: f64) -> f64 {
        let end = self.retired_at.unwrap_or(horizon);
        let open_outage = if self.state == ReplicaState::Down {
            (end - self.down_since).max(0.0)
        } else {
            0.0
        };
        (end - self.spawned_at - self.downtime - open_outage).max(0.0)
    }
}

/// What a scheduled cluster event does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ClusterEventKind {
    /// A provisioning delay elapsed: the replica becomes routable.
    SpawnReady,
    /// A configured outage ends.
    Recover,
    /// A configured outage begins.
    Fail,
    /// An autoscaler decision point.
    Decision,
}

impl ClusterEventKind {
    /// Tie-break rank at equal times: capacity arrives (spawn-ready,
    /// recover) before capacity leaves (fail), and autoscaler decisions
    /// observe the post-transition state.
    fn rank(&self) -> u8 {
        match self {
            ClusterEventKind::SpawnReady => 0,
            ClusterEventKind::Recover => 1,
            ClusterEventKind::Fail => 2,
            ClusterEventKind::Decision => 3,
        }
    }
}

/// One scheduled cluster event (failure/recovery from config, autoscaler
/// decision points, dynamic spawn-ready events).
#[derive(Clone, Copy, Debug)]
struct ClusterEvent {
    at: f64,
    kind: ClusterEventKind,
    /// Target replica (unused for `Decision`).
    replica: usize,
}

impl ClusterEvent {
    fn sort_key(&self) -> (f64, u8, usize) {
        (self.at, self.kind.rank(), self.replica)
    }
}

/// Cluster-side bookkeeping for one in-flight request: where it was routed
/// and the first two moments of its predicted cost distribution.
struct InFlight {
    replica: usize,
    /// Predicted E[total cost] (cost-model units).
    cost: f64,
    /// Predicted Var[total cost].
    var: f64,
    /// SLO weight of this request's class (1.0 under class-blind serving);
    /// scales its contribution to the weighted forecast backlog the
    /// uncertainty-aware autoscaler provisions for.
    weight: f64,
    /// Original request (kept for re-dispatch and predictor learning).
    req: Request,
}

/// The event-driven multi-replica cluster: N coordinators on a shared
/// virtual clock behind a [`Router`], with a shared prediction service,
/// replica failure/recovery, elastic autoscaling, and idle-replica work
/// stealing.
pub struct EventCluster {
    pub cfg: ExperimentConfig,
    pub replicas: Vec<ClusterReplica>,
    pub router: Box<dyn Router>,
    /// Shared prediction service (prices arrivals; learns from completions).
    pub predictor: Box<dyn Predictor>,
    /// Elastic provisioning policy (None = fixed fleet).
    autoscaler: Option<Box<dyn AutoscalePolicy>>,
    cost: Box<dyn CostModel>,
    /// id -> routing + predicted-cost bookkeeping.
    in_flight: HashMap<RequestId, InFlight>,
    /// Per-replica sum of predicted cost of in-flight requests.
    backlog: Vec<f64>,
    /// Per-replica sum of predicted cost *variance* of in-flight requests.
    backlog_var: Vec<f64>,
    /// Cluster-wide SLO-weighted backlog moments: Σ w·E[cost] and
    /// Σ w²·Var[cost] over in-flight requests (w = 1 under class-blind
    /// serving, so these equal the unweighted sums). Maintained
    /// incrementally — never by iterating the in-flight map, whose order
    /// is not deterministic — and consumed by the uncertainty-aware
    /// autoscaler's weighted forecast.
    backlog_weighted: f64,
    backlog_weighted_var: f64,
    /// Per-replica routed-request counts.
    pub routed: Vec<u64>,
    /// Requests re-dispatched through the router after a replica failure.
    pub re_routed: u64,
    /// Queued requests re-routed off a scale-in victim at drain time.
    pub drained: u64,
    /// Queued requests migrated to an idle replica by work stealing.
    pub stolen: u64,
    /// Steal candidates rejected by the transfer-cost benefit gate at
    /// least once.
    steal_rejected: HashSet<RequestId>,
    /// Whether anything that could change a steal verdict (queue contents,
    /// backlogs, replica states) has happened since the last fruitless
    /// stealing pass. The benefit gate makes "idle thief, nothing
    /// profitable" a *persistent* state; without this flag every event-loop
    /// iteration would rescan and re-sort the queues just to reach the same
    /// verdict.
    steal_dirty: bool,
    /// Replica lifecycle timeline (provision/up/drain/retire/fail/recover).
    pub scaling_events: Vec<ScalingEvent>,
}

impl EventCluster {
    /// Build a cluster from `cfg` (replica count / router / heterogeneity /
    /// autoscale policy from `cfg.cluster`), overriding the router with
    /// `router`.
    pub fn with_router(cfg: &ExperimentConfig, router: RouterKind) -> EventCluster {
        let n = cfg.cluster.replicas.max(1);
        let replicas: Vec<ClusterReplica> = (0..n)
            .map(|i| {
                let profile = cfg.cluster.replica_profile(&cfg.engine, i);
                let seed = cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                ClusterReplica {
                    coord: crate::serve::build_sim_coordinator_with(cfg, profile, seed),
                    speed: cfg.cluster.speed_of(i),
                    state: ReplicaState::Active,
                    down_since: 0.0,
                    downtime: 0.0,
                    spawned_at: 0.0,
                    ready_at: 0.0,
                    retired_at: None,
                    seen_outcomes: 0,
                    seen_aborted: 0,
                }
            })
            .collect();
        let predictor = crate::predictor::make_predictor(
            cfg.predictor,
            cfg.workload.embed_dim,
            cfg.history_capacity,
            cfg.similarity_threshold,
            cfg.seed ^ 0xc175_7e12,
        );
        let mut boxed = make_router(router, cfg.cluster.router_quantile);
        if cfg.slo.class_aware {
            boxed = Box::new(ClassAwareRouter::new(boxed));
        }
        EventCluster {
            cfg: cfg.clone(),
            backlog: vec![0.0; n],
            backlog_var: vec![0.0; n],
            backlog_weighted: 0.0,
            backlog_weighted_var: 0.0,
            routed: vec![0; n],
            re_routed: 0,
            drained: 0,
            stolen: 0,
            steal_rejected: HashSet::new(),
            steal_dirty: true,
            scaling_events: Vec::new(),
            replicas,
            router: boxed,
            predictor,
            autoscaler: crate::autoscale::make_autoscaler(&cfg.cluster.autoscale),
            cost: crate::cost::make_cost_model(cfg.cost_model),
            in_flight: HashMap::new(),
        }
    }

    /// Requests refused at admission, cluster-wide. Each coordinator owns
    /// its own count (it is the sole place a refusal happens), so summing
    /// here counts every rejection exactly once.
    pub fn rejected(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.rejected).sum()
    }

    /// Requests aborted by queue timeout, cluster-wide.
    pub fn aborted(&self) -> u64 {
        self.replicas.iter().map(|r| r.coord.aborted).sum()
    }

    /// Per-SLO-class admission rejections, cluster-wide (indexed by
    /// [`SloClass::index`]).
    pub fn rejected_by_class(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for r in &self.replicas {
            for (k, &n) in r.coord.rejected_by_class.iter().enumerate() {
                out[k] += n;
            }
        }
        out
    }

    /// Per-SLO-class queue-timeout aborts, cluster-wide (indexed by
    /// [`SloClass::index`]).
    pub fn aborted_by_class(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for r in &self.replicas {
            for (k, &n) in r.coord.aborted_by_class.iter().enumerate() {
                out[k] += n;
            }
        }
        out
    }

    /// Requests the cluster still tracks as in flight (0 after a completed
    /// run — anything else means bookkeeping leaked).
    pub fn in_flight_count(&self) -> usize {
        self.in_flight.len()
    }

    /// Sum of per-replica predicted-cost backlogs (≈0 after a drained run).
    pub fn total_backlog(&self) -> f64 {
        self.backlog.iter().sum()
    }

    /// Cluster-wide SLO-weighted backlog mean (≈0 after a drained run;
    /// equals [`EventCluster::total_backlog`] under class-blind serving up
    /// to float accumulation order).
    pub fn weighted_backlog(&self) -> f64 {
        self.backlog_weighted
    }

    /// Steal candidates the transfer-cost benefit gate rejected (distinct
    /// requests; one later stolen after backlog shifts still counts here).
    pub fn steals_skipped(&self) -> u64 {
        self.steal_rejected.len() as u64
    }

    /// Build with the router configured in `cfg.cluster.router`.
    pub fn new(cfg: &ExperimentConfig) -> EventCluster {
        EventCluster::with_router(cfg, cfg.cluster.router)
    }

    /// Pre-warm the shared predictor and every replica's local predictor
    /// with the offline corpus (`cfg.history_prewarm`).
    pub fn prewarm(&mut self) {
        crate::serve::prewarm_predictor(self.predictor.as_mut(), &self.cfg);
        for r in &mut self.replicas {
            crate::serve::prewarm_predictor(r.coord.predictor.as_mut(), &self.cfg);
        }
    }

    /// Routable snapshot: one view per *routable* (Active) replica.
    /// `ReplicaView::id` carries the true replica index, which no longer
    /// matches the position in the returned slice once any replica is down,
    /// provisioning, or draining — routers return positions, the dispatcher
    /// maps them back through `id`.
    fn views(&self) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.routable())
            .map(|(i, r)| ReplicaView {
                id: i,
                live: r.coord.live_count(),
                kv_used_blocks: r.coord.kv.used_blocks(),
                kv_total_blocks: r.coord.kv.total_blocks(),
                now: r.coord.now(),
                speed: r.speed,
                max_batch: r.coord.engine.max_batch(),
                predicted_backlog: self.backlog[i],
                predicted_backlog_var: self.backlog_var[i],
            })
            .collect()
    }

    /// Index and clock of the busy replica with the smallest virtual time,
    /// if any replica has live work. Only Active and Draining replicas can
    /// hold live work (Down replicas are drained at failure time,
    /// Provisioning/Retired ones never held any), so only those are
    /// stepped — a Draining replica keeps running until its last live
    /// request finishes.
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            let steppable = matches!(r.state, ReplicaState::Active | ReplicaState::Draining);
            if !steppable || r.coord.is_idle() {
                continue;
            }
            let t = r.coord.now();
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((i, t));
            }
        }
        best
    }

    /// Route and submit one request. `not_before` is the earliest virtual
    /// time the target may start it: the arrival time for fresh requests,
    /// the failure instant for re-dispatched ones (an idle survivor with a
    /// lagging clock must not serve work "before" the crash that freed it).
    /// Fails hard when no replica is alive or the router returns an
    /// out-of-range position — both are configuration/implementation errors
    /// that must not be silently patched (the old `.min(len-1)` clamp
    /// turned router misroutes into quiet load skew). A refused submission
    /// counts as a rejection (crash re-dispatch and fresh arrivals share
    /// admission semantics).
    fn dispatch(&mut self, req: Request, not_before: f64) -> anyhow::Result<()> {
        self.place(req, not_before, None)?;
        Ok(())
    }

    /// Routing core shared by [`EventCluster::dispatch`] and the scale-in
    /// drain path. With `keep_on: Some(victim)` a routed target without
    /// admission headroom — or an empty routable set — falls back to
    /// re-admitting on the (draining) `victim`, which always fits: the
    /// request occupied one of the victim's admission slots moments ago and
    /// nothing was admitted there since. A *voluntary* scale-in must never
    /// convert an already-admitted request into a rejection. Returns true
    /// when the request landed somewhere other than the fallback.
    fn place(
        &mut self,
        req: Request,
        not_before: f64,
        keep_on: Option<usize>,
    ) -> anyhow::Result<bool> {
        let pred = self.predictor.predict(&req);
        let cost_dist = self.cost.cost_dist(req.input_len, &pred);
        let pcost = cost_dist.mean();
        let pvar = cost_dist.variance();
        let weight = if self.cfg.slo.class_aware {
            self.cfg.slo.specs.spec(req.slo).weight
        } else {
            1.0
        };
        let views = self.views();
        let mut target = None;
        if views.is_empty() {
            if keep_on.is_none() {
                anyhow::bail!(
                    "cannot route request {}: none of the {} replicas is routable",
                    req.id,
                    self.replicas.len()
                );
            }
        } else {
            let slot = self.router.route(&req, pcost, &views);
            if slot >= views.len() {
                anyhow::bail!(
                    "router {} returned position {slot} but only {} replicas are \
                     routable",
                    self.router.name(),
                    views.len()
                );
            }
            let i = views[slot].id;
            // the coordinator's own (possibly class-aware) admission verdict,
            // so the has-room view can never disagree with submit()
            let has_room = self.replicas[i].coord.admits(req.slo);
            if has_room || keep_on.is_none() {
                target = Some(i);
            }
        }
        let moved = target.is_some();
        let i = target
            .or(keep_on)
            .expect("place: empty routable set without fallback already bailed");
        let id = req.id;
        self.replicas[i].coord.advance_to(req.arrival.max(not_before));
        // the drain fallback is a *migration*: the request already passed
        // admission on the victim, so re-admitting it there is exempt
        let accepted = if moved {
            self.replicas[i].coord.submit(req.clone())
        } else {
            self.replicas[i].coord.submit_exempt(req.clone())
        };
        debug_assert!(accepted || keep_on.is_none(), "drain re-admission must fit");
        if accepted {
            self.in_flight.insert(
                id,
                InFlight { replica: i, cost: pcost, var: pvar, weight, req },
            );
            self.backlog[i] += pcost;
            self.backlog_var[i] += pvar;
            self.backlog_weighted += weight * pcost;
            self.backlog_weighted_var += weight * weight * pvar;
            self.routed[i] += 1;
            self.steal_dirty = true; // fresh queued work: steal verdicts change
        }
        // refusals are counted by the coordinator itself (sole owner of the
        // rejected counter; see EventCluster::rejected)
        Ok(moved && accepted)
    }

    /// Run one scheduling iteration on replica `i` and drain its new
    /// completions into cluster bookkeeping (backlog release + shared
    /// predictor learning). Returns false when the step made no observable
    /// progress (clock, completions, aborts, and live set all unchanged) —
    /// with live work that means the replica is wedged (e.g. a request that
    /// can never fit its KV capacity) and the caller must not keep spinning.
    fn step_replica(&mut self, i: usize) -> anyhow::Result<bool> {
        let (now0, live0) = {
            let c = &self.replicas[i].coord;
            (c.now(), c.live_count())
        };
        self.replicas[i].coord.step()?;
        let new: Vec<(RequestId, u32)> = {
            let r = &self.replicas[i];
            r.coord.outcomes()[r.seen_outcomes..]
                .iter()
                .map(|o| (o.id, o.output_len))
                .collect()
        };
        self.replicas[i].seen_outcomes += new.len();
        let live_now = self.replicas[i].coord.live_count();
        let progressed =
            !new.is_empty() || self.replicas[i].coord.now() > now0 || live_now != live0;
        // completions / live-set changes move backlogs and can idle a
        // replica — both alter steal verdicts; a bare clock advance cannot
        if !new.is_empty() || live_now != live0 {
            self.steal_dirty = true;
        }
        for (id, output_len) in new {
            if let Some(f) = self.in_flight.remove(&id) {
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
                self.predictor.observe(&f.req, output_len);
            }
        }
        // Reconcile timeout-aborts: they leave the live set without an
        // outcome, so their backlog contribution must be released here or
        // the cost-aware router would shun this replica forever.
        if self.replicas[i].coord.aborted > self.replicas[i].seen_aborted {
            self.replicas[i].seen_aborted = self.replicas[i].coord.aborted;
            let coord = &self.replicas[i].coord;
            let mut gone: Vec<RequestId> = self
                .in_flight
                .iter()
                .filter(|(id, entry)| entry.replica == i && !coord.is_live(**id))
                .map(|(id, _)| *id)
                .collect();
            // the map's iteration order is not deterministic; releasing in
            // id order keeps the float bookkeeping — and therefore every
            // downstream routing/scaling decision and the report JSON —
            // byte-identical across runs of the same seed
            gone.sort_unstable();
            for id in gone {
                if let Some(f) = self.in_flight.remove(&id) {
                    self.release_backlog(f.replica, f.cost, f.var, f.weight);
                }
            }
        }
        Ok(progressed)
    }

    /// Release one request's contribution to a replica's predicted-cost
    /// moments and the cluster-wide weighted moments (floored at 0 against
    /// accumulated float error).
    fn release_backlog(&mut self, replica: usize, cost: f64, var: f64, weight: f64) {
        self.backlog[replica] = (self.backlog[replica] - cost).max(0.0);
        self.backlog_var[replica] = (self.backlog_var[replica] - var).max(0.0);
        self.backlog_weighted = (self.backlog_weighted - weight * cost).max(0.0);
        self.backlog_weighted_var =
            (self.backlog_weighted_var - weight * weight * var).max(0.0);
    }

    /// Drive the full arrival stream to completion: global-time-ordered
    /// interleaving of replica iterations, routed arrivals, replica
    /// failure/recovery events, and autoscaler decisions (whose scale-outs
    /// schedule spawn-ready events after the provisioning delay), then
    /// drain. Idle replicas steal queued work from backlogged peers between
    /// events.
    pub fn run(&mut self, mut requests: Vec<Request>) -> anyhow::Result<()> {
        if let Err(e) = self.cfg.cluster.autoscale.validate() {
            anyhow::bail!("{e}");
        }
        requests.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut events = self.initial_events()?;
        let mut idx = 0;
        let mut eidx = 0;
        loop {
            self.steal_work();
            let next_arrival = requests.get(idx).map(|r| r.arrival);
            let next_event = events.get(eidx).map(|e| e.at);
            // scheduled events win ties so same-instant arrivals already
            // route over the post-transition replica set
            let event_first = match (next_event, next_arrival) {
                (Some(te), Some(ta)) => te <= ta,
                (Some(_), None) => true,
                _ => false,
            };
            let next_t = match (next_event, next_arrival) {
                (Some(te), Some(ta)) => Some(te.min(ta)),
                (a, b) => a.or(b),
            };
            match (self.earliest_busy(), next_t) {
                // a busy replica trails the next event: advance it first
                (Some((i, t)), Some(te)) if t < te => self.check_progress(i)?,
                // all busy replicas have caught up: apply the event
                (_, Some(_)) => {
                    if event_first {
                        let ev = events[eidx];
                        eidx += 1;
                        let arrivals_pending = idx < requests.len();
                        self.apply_event(ev, &mut events, eidx, arrivals_pending)?;
                    } else {
                        let r = requests[idx].clone();
                        idx += 1;
                        let at = r.arrival;
                        self.dispatch(r, at)?;
                    }
                }
                // events exhausted: drain remaining work
                (Some((i, _)), None) => self.check_progress(i)?,
                (None, None) => break,
            }
        }
        Ok(())
    }

    /// Assemble the time-sorted scheduled-event stream: failure/recovery
    /// transitions from the config, the autoscaler's first periodic
    /// decision point (each fired decision schedules its successor while
    /// arrivals remain or work is live, so the chain covers the drain tail
    /// too), and the policy's own scripted times. Overlapping or touching
    /// outage windows on one replica are merged into their union first —
    /// otherwise the earliest recovery of a nested outage would resurrect
    /// the replica while a longer outage is still running, undercounting
    /// downtime.
    fn initial_events(&self) -> anyhow::Result<Vec<ClusterEvent>> {
        let n = self.replicas.len();
        // with autoscaling on, an outage may target a replica the scaler
        // will have spawned by then (indices are deterministic); the check
        // that it actually exists moves to the instant the event fires
        let elastic = self.autoscaler.is_some();
        let mut max_idx = n;
        for f in &self.cfg.cluster.failures {
            if f.replica >= n && !elastic {
                anyhow::bail!(
                    "failure event references replica {} but the cluster has \
                     {n} replicas",
                    f.replica
                );
            }
            if let Err(e) = f.validate() {
                anyhow::bail!("{e}");
            }
            max_idx = max_idx.max(f.replica + 1);
        }
        let mut by_replica: Vec<Vec<(f64, f64)>> = vec![Vec::new(); max_idx];
        for f in &self.cfg.cluster.failures {
            by_replica[f.replica].push((f.at, f.at + f.duration));
        }
        let mut events = Vec::with_capacity(self.cfg.cluster.failures.len() * 2);
        for (replica, mut windows) in by_replica.into_iter().enumerate() {
            windows.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut merged: Vec<(f64, f64)> = Vec::new();
            for (start, end) in windows {
                match merged.last_mut() {
                    Some(last) if start <= last.1 => last.1 = last.1.max(end),
                    _ => merged.push((start, end)),
                }
            }
            for (start, end) in merged {
                events.push(ClusterEvent {
                    at: start,
                    kind: ClusterEventKind::Fail,
                    replica,
                });
                events.push(ClusterEvent {
                    at: end,
                    kind: ClusterEventKind::Recover,
                    replica,
                });
            }
        }
        if let Some(pol) = self.autoscaler.as_ref() {
            // seed the periodic chain; Decision handling extends it
            events.push(ClusterEvent {
                at: self.cfg.cluster.autoscale.interval,
                kind: ClusterEventKind::Decision,
                replica: 0,
            });
            // scripted steps fire exactly at their configured times, even
            // past the last arrival (a late scale-in still frees capacity
            // during the drain tail)
            for at in pol.scheduled_times() {
                events.push(ClusterEvent {
                    at,
                    kind: ClusterEventKind::Decision,
                    replica: 0,
                });
            }
        }
        events.sort_by(|a, b| {
            a.sort_key()
                .partial_cmp(&b.sort_key())
                .expect("NaN event time")
        });
        // collapse duplicate decision instants (a scripted step landing on
        // the periodic grid must fire once, not twice)
        events.dedup_by(|a, b| {
            a.kind == ClusterEventKind::Decision
                && b.kind == ClusterEventKind::Decision
                && a.at == b.at
        });
        Ok(events)
    }

    /// Apply one scheduled event; autoscaler decisions may append
    /// spawn-ready events and their own successor decision point (inserted
    /// in time order at/after `eidx`).
    fn apply_event(
        &mut self,
        ev: ClusterEvent,
        events: &mut Vec<ClusterEvent>,
        eidx: usize,
        arrivals_pending: bool,
    ) -> anyhow::Result<()> {
        match ev.kind {
            ClusterEventKind::Fail => self.apply_failure(ev.replica, ev.at),
            ClusterEventKind::Recover => {
                self.apply_recovery(ev.replica, ev.at);
                Ok(())
            }
            ClusterEventKind::SpawnReady => {
                self.apply_spawn_ready(ev.replica, ev.at);
                Ok(())
            }
            ClusterEventKind::Decision => {
                let mut new_events = self.apply_decision(ev.at)?;
                // keep the periodic chain alive while there is anything
                // left to decide about: feedback policies must be able to
                // scale in during the drain tail after the last arrival.
                // Once arrivals are exhausted and the cluster is idle the
                // chain ends, which bounds the event stream.
                let chain_pending = events[eidx..]
                    .iter()
                    .any(|e| e.kind == ClusterEventKind::Decision);
                if self.autoscaler.is_some()
                    && !chain_pending
                    && (arrivals_pending || self.has_live_work())
                {
                    new_events.push(ClusterEvent {
                        at: ev.at + self.cfg.cluster.autoscale.interval,
                        kind: ClusterEventKind::Decision,
                        replica: 0,
                    });
                }
                for new_ev in new_events {
                    let pos = events[eidx..]
                        .iter()
                        .position(|e| e.sort_key() > new_ev.sort_key())
                        .map(|p| eidx + p)
                        .unwrap_or(events.len());
                    events.insert(pos, new_ev);
                }
                Ok(())
            }
        }
    }

    /// Whether any replica still holds live (queued/running/preempted)
    /// work.
    fn has_live_work(&self) -> bool {
        self.replicas.iter().any(|r| !r.coord.is_idle())
    }

    /// A scheduled outage begins: drain everything the replica held —
    /// queued, running, and preempted requests lose their state, exactly as
    /// a crash would — release the cluster-side backlog/in-flight
    /// bookkeeping for them, and re-dispatch each one through the router
    /// over the routable replicas. A replica that was already draining for
    /// scale-in retires on the spot (it was leaving anyway; the crash just
    /// lost the work it was finishing, which is re-routed like any other
    /// failure). A replica still *provisioning* goes down holding no work:
    /// if the outage ends before the provisioning delay would have, the
    /// recovery resumes provisioning and the pending spawn-ready event
    /// still activates it exactly on schedule; if the outage outlasts the
    /// delay, the spawn-ready no-ops while down and the recovery activates
    /// it (provisioning completed during the outage). Either way an outage
    /// can only delay, never advance, the instant capacity arrives.
    /// Failures on retired or already-down replicas are no-ops; one naming
    /// a replica that was never provisioned is a hard configuration error.
    fn apply_failure(&mut self, i: usize, at: f64) -> anyhow::Result<()> {
        if i >= self.replicas.len() {
            anyhow::bail!(
                "failure event at t={at} references replica {i}, but only \
                 {} replicas have been provisioned by then",
                self.replicas.len()
            );
        }
        let was_draining = match self.replicas[i].state {
            ReplicaState::Active => false,
            ReplicaState::Draining => true,
            ReplicaState::Provisioning => {
                self.replicas[i].coord.advance_to(at);
                self.record(at, i, ScaleAction::Fail);
                self.replicas[i].state = ReplicaState::Down;
                self.replicas[i].down_since = at;
                return Ok(());
            }
            _ => return Ok(()),
        };
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Fail);
        self.steal_dirty = true;
        if was_draining {
            self.retire(i, at);
        } else {
            self.replicas[i].state = ReplicaState::Down;
            self.replicas[i].down_since = at;
        }
        let mut lost = self.replicas[i].coord.drain_live();
        for req in &lost {
            if let Some(f) = self.in_flight.remove(&req.id) {
                debug_assert_eq!(f.replica, i, "in-flight map out of sync at failure");
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
            }
        }
        lost.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        self.re_routed += lost.len() as u64;
        for req in lost {
            self.dispatch(req, at)?;
        }
        Ok(())
    }

    /// A scheduled outage ends: the (empty) replica rejoins the routable
    /// set and its downtime is charged. A replica whose provisioning was
    /// interrupted by the outage — recovery lands before its `ready_at` —
    /// *resumes* provisioning instead: the still-pending spawn-ready event
    /// brings it up at the originally scheduled instant, so an outage can
    /// never hand the cluster capacity earlier than the provisioning delay
    /// allows. Replicas that retired while down stay retired.
    fn apply_recovery(&mut self, i: usize, at: f64) {
        if self.replicas[i].state != ReplicaState::Down {
            return;
        }
        self.replicas[i].downtime += at - self.replicas[i].down_since;
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Recover);
        if at < self.replicas[i].ready_at {
            self.replicas[i].state = ReplicaState::Provisioning;
            return;
        }
        self.replicas[i].state = ReplicaState::Active;
        self.steal_dirty = true; // a fresh idle thief just appeared
    }

    /// A provisioning delay elapsed: the cold replica joins the routable
    /// set.
    fn apply_spawn_ready(&mut self, i: usize, at: f64) {
        if self.replicas[i].state != ReplicaState::Provisioning {
            return;
        }
        self.replicas[i].state = ReplicaState::Active;
        self.replicas[i].coord.advance_to(at);
        self.record(at, i, ScaleAction::Up);
        self.steal_dirty = true; // a fresh idle thief just appeared
    }

    /// Run the autoscaler at a decision point. Scale-out spawns fresh
    /// replicas (returned as future spawn-ready events); scale-in begins
    /// draining victims immediately. The desired target counts capacity
    /// that is present or committed (active + provisioning + down).
    fn apply_decision(&mut self, now: f64) -> anyhow::Result<Vec<ClusterEvent>> {
        let view = self.autoscale_view(now);
        let target = match self.autoscaler.as_mut() {
            None => return Ok(Vec::new()),
            Some(p) => p.target(&view),
        };
        let Some(target) = target else {
            return Ok(Vec::new());
        };
        let target = target.max(1);
        let present = view.present();
        if target > present {
            let delay = self.cfg.cluster.autoscale.provision_delay;
            let mut spawns = Vec::with_capacity(target - present);
            for _ in 0..(target - present) {
                let i = self.spawn_replica(now);
                self.record(now, i, ScaleAction::Provision);
                spawns.push(ClusterEvent {
                    at: now + delay,
                    kind: ClusterEventKind::SpawnReady,
                    replica: i,
                });
            }
            return Ok(spawns);
        }
        let mut shrink = present - target;
        while shrink > 0 {
            // cancel not-yet-ready replicas first (newest first): they hold
            // no work, so retiring them is free — a scale-out/scale-in
            // whipsaw must not destroy warm serving capacity while a cold
            // replica is still on its way up. Its pending spawn-ready event
            // becomes a no-op (the state is no longer Provisioning).
            if let Some(p) = self
                .replicas
                .iter()
                .rposition(|r| r.state == ReplicaState::Provisioning)
            {
                self.retire(p, now);
                shrink -= 1;
                continue;
            }
            let active: Vec<usize> = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.state == ReplicaState::Active)
                .map(|(i, _)| i)
                .collect();
            // never drain the last routable replica: the cluster must stay
            // able to place re-routed and future work
            if active.len() <= 1 {
                break;
            }
            // cheapest victim to drain: fewest live requests, ties to the
            // highest index (retire the newest replica first)
            let victim = *active
                .iter()
                .min_by_key(|&&i| (self.replicas[i].coord.live_count(), usize::MAX - i))
                .expect("non-empty active set");
            self.begin_drain(victim, now)?;
            shrink -= 1;
        }
        Ok(Vec::new())
    }

    /// Snapshot the cluster for the autoscaler.
    fn autoscale_view(&self, now: f64) -> crate::autoscale::AutoscaleView {
        let mut active = 0;
        let mut provisioning = 0;
        let mut down = 0;
        let mut draining = 0;
        let mut total_live = 0;
        let mut total_queued = 0;
        let mut occ_sum = 0.0;
        for r in &self.replicas {
            match r.state {
                ReplicaState::Active => {
                    active += 1;
                    total_live += r.coord.live_count();
                    total_queued += r.coord.queued_count();
                    let total = r.coord.kv.total_blocks();
                    if total > 0 {
                        occ_sum += r.coord.kv.used_blocks() as f64 / total as f64;
                    }
                }
                ReplicaState::Provisioning => provisioning += 1,
                ReplicaState::Down => down += 1,
                ReplicaState::Draining => draining += 1,
                ReplicaState::Retired => {}
            }
        }
        let mean_kv_occupancy = if active > 0 {
            occ_sum / active as f64
        } else {
            0.0
        };
        crate::autoscale::AutoscaleView {
            now,
            active,
            provisioning,
            down,
            draining,
            total_live,
            total_queued,
            mean_kv_occupancy,
            backlog_mean: self.backlog.iter().sum(),
            backlog_var: self.backlog_var.iter().sum(),
            backlog_weighted_mean: self.backlog_weighted,
            backlog_weighted_var: self.backlog_weighted_var,
        }
    }

    /// Append a fresh cold replica in the Provisioning state. Heterogeneity
    /// vectors keep cycling at the new index, and the replica gets its own
    /// deterministic seed, so elastic runs stay exactly reproducible.
    fn spawn_replica(&mut self, now: f64) -> usize {
        let i = self.replicas.len();
        let profile = self.cfg.cluster.replica_profile(&self.cfg.engine, i);
        let seed = self.cfg.seed ^ ((i as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut coord = crate::serve::build_sim_coordinator_with(&self.cfg, profile, seed);
        if self.cfg.cluster.autoscale.prewarm {
            crate::serve::prewarm_predictor(coord.predictor.as_mut(), &self.cfg);
        }
        coord.advance_to(now);
        self.replicas.push(ClusterReplica {
            coord,
            speed: self.cfg.cluster.speed_of(i),
            state: ReplicaState::Provisioning,
            down_since: 0.0,
            downtime: 0.0,
            spawned_at: now,
            ready_at: now + self.cfg.cluster.autoscale.provision_delay,
            retired_at: None,
            seen_outcomes: 0,
            seen_aborted: 0,
        });
        self.backlog.push(0.0);
        self.backlog_var.push(0.0);
        self.routed.push(0);
        i
    }

    /// Begin scale-in on `victim`: stop routing to it, re-route its
    /// never-scheduled queued work through the router (those requests hold
    /// no KV or engine state, so the migration is exact), and leave its
    /// running/preempted requests to finish in place. Unlike crash
    /// re-dispatch, a *voluntary* scale-in must be lossless: a queued
    /// request whose re-route target has no admission headroom (or when no
    /// replica is routable at all) stays on the victim, which keeps serving
    /// until its live set drains. Retires immediately when nothing is left
    /// live.
    fn begin_drain(&mut self, victim: usize, now: f64) -> anyhow::Result<()> {
        self.replicas[victim].state = ReplicaState::Draining;
        self.replicas[victim].coord.advance_to(now);
        self.record(now, victim, ScaleAction::Drain);
        let mut moved = self.replicas[victim].coord.drain_queued(usize::MAX);
        for req in &moved {
            if let Some(f) = self.in_flight.remove(&req.id) {
                debug_assert_eq!(f.replica, victim, "in-flight map out of sync at drain");
                self.release_backlog(f.replica, f.cost, f.var, f.weight);
            }
        }
        moved.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        for req in moved {
            if self.place(req, now, Some(victim))? {
                self.drained += 1;
            }
        }
        self.steal_dirty = true;
        if self.replicas[victim].coord.is_idle() {
            self.retire(victim, now);
        }
        Ok(())
    }

    /// Finalize a drained replica's exit.
    fn retire(&mut self, i: usize, at: f64) {
        let at = at.max(self.replicas[i].coord.now());
        self.replicas[i].state = ReplicaState::Retired;
        self.replicas[i].retired_at = Some(at);
        self.record(at, i, ScaleAction::Retire);
    }

    fn record(&mut self, at: f64, replica: usize, action: ScaleAction) {
        self.scaling_events.push(ScalingEvent { at, replica, action });
    }

    /// Idle-replica work stealing: while some routable replica sits idle
    /// and another has more than one live request including never-scheduled
    /// (queued) ones, migrate up to half of the victim's queued requests to
    /// the idle replica. Queued requests hold no KV or engine state, so the
    /// only migration cost is shipping the prompt — each candidate is gated
    /// on a benefit check: the speed-normalized predicted backlog it stops
    /// waiting behind must exceed a transfer penalty proportional to its
    /// prompt length (`ClusterConfig::steal_transfer_per_token`; 0 restores
    /// unconditional stealing). Rejected candidates are counted in
    /// [`EventCluster::steals_skipped`]. The thief's clock is advanced to
    /// the victim's so no request runs before the moment it was provably
    /// stealable.
    fn steal_work(&mut self) {
        if !self.steal_dirty {
            return; // nothing changed since the last fruitless pass
        }
        // the pass below runs to quiescence (it loops until no profitable
        // steal remains), so afterwards only a state change can make a new
        // pass worthwhile — the mutators set the flag again
        self.steal_dirty = false;
        let transfer = self.cfg.cluster.steal_transfer_per_token;
        'pass: loop {
            let thief = match self
                .replicas
                .iter()
                .position(|r| r.routable() && r.coord.is_idle())
            {
                Some(t) => t,
                None => return,
            };
            // candidate victims, most-queued first (ties to the lowest
            // index for determinism); later victims are tried when the
            // most-backlogged one has no gate-passing candidate, so a small
            // cheap queue cannot shadow a profitable one
            let mut victims: Vec<(usize, usize)> = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(j, r)| {
                    *j != thief && r.routable() && r.coord.live_count() >= 2
                })
                .map(|(j, r)| (j, r.coord.queued_count()))
                .filter(|&(_, queued)| queued > 0)
                .collect();
            victims.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            if victims.is_empty() {
                return;
            }
            // cap at the thief's admission window (it is idle, so its live
            // set is empty): stolen submissions must never be refused, or a
            // request that was safely queued would count as rejected
            let capacity = match self.replicas[thief].coord.max_queue {
                0 => usize::MAX,
                cap => cap,
            };
            for (v, v_queued) in victims {
                let take = v_queued.div_ceil(2).min(capacity);
                let speed_v = self.replicas[v].speed.max(1e-9);
                let speed_t = self.replicas[thief].speed.max(1e-9);
                // running tallies so each candidate is judged against the
                // backlog as it would stand after the moves chosen so far.
                // The benefit is the completion-time delta: the queue *and
                // own service* it would pay on the victim, minus the queue
                // it joins plus its own (speed-adjusted) service on the
                // thief — so shipping work to a much slower replica is
                // charged for the slower execution, not just the transfer.
                let mut backlog_v = self.backlog[v];
                let mut backlog_t = self.backlog[thief];
                let meta = self.replicas[v].coord.queued_meta();
                let mut chosen: Vec<RequestId> = Vec::with_capacity(take);
                for &(id, input_len, _) in meta.iter().take(take) {
                    let own = self.in_flight.get(&id).map(|f| f.cost).unwrap_or(0.0);
                    let benefit = backlog_v / speed_v - (backlog_t + own) / speed_t;
                    if transfer > 0.0 && benefit <= transfer * input_len as f64 {
                        self.steal_rejected.insert(id);
                        continue;
                    }
                    chosen.push(id);
                    backlog_v = (backlog_v - own).max(0.0);
                    backlog_t += own;
                }
                if chosen.is_empty() {
                    continue; // nothing profitable here: try the next victim
                }
                let victim_now = self.replicas[v].coord.now();
                let moved = self.replicas[v].coord.drain_ids(&chosen);
                if moved.is_empty() {
                    return;
                }
                self.replicas[thief].coord.advance_to(victim_now);
                for req in moved {
                    let id = req.id;
                    // stealing is a migration: the request already passed
                    // admission on the victim, so the thief must not
                    // re-apply (class-aware) admission and refuse it
                    let accepted = self.replicas[thief].coord.submit_exempt(req);
                    debug_assert!(accepted, "idle thief must accept within its window");
                    if !accepted {
                        continue;
                    }
                    self.stolen += 1;
                    if let Some(entry) = self.in_flight.get_mut(&id) {
                        let (pcost, pvar) = (entry.cost, entry.var);
                        let from = entry.replica;
                        entry.replica = thief;
                        self.backlog[from] = (self.backlog[from] - pcost).max(0.0);
                        self.backlog_var[from] = (self.backlog_var[from] - pvar).max(0.0);
                        self.backlog[thief] += pcost;
                        self.backlog_var[thief] += pvar;
                    }
                }
                // the thief is busy now; look for another idle replica
                continue 'pass;
            }
            // no victim offered a profitable steal. An idle thief's own
            // backlog is ~0, so the verdict would be the same for every
            // other idle replica of any speed: stop the pass.
            return;
        }
    }

    /// Step replica `i` and fail loudly if it is wedged instead of spinning
    /// forever. A no-progress step with live work means some request can
    /// never be scheduled (e.g. its prompt needs more KV blocks than the
    /// replica owns), which is a configuration error, not a transient.
    /// A draining replica whose last live request just finished retires
    /// here.
    fn check_progress(&mut self, i: usize) -> anyhow::Result<()> {
        if !self.step_replica(i)? {
            anyhow::bail!(
                "replica {i} is wedged: {} live request(s) but a scheduling \
                 iteration made no progress — its capacity (kv_capacity {} \
                 tokens, max_batch {}) cannot serve the routed workload",
                self.replicas[i].coord.live_count(),
                self.replicas[i].coord.kv.total_blocks()
                    * self.replicas[i].coord.kv.block_tokens(),
                self.replicas[i].coord.engine.max_batch(),
            );
        }
        if self.replicas[i].state == ReplicaState::Draining
            && self.replicas[i].coord.is_idle()
        {
            let at = self.replicas[i].coord.now();
            self.retire(i, at);
        }
        Ok(())
    }

    /// Total completions across replicas.
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.coord.outcomes().len()).sum()
    }

    /// Merged outcome stream (unsorted).
    pub fn merged_outcomes(&self) -> Vec<crate::core::RequestOutcome> {
        let mut out = Vec::with_capacity(self.completed());
        for r in &self.replicas {
            out.extend_from_slice(r.coord.outcomes());
        }
        out
    }

    /// Cluster-level report (aggregate + per-replica + lifecycle counters +
    /// scaling timeline).
    pub fn report(&self, warmup_fraction: f64) -> ClusterReport {
        let per_replica: Vec<RunReport> = self
            .replicas
            .iter()
            .map(|r| r.coord.report(warmup_fraction))
            .collect();
        // an outage still open at report time is charged up to the
        // cluster-wide clock horizon; a *retired* replica is simply gone —
        // it must not count as "down" for the remainder of the run, and a
        // replica added mid-run is charged only from its provisioning time
        let horizon = self
            .replicas
            .iter()
            .map(|r| r.coord.now())
            .fold(0.0, f64::max);
        let downtime: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| {
                r.downtime
                    + if r.state == ReplicaState::Down {
                        (horizon - r.down_since).max(0.0)
                    } else {
                        0.0
                    }
            })
            .collect();
        let replica_seconds: Vec<f64> = self
            .replicas
            .iter()
            .map(|r| r.replica_seconds(horizon))
            .collect();
        ClusterReport::new(
            self.router.name().to_string(),
            per_replica,
            crate::metrics::ClusterCounters {
                routed: self.routed.clone(),
                re_routed: self.re_routed,
                drained: self.drained,
                stolen: self.stolen,
                steals_skipped: self.steals_skipped(),
                downtime,
                replica_seconds,
                scaling_events: self.scaling_events.clone(),
            },
            &self.merged_outcomes(),
            warmup_fraction,
            &self.cfg.slo.specs,
        )
    }
}

/// Run one event-driven cluster experiment with an explicit router over the
/// config's seeded workload. Callers comparing routers call this repeatedly
/// with the same `cfg`: the workload (same seed) is bit-identical across
/// calls, so reports are directly comparable.
pub fn run_router_experiment(
    cfg: &ExperimentConfig,
    router: RouterKind,
) -> anyhow::Result<ClusterReport> {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, router);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster.report(cfg.warmup_fraction))
}

/// Run the event-driven cluster with the router configured in
/// `cfg.cluster.router`.
pub fn run_event_cluster(cfg: &ExperimentConfig) -> anyhow::Result<ClusterReport> {
    run_router_experiment(cfg, cfg.cluster.router)
}

/// A multi-node serving simulation returning per-node reports: the cluster
/// serves `n_nodes`× the configured per-node load behind a least-loaded
/// router. Useful when sweeping cluster *size* at fixed per-node load
/// (the event-driven cluster does the work; [`run_router_experiment`] is
/// the entry point for fixed-load router comparisons).
pub fn run_cluster_experiment(
    cfg: &ExperimentConfig,
    n_nodes: usize,
) -> anyhow::Result<Vec<RunReport>> {
    let mut scaled = cfg.clone();
    scaled.workload.rps = cfg.workload.rps * n_nodes as f64;
    scaled.workload.n_requests = cfg.workload.n_requests * n_nodes;
    scaled.cluster.replicas = n_nodes;
    let workload = WorkloadGen::new(scaled.workload.clone(), scaled.seed).generate();
    let mut cluster = EventCluster::with_router(&scaled, RouterKind::LeastLoaded);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster
        .replicas
        .iter()
        .map(|r| r.coord.report(cfg.warmup_fraction))
        .collect())
}

// ===========================================================================
// Overhead measurement (legacy fig12 mode)
// ===========================================================================

/// Result of one cluster-scale overhead measurement.
#[derive(Clone, Debug)]
pub struct ClusterOverhead {
    pub nodes: usize,
    pub aggregate_rps: f64,
    /// mean per-request predict latency, seconds (service + queueing)
    pub predict_latency: f64,
    /// mean per-request scheduling latency, seconds (priority eval + sort
    /// at the configured queue depth)
    pub sched_latency: f64,
    /// total per-request overhead
    pub total_latency: f64,
    /// utilization of the shared predictor service
    pub predictor_utilization: f64,
}

/// Cluster-scalability overhead simulator (wallclock-measured shared
/// predictor + scheduler service times, M/M/1 queueing at the predictor).
pub struct ClusterSim {
    pub cfg: ExperimentConfig,
    /// per-node request rate (paper: 8 RPS/node)
    pub rps_per_node: f64,
    /// scheduler queue depth to exercise (paper: up to 1,000 buffered)
    pub queue_depth: usize,
    /// number of measured prediction/scheduling operations per point
    pub samples: usize,
}

impl ClusterSim {
    pub fn new(cfg: ExperimentConfig) -> ClusterSim {
        ClusterSim { cfg, rps_per_node: 8.0, queue_depth: 1000, samples: 200 }
    }

    /// Measure predict + schedule overhead for an `n_nodes` cluster.
    pub fn measure(&self, n_nodes: usize) -> ClusterOverhead {
        let mut rng = Rng::new(self.cfg.seed ^ (n_nodes as u64) << 8);

        // --- build a warm shared history index at paper scale -------------
        let mut wl_cfg = self.cfg.workload.clone();
        wl_cfg.n_requests = self.cfg.history_capacity.min(10_000);
        let warm = WorkloadGen::new(wl_cfg, self.cfg.seed ^ 0xc1).generate();
        let mut predictor = HistoryPredictor::new(
            self.cfg.workload.embed_dim,
            self.cfg.history_capacity,
            self.cfg.similarity_threshold,
        );
        for r in &warm.requests {
            predictor.observe(r, r.true_output_len);
        }

        // --- measure predict service time ---------------------------------
        let mut probe_cfg = self.cfg.workload.clone();
        probe_cfg.n_requests = self.samples;
        let probes = WorkloadGen::new(probe_cfg, self.cfg.seed ^ 0xc2).generate();
        let mut service_times = Vec::with_capacity(self.samples);
        let mut dists: Vec<LengthDist> = Vec::with_capacity(self.samples);
        for r in &probes.requests {
            let t0 = Instant::now();
            let d = predictor.predict(r);
            service_times.push(t0.elapsed().as_secs_f64());
            dists.push(d);
        }
        let s_pred = mean(&service_times);

        // The shared predictor serves the whole cluster: arrival rate
        // lambda = nodes * rps; M/M/1 waiting time = rho/(1-rho) * s.
        let lambda = n_nodes as f64 * self.rps_per_node;
        let rho = (lambda * s_pred).min(0.99);
        let predict_latency = s_pred + s_pred * rho / (1.0 - rho);

        // --- measure scheduling latency at queue depth --------------------
        // real Gittins evaluations + a real sort over `queue_depth` entries,
        // replicating one coordinator iteration's scheduling work.
        let cost: Box<dyn CostModel> = crate::cost::make_cost_model(self.cfg.cost_model);
        let mut entries: Vec<(f64, LengthDist, u32, u32)> = (0..self.queue_depth)
            .map(|i| {
                let d = &dists[i % dists.len()];
                let input = 64 + (rng.below(512) as u32);
                let gen = rng.below(200) as u32;
                (0.0, cost.cost_dist(input, d), input, gen)
            })
            .collect();
        let mut sched_times = Vec::with_capacity(self.samples.min(50));
        for _ in 0..self.samples.min(50) {
            let t0 = Instant::now();
            for e in entries.iter_mut() {
                let consumed = cost.consumed(e.2, e.3);
                e.0 = gittins_index_at_age(&e.1, consumed);
            }
            let mut order: Vec<usize> = (0..entries.len()).collect();
            order.sort_by(|&a, &b| entries[a].0.partial_cmp(&entries[b].0).unwrap());
            std::hint::black_box(&order);
            sched_times.push(t0.elapsed().as_secs_f64());
        }
        // scheduling happens per node but the paper's centralized variant
        // scales the work with cluster size; model one scheduler handling
        // all nodes' queues round-robin. Up to 64 nodes one full-depth pass
        // covers everyone; past that the pass count grows linearly.
        let sched_latency = mean(&sched_times) * sched_scale(n_nodes);

        ClusterOverhead {
            nodes: n_nodes,
            aggregate_rps: lambda,
            predict_latency,
            sched_latency,
            total_latency: predict_latency + sched_latency,
            predictor_utilization: rho,
        }
    }

    /// Sweep cluster sizes (the paper's Fig. 12 x-axis).
    pub fn sweep(&self, sizes: &[usize]) -> Vec<ClusterOverhead> {
        sizes.iter().map(|&n| self.measure(n)).collect()
    }
}

/// Centralized-scheduler work multiplier: `(n/64).max(1)` full-depth
/// scheduling passes. Monotone non-decreasing in `n` — a small cluster pays
/// one full pass, never a fraction of one. (The previous expression,
/// `n / 64.0_f64.max(1.0)`, divided *every* cluster size by a constant 64
/// due to operator precedence, so 1-node clusters reported 64× too little
/// scheduling overhead.)
pub fn sched_scale(n_nodes: usize) -> f64 {
    (n_nodes as f64 / 64.0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    #[test]
    fn route_picks_min() {
        assert_eq!(route_least_loaded(&[3, 1, 2]), 1);
        assert_eq!(route_least_loaded(&[0]), 0);
    }

    fn view(id: usize, live: usize, used: usize, backlog: f64, speed: f64) -> ReplicaView {
        ReplicaView {
            id,
            live,
            kv_used_blocks: used,
            kv_total_blocks: 100,
            now: 0.0,
            speed,
            max_batch: 8,
            predicted_backlog: backlog,
            predicted_backlog_var: 0.0,
        }
    }

    fn any_req() -> Request {
        let mut cfg = crate::config::WorkloadConfig::default();
        cfg.n_requests = 1;
        WorkloadGen::new(cfg, 1).generate().requests.pop().unwrap()
    }

    #[test]
    fn routers_pick_expected_replicas() {
        let views = vec![
            view(0, 4, 80, 500.0, 1.0),
            view(1, 2, 90, 100.0, 1.0),
            view(2, 3, 10, 400.0, 0.1),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        // cost-aware: 500/1, 100/1, 400/0.1=4000 -> replica 1
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        assert_eq!(rr.route(&r, 1.0, &views), 0);
        assert_eq!(rr.route(&r, 1.0, &views), 1);
        assert_eq!(rr.route(&r, 1.0, &views), 2);
        assert_eq!(rr.route(&r, 1.0, &views), 0);
    }

    #[test]
    fn routers_return_positions_not_ids_over_sparse_views() {
        // the surviving view set after failures: ids 3/7/9, positions 0/1/2.
        // returning `ReplicaView::id` here (the old bug) would be out of
        // range or a misroute.
        let views = vec![
            view(3, 4, 80, 500.0, 1.0),
            view(7, 2, 90, 100.0, 1.0),
            view(9, 3, 10, 400.0, 1.0),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        for expect in [0usize, 1, 2, 0] {
            assert_eq!(rr.route(&r, 1.0, &views), expect);
        }
    }

    #[test]
    fn make_router_builds_all_kinds() {
        for kind in RouterKind::ALL {
            assert_eq!(make_router(kind, 0.9).kind(), kind);
        }
    }

    #[test]
    fn quantile_router_avoids_heavy_tailed_backlogs() {
        // equal mean backlogs, very different tails: the mean-based router
        // ties to the lowest index, the quantile router steers to the
        // narrow one
        let mut views = vec![view(0, 3, 50, 400.0, 1.0), view(1, 3, 50, 400.0, 1.0)];
        views[0].predicted_backlog_var = 250_000.0; // sd 500
        views[1].predicted_backlog_var = 100.0; // sd 10
        let r = any_req();
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 0);
        let mut q = QuantileCostRouter::new(0.9);
        assert_eq!(q.route(&r, 1.0, &views), 1);
        // at q=0.5 (z=0) it degrades to exactly the mean router's choice
        let mut q50 = QuantileCostRouter::new(0.5);
        assert_eq!(q50.route(&r, 1.0, &views), 0);
    }

    #[test]
    fn class_aware_router_gives_interactive_headroom() {
        let mut r = ClassAwareRouter::new(Box::new(RoundRobinRouter::default()));
        // replica 0: 95% KV occupancy (no headroom), small backlog;
        // replica 1: plenty of headroom, larger backlog
        let mut views = vec![view(0, 3, 95, 100.0, 1.0), view(1, 3, 10, 400.0, 1.0)];
        let mut req = any_req();
        req.slo = SloClass::Interactive;
        // interactive avoids the KV-saturated replica even though its
        // backlog is smaller
        assert_eq!(r.route(&req, 1.0, &views), 1);
        // batch delegates to the inner round-robin (first call -> slot 0)
        req.slo = SloClass::Batch;
        assert_eq!(r.route(&req, 1.0, &views), 0);
        // no replica has KV headroom: fall back to the full set, picked on
        // the p95 quantile of outstanding cost (tail-averse placement)
        views[1].kv_used_blocks = 96;
        views[0].predicted_backlog_var = 250_000.0; // sd 500
        views[1].predicted_backlog_var = 0.0;
        req.slo = SloClass::Interactive;
        // q0 = 100 + 1.645*500 ~= 922 > q1 = 400
        assert_eq!(r.route(&req, 1.0, &views), 1);
        // wrapper is label-transparent for A/B reporting
        assert_eq!(r.kind(), RouterKind::RoundRobin);
    }

    #[test]
    fn event_cluster_conserves_requests() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 60;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::CostAware);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 60);
        assert_eq!(cluster.rejected(), 0);
        assert_eq!(cluster.in_flight_count(), 0);
        let report = cluster.report(0.0);
        assert_eq!(report.aggregate.measured, 60);
        assert_eq!(report.per_replica.len(), 4);
        assert_eq!(report.aggregate.completed, 60);
        assert_eq!(report.aggregate.rejected, 0);
        assert!((report.aggregate.goodput() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overhead_grows_with_cluster_size() {
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 2000; // keep the test quick
        let sim = ClusterSim { samples: 30, queue_depth: 200, ..ClusterSim::new(cfg) };
        let small = sim.measure(1);
        let large = sim.measure(64);
        assert!(large.total_latency > small.total_latency);
        assert!(large.predictor_utilization >= small.predictor_utilization);
    }

    #[test]
    fn sched_scale_never_discounts_small_clusters() {
        // regression for the precedence bug `n / 64.0_f64.max(1.0)`: small
        // clusters must pay one full scheduling pass, not 1/64th of one
        assert_eq!(sched_scale(1), 1.0);
        assert_eq!(sched_scale(16), 1.0);
        assert_eq!(sched_scale(64), 1.0);
        assert_eq!(sched_scale(128), 2.0);
        let mut prev = 0.0;
        for n in [1usize, 2, 8, 32, 64, 96, 128, 512] {
            let s = sched_scale(n);
            assert!(s >= prev, "sched_scale not monotone at {n}");
            assert!(s >= 1.0);
            prev = s;
        }
    }

    #[test]
    fn measured_sched_latency_comparable_across_sizes() {
        // wallclock-level regression: under the old bug a 1-node cluster
        // reported ~1/64th of the 64-node scheduling latency; fixed, both
        // pay one full-depth pass and differ only by measurement noise
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 1000;
        let sim = ClusterSim { samples: 20, queue_depth: 200, ..ClusterSim::new(cfg) };
        let one = sim.measure(1);
        let big = sim.measure(64);
        assert!(
            one.sched_latency > 0.1 * big.sched_latency,
            "1-node sched latency {} implausibly below 64-node {}",
            one.sched_latency,
            big.sched_latency
        );
    }

    #[test]
    fn invalid_failure_events_are_hard_errors() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 5;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![FailureEvent { replica: 9, at: 1.0, duration: 1.0 }];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("replica 9"), "got: {err}");
    }

    #[test]
    fn overlapping_outages_merge_to_their_union() {
        // regression: a short outage nested inside a long one must not
        // resurrect the replica at the short outage's recovery point
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 200;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 1.0, duration: 6.0 },
            FailureEvent { replica: 0, at: 2.0, duration: 1.0 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 200);
        let report = cluster.report(0.0);
        assert!(
            (report.downtime[0] - 6.0).abs() < 1e-9,
            "union outage is [1,7): downtime {} != 6.0",
            report.downtime[0]
        );
    }

    #[test]
    fn all_replicas_down_is_a_hard_error_not_a_silent_drop() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 20;
        cfg.workload.rps = 10.0;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 0.0, duration: 1e6 },
            FailureEvent { replica: 1, at: 0.0, duration: 1e6 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("routable"), "got: {err}");
    }

    #[test]
    fn cluster_experiment_completes_all() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 40;
        cfg.warmup_fraction = 0.0;
        let reports = run_cluster_experiment(&cfg, 3).unwrap();
        assert_eq!(reports.len(), 3);
        let total: usize = reports.iter().map(|r| r.measured).sum();
        assert_eq!(total, 120);
    }
}
