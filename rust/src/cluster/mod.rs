//! Multi-node cluster simulation (§4.4 scalability, Fig. 12).
//!
//! Simulates up to 64 GPU nodes, each running its own coordinator instance
//! at 8 RPS with up to 1,000 queued requests, behind a least-loaded router
//! and a *shared* prediction service (one embedding index serving the whole
//! cluster, as the paper's centralized scheduler does). The quantities the
//! paper reports — per-request **predicting latency** and **scheduling
//! latency** as the cluster grows — are *measured wallclock* here: real
//! FlatIndex searches over a 10k-record window under the cluster's
//! aggregate arrival rate, and real priority evaluation + batch packing at
//! the configured queue depth, plus M/M/1 queueing delay at the shared
//! predictor implied by the measured service time.

use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::cost::CostModel;
use crate::distribution::LengthDist;
use crate::gittins::gittins_index_at_age;
use crate::predictor::{HistoryPredictor, Predictor};
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::WorkloadGen;

/// Result of one cluster-scale measurement.
#[derive(Clone, Debug)]
pub struct ClusterOverhead {
    pub nodes: usize,
    pub aggregate_rps: f64,
    /// mean per-request predict latency, seconds (service + queueing)
    pub predict_latency: f64,
    /// mean per-request scheduling latency, seconds (priority eval + sort
    /// at the configured queue depth)
    pub sched_latency: f64,
    /// total per-request overhead
    pub total_latency: f64,
    /// utilization of the shared predictor service
    pub predictor_utilization: f64,
}

/// Cluster-scalability simulator.
pub struct ClusterSim {
    pub cfg: ExperimentConfig,
    /// per-node request rate (paper: 8 RPS/node)
    pub rps_per_node: f64,
    /// scheduler queue depth to exercise (paper: up to 1,000 buffered)
    pub queue_depth: usize,
    /// number of measured prediction/scheduling operations per point
    pub samples: usize,
}

impl ClusterSim {
    pub fn new(cfg: ExperimentConfig) -> ClusterSim {
        ClusterSim { cfg, rps_per_node: 8.0, queue_depth: 1000, samples: 200 }
    }

    /// Measure predict + schedule overhead for an `n_nodes` cluster.
    pub fn measure(&self, n_nodes: usize) -> ClusterOverhead {
        let mut rng = Rng::new(self.cfg.seed ^ (n_nodes as u64) << 8);

        // --- build a warm shared history index at paper scale -------------
        let mut wl_cfg = self.cfg.workload.clone();
        wl_cfg.n_requests = self.cfg.history_capacity.min(10_000);
        let warm = WorkloadGen::new(wl_cfg, self.cfg.seed ^ 0xc1).generate();
        let mut predictor = HistoryPredictor::new(
            self.cfg.workload.embed_dim,
            self.cfg.history_capacity,
            self.cfg.similarity_threshold,
        );
        for r in &warm.requests {
            predictor.observe(r, r.true_output_len);
        }

        // --- measure predict service time ---------------------------------
        let mut probe_cfg = self.cfg.workload.clone();
        probe_cfg.n_requests = self.samples;
        let probes = WorkloadGen::new(probe_cfg, self.cfg.seed ^ 0xc2).generate();
        let mut service_times = Vec::with_capacity(self.samples);
        let mut dists: Vec<LengthDist> = Vec::with_capacity(self.samples);
        for r in &probes.requests {
            let t0 = Instant::now();
            let d = predictor.predict(r);
            service_times.push(t0.elapsed().as_secs_f64());
            dists.push(d);
        }
        let s_pred = mean(&service_times);

        // The shared predictor serves the whole cluster: arrival rate
        // lambda = nodes * rps; M/M/1 waiting time = rho/(1-rho) * s.
        let lambda = n_nodes as f64 * self.rps_per_node;
        let rho = (lambda * s_pred).min(0.99);
        let predict_latency = s_pred + s_pred * rho / (1.0 - rho);

        // --- measure scheduling latency at queue depth --------------------
        // real Gittins evaluations + a real sort over `queue_depth` entries,
        // replicating one coordinator iteration's scheduling work.
        let cost: Box<dyn CostModel> = crate::cost::make_cost_model(self.cfg.cost_model);
        let mut entries: Vec<(f64, LengthDist, u32, u32)> = (0..self.queue_depth)
            .map(|i| {
                let d = &dists[i % dists.len()];
                let input = 64 + (rng.below(512) as u32);
                let gen = rng.below(200) as u32;
                (0.0, cost.cost_dist(input, d), input, gen)
            })
            .collect();
        let mut sched_times = Vec::with_capacity(self.samples.min(50));
        for _ in 0..self.samples.min(50) {
            let t0 = Instant::now();
            for e in entries.iter_mut() {
                let consumed = cost.consumed(e.2, e.3);
                e.0 = gittins_index_at_age(&e.1, consumed);
            }
            let mut order: Vec<usize> = (0..entries.len()).collect();
            order.sort_by(|&a, &b| entries[a].0.partial_cmp(&entries[b].0).unwrap());
            std::hint::black_box(&order);
            sched_times.push(t0.elapsed().as_secs_f64());
        }
        // scheduling happens per node but the paper's centralized variant
        // scales the work with cluster size; model one scheduler handling
        // all nodes' queues round-robin:
        let sched_latency = mean(&sched_times) * n_nodes as f64 / 64.0_f64.max(1.0);
        // normalize so the 64-node point does one full-depth pass
        let sched_latency = sched_latency.max(mean(&sched_times) * n_nodes as f64 / 64.0);

        ClusterOverhead {
            nodes: n_nodes,
            aggregate_rps: lambda,
            predict_latency,
            sched_latency,
            total_latency: predict_latency + sched_latency,
            predictor_utilization: rho,
        }
    }

    /// Sweep cluster sizes (the paper's Fig. 12 x-axis).
    pub fn sweep(&self, sizes: &[usize]) -> Vec<ClusterOverhead> {
        sizes.iter().map(|&n| self.measure(n)).collect()
    }
}

/// Least-loaded routing decision across per-node live counts (exposed for
/// tests and the cluster example).
pub fn route_least_loaded(loads: &[usize]) -> usize {
    loads
        .iter()
        .enumerate()
        .min_by_key(|(_, &l)| l)
        .map(|(i, _)| i)
        .expect("route over empty cluster")
}

/// A multi-node serving simulation: N independent sim coordinators with
/// least-loaded routing. Used by `examples/cluster_sim.rs` and the fig12
/// bench to show end-to-end latency is preserved at scale.
pub fn run_cluster_experiment(
    cfg: &ExperimentConfig,
    n_nodes: usize,
) -> anyhow::Result<Vec<crate::metrics::RunReport>> {
    let mut wl_cfg = cfg.workload.clone();
    wl_cfg.rps = cfg.workload.rps * n_nodes as f64;
    wl_cfg.n_requests = cfg.workload.n_requests * n_nodes;
    let workload = WorkloadGen::new(wl_cfg, cfg.seed).generate();

    let mut coords: Vec<_> = (0..n_nodes)
        .map(|_| crate::serve::build_sim_coordinator(cfg))
        .collect();
    // route by least live requests at arrival time, then run each node
    let mut assigned: Vec<Vec<crate::core::Request>> = vec![Vec::new(); n_nodes];
    let mut loads = vec![0usize; n_nodes];
    // approximate live-load tracking: decay by completions at fixed service
    // estimate; for routing purposes arrival-count round-robin least-loaded
    for r in workload.requests {
        let node = route_least_loaded(&loads);
        loads[node] += 1;
        assigned[node].push(r);
        // decay: oldest nodes shed load as time passes
        if loads.iter().sum::<usize>() % (n_nodes * 4) == 0 {
            for l in loads.iter_mut() {
                *l = l.saturating_sub(1);
            }
        }
    }
    let mut reports = Vec::with_capacity(n_nodes);
    for (coord, reqs) in coords.iter_mut().zip(assigned) {
        coord.run_workload(reqs)?;
        reports.push(coord.report(cfg.warmup_fraction));
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    #[test]
    fn route_picks_min() {
        assert_eq!(route_least_loaded(&[3, 1, 2]), 1);
        assert_eq!(route_least_loaded(&[0]), 0);
    }

    #[test]
    fn overhead_grows_with_cluster_size() {
        let mut cfg = ExperimentConfig::default();
        cfg.history_capacity = 2000; // keep the test quick
        let sim = ClusterSim { samples: 30, queue_depth: 200, ..ClusterSim::new(cfg) };
        let small = sim.measure(1);
        let large = sim.measure(64);
        assert!(large.total_latency > small.total_latency);
        assert!(large.predictor_utilization >= small.predictor_utilization);
    }

    #[test]
    fn cluster_experiment_completes_all() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 40;
        cfg.warmup_fraction = 0.0;
        let reports = run_cluster_experiment(&cfg, 3).unwrap();
        assert_eq!(reports.len(), 3);
        let total: usize = reports.iter().map(|r| r.measured).sum();
        assert_eq!(total, 120);
    }
}
