//! Multi-replica cluster serving (§4.4 scalability).
//!
//! The event-driven cluster simulation is built from four pieces:
//!
//! * [`kernel`] — the virtual clock's deterministic event queue: every
//!   timed occurrence is a [`KernelEvent`], ordered by `(time, class,
//!   seq)` so same-seed runs are byte-identical.
//! * [`ClusterCtx`] ([`ctx`]) — the shared state every concern observes:
//!   the replica roster (each a full [`Coordinator`](crate::serve::Coordinator)
//!   `<`[`SimEngine`](crate::engine::SimEngine)`>` — real continuous
//!   batching, KV-block accounting, preemption), the pluggable [`Router`],
//!   a *shared* prediction service (one history index fronting the whole
//!   cluster, as the paper's centralized scheduler has), per-replica
//!   predicted-backlog moments, and all lifecycle counters. Replica
//!   lifecycle and scale-in mechanics (outages, drains, migration) are a
//!   second `impl` block in [`lifecycle`].
//! * [`components`] — the [`ClusterComponent`] implementations, one per
//!   concern: [`ArrivalSource`] (workload in), [`FailureInjector`]
//!   (single-replica outages + correlated failure domains),
//!   [`AutoscaleDriver`] (elastic scale-out/in, including
//!   migration-cost-aware victim selection and per-pool policies under
//!   disaggregation), [`TransferFabric`] (the disaggregated prefill →
//!   decode KV handoff over bandwidth-limited links), [`WorkStealer`]
//!   (idle-replica stealing, pool-confined), and [`SloAdmission`] (the
//!   placement/admission seam). Components talk through the kernel, never
//!   to each other.
//! * [`EventCluster`] (this file) — the orchestrator: it owns the context,
//!   registers the components, and drives the loop — step the busiest
//!   trailing replica until every busy replica has caught up to the next
//!   event, then hand that event to its component. Replicas may be
//!   heterogeneous (per-replica speed / batch-size / KV-capacity from
//!   [`ClusterConfig`](crate::config::ClusterConfig)).
//!
//! Routers ([`router`]): `round-robin`, `least-loaded` (live-request
//! count), `least-kv` (KV-block occupancy), `cost-aware` (predicted
//! outstanding cost from the shared predictor, normalized by replica
//! speed), and `quantile-cost` (the distribution-aware variant), plus the
//! [`ClassAwareRouter`] SLO wrapper. Routers see only the *routable*
//! replica set and return positions into it; the dispatcher maps positions
//! back to replica ids.
//!
//! Replica lifecycle ([`replica`]): replicas move through
//! [`ReplicaState`]s — failures drain and re-dispatch live work over the
//! survivors, domain outages do so for a whole rack/zone in one event,
//! autoscaling spawns cold replicas behind a provisioning delay and
//! retires drained victims (optionally migrating their partially-generated
//! work when shipping KV beats waiting). Every transition lands on the
//! [`ScalingEvent`](crate::autoscale::ScalingEvent) timeline, and the
//! report charges each replica only for its provisioned lifetime
//! (`replica_seconds`), yielding goodput per replica-second.
//!
//! Arrival pacing — including the bursty MMPP and diurnal processes under
//! which failure/re-routing is most interesting — lives in
//! [`crate::workload::arrivals`] and is configured per workload.
//!
//! Disaggregated serving ([`disagg`]): with
//! [`ClusterConfig::pools`](crate::config::ClusterConfig) non-empty the
//! roster splits into a prefill pool and a decode pool. Fresh arrivals
//! route over the prefill pool only; once a request reaches its first
//! token the [`TransferFabric`] ships its KV to the decode pool over
//! bandwidth-limited links, and each pool is sized by its own autoscale
//! policy instance against the pool's share of the forecast (TTFT-weighted
//! prefill cost vs TPOT-weighted decode cost under the SLO-aware policy).
//!
//! Dispatch-rate hot paths are answered from the incrementally-maintained
//! score indexes in [`index`] (per-metric lazy-deletion heaps updated on
//! every ctx delta) rather than full view rescans; setting
//! `ClusterCtx::use_indexes` to false before a run retains the original
//! rescan algorithms verbatim — the differential oracle the equivalence
//! suite (and debug-build cross-checks) compare against. Index order
//! equals [`argmin`] rescan order exactly, so same-seed reports are
//! byte-identical either way.
//!
//! The legacy fig12 **overhead measurement** ([`ClusterSim`]) is kept as a
//! secondary mode behind `sagesched cluster --overhead`; see [`overhead`].

pub mod components;
pub mod ctx;
pub mod disagg;
pub mod index;
pub mod kernel;
pub mod lifecycle;
pub mod overhead;
pub mod replica;
pub mod router;

pub use components::{
    ArrivalSource, AutoscaleDriver, ClusterComponent, FailureInjector, SloAdmission,
    TransferFabric, WorkStealer,
};
pub use ctx::ClusterCtx;
pub use kernel::{EventPayload, EventQueue, KernelEvent};
pub use overhead::{sched_scale, ClusterOverhead, ClusterSim};
pub use replica::{ClusterReplica, ReplicaState};
pub use router::{
    argmin, make_router, route_least_loaded, ClassAwareRouter, CostAwareRouter,
    LeastKvRouter, LeastLoadedRouter, QuantileCostRouter, ReplicaView, RoundRobinRouter,
    Router,
};

use crate::config::{ExperimentConfig, RouterKind};
use crate::core::Request;
use crate::metrics::{ClusterReport, RunReport};
use crate::workload::WorkloadGen;

/// The event-driven multi-replica cluster: a [`ClusterCtx`] driven by the
/// registered [`ClusterComponent`]s over the [`kernel`]'s event queue.
/// Derefs to [`ClusterCtx`], so all shared state and accessors (replica
/// roster, counters, reports) are read directly off the cluster value.
pub struct EventCluster {
    ctx: ClusterCtx,
}

impl std::ops::Deref for EventCluster {
    type Target = ClusterCtx;

    fn deref(&self) -> &ClusterCtx {
        &self.ctx
    }
}

impl std::ops::DerefMut for EventCluster {
    fn deref_mut(&mut self) -> &mut ClusterCtx {
        &mut self.ctx
    }
}

impl EventCluster {
    /// Build a cluster from `cfg` (replica count / router / heterogeneity /
    /// autoscale policy from `cfg.cluster`), overriding the router with
    /// `router`.
    pub fn with_router(cfg: &ExperimentConfig, router: RouterKind) -> EventCluster {
        EventCluster { ctx: ClusterCtx::new(cfg, router) }
    }

    /// Build with the router configured in `cfg.cluster.router`.
    pub fn new(cfg: &ExperimentConfig) -> EventCluster {
        EventCluster::with_router(cfg, cfg.cluster.router)
    }

    /// Drive the full arrival stream to completion: global-time-ordered
    /// interleaving of replica iterations and kernel events (arrivals,
    /// failure/recovery and domain outages, autoscaler decisions and
    /// spawn-readies), then drain. The loop itself knows nothing about any
    /// individual concern: components validate and seed the schedule in
    /// `on_start`, act at quiescent points (work stealing), and consume
    /// the events they own.
    pub fn run(&mut self, requests: Vec<Request>) -> anyhow::Result<()> {
        let mut kernel = EventQueue::new();
        let mut components: Vec<Box<dyn ClusterComponent>> = vec![
            Box::new(AutoscaleDriver::new(&self.ctx.cfg)),
            Box::new(FailureInjector::default()),
            Box::new(ArrivalSource::new(requests)),
            // the fabric observes prefill completions before the stealer
            // runs, so freshly-drained replicas are visible as steal targets
            Box::new(TransferFabric::new(&self.ctx.cfg)),
            Box::new(WorkStealer),
            Box::new(SloAdmission),
        ];
        for c in components.iter_mut() {
            c.on_start(&mut self.ctx, &mut kernel)?;
        }
        loop {
            for c in components.iter_mut() {
                c.on_quiescent(&mut self.ctx, &mut kernel)?;
            }
            let next_t = kernel.peek_at();
            match (self.ctx.earliest_busy(), next_t) {
                // a busy replica trails the next event: advance it first
                (Some((i, t)), Some(te)) if t < te => self.ctx.check_progress(i)?,
                // all busy replicas have caught up: fire the event
                (_, Some(_)) => {
                    self.ctx.kernel_events += 1;
                    let mut ev = Some(kernel.pop().expect("peeked event vanished"));
                    for c in components.iter_mut() {
                        match ev.take() {
                            Some(e) => ev = c.on_event(e, &mut self.ctx, &mut kernel)?,
                            None => break,
                        }
                    }
                    if let Some(e) = ev {
                        anyhow::bail!(
                            "no component consumed kernel event {:?} at t={}",
                            e.payload,
                            e.at
                        );
                    }
                }
                // events exhausted: drain remaining work
                (Some((i, _)), None) => self.ctx.check_progress(i)?,
                (None, None) => break,
            }
        }
        Ok(())
    }
}

/// Run one event-driven cluster experiment with an explicit router over the
/// config's seeded workload. Callers comparing routers call this repeatedly
/// with the same `cfg`: the workload (same seed) is bit-identical across
/// calls, so reports are directly comparable.
pub fn run_router_experiment(
    cfg: &ExperimentConfig,
    router: RouterKind,
) -> anyhow::Result<ClusterReport> {
    let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
    let mut cluster = EventCluster::with_router(cfg, router);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster.report(cfg.warmup_fraction))
}

/// Run the event-driven cluster with the router configured in
/// `cfg.cluster.router`.
pub fn run_event_cluster(cfg: &ExperimentConfig) -> anyhow::Result<ClusterReport> {
    run_router_experiment(cfg, cfg.cluster.router)
}

/// A multi-node serving simulation returning per-node reports: the cluster
/// serves `n_nodes`× the configured per-node load behind a least-loaded
/// router. Useful when sweeping cluster *size* at fixed per-node load
/// (the event-driven cluster does the work; [`run_router_experiment`] is
/// the entry point for fixed-load router comparisons).
pub fn run_cluster_experiment(
    cfg: &ExperimentConfig,
    n_nodes: usize,
) -> anyhow::Result<Vec<RunReport>> {
    let mut scaled = cfg.clone();
    scaled.workload.rps = cfg.workload.rps * n_nodes as f64;
    scaled.workload.n_requests = cfg.workload.n_requests * n_nodes;
    scaled.cluster.replicas = n_nodes;
    let workload = WorkloadGen::new(scaled.workload.clone(), scaled.seed).generate();
    let mut cluster = EventCluster::with_router(&scaled, RouterKind::LeastLoaded);
    cluster.prewarm();
    cluster.run(workload.requests)?;
    Ok(cluster
        .replicas
        .iter()
        .map(|r| r.coord.report(cfg.warmup_fraction))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    #[test]
    fn event_cluster_conserves_requests() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 60;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::CostAware);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 60);
        assert_eq!(cluster.rejected(), 0);
        assert_eq!(cluster.in_flight_count(), 0);
        let report = cluster.report(0.0);
        assert_eq!(report.aggregate.measured, 60);
        assert_eq!(report.per_replica.len(), 4);
        assert_eq!(report.aggregate.completed, 60);
        assert_eq!(report.aggregate.rejected, 0);
        assert!((report.aggregate.goodput() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn invalid_failure_events_are_hard_errors() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 5;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![FailureEvent { replica: 9, at: 1.0, duration: 1.0 }];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("replica 9"), "got: {err}");
    }

    #[test]
    fn overlapping_outages_merge_to_their_union() {
        // regression: a short outage nested inside a long one must not
        // resurrect the replica at the short outage's recovery point
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 200;
        cfg.workload.rps = 20.0;
        cfg.warmup_fraction = 0.0;
        cfg.history_prewarm = 0;
        cfg.cluster.replicas = 4;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 1.0, duration: 6.0 },
            FailureEvent { replica: 0, at: 2.0, duration: 1.0 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        cluster.run(workload.requests).unwrap();
        assert_eq!(cluster.completed(), 200);
        let report = cluster.report(0.0);
        assert!(
            (report.downtime[0] - 6.0).abs() < 1e-9,
            "union outage is [1,7): downtime {} != 6.0",
            report.downtime[0]
        );
    }

    #[test]
    fn all_replicas_down_is_a_hard_error_not_a_silent_drop() {
        use crate::config::FailureEvent;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 20;
        cfg.workload.rps = 10.0;
        cfg.cluster.replicas = 2;
        cfg.cluster.failures = vec![
            FailureEvent { replica: 0, at: 0.0, duration: 1e6 },
            FailureEvent { replica: 1, at: 0.0, duration: 1e6 },
        ];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::LeastLoaded);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("routable"), "got: {err}");
    }

    #[test]
    fn bad_domain_references_are_hard_errors() {
        use crate::config::{DomainFailureEvent, FailureDomain};
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 5;
        cfg.cluster.replicas = 2;
        // event names a domain that does not exist
        cfg.cluster.failure_domains =
            vec![FailureDomain { name: "rack0".to_string(), replicas: vec![0, 1] }];
        cfg.cluster.domain_failures =
            vec![DomainFailureEvent { domain: 3, at: 1.0, duration: 1.0 }];
        let workload = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let mut cluster = EventCluster::with_router(&cfg, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests.clone()).unwrap_err();
        assert!(err.to_string().contains("domain 3"), "got: {err}");
        // domain names a replica that does not exist
        let mut cfg2 = cfg.clone();
        cfg2.cluster.failure_domains =
            vec![FailureDomain { name: "rack0".to_string(), replicas: vec![0, 9] }];
        cfg2.cluster.domain_failures =
            vec![DomainFailureEvent { domain: 0, at: 1.0, duration: 1.0 }];
        let mut cluster = EventCluster::with_router(&cfg2, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests.clone()).unwrap_err();
        assert!(err.to_string().contains("replica 9"), "got: {err}");
        // domain window overlapping an individual outage on a member
        let mut cfg3 = cfg.clone();
        cfg3.cluster.failure_domains =
            vec![FailureDomain { name: "rack0".to_string(), replicas: vec![0, 1] }];
        cfg3.cluster.domain_failures =
            vec![DomainFailureEvent { domain: 0, at: 1.0, duration: 2.0 }];
        cfg3.cluster.failures =
            vec![crate::config::FailureEvent { replica: 1, at: 2.0, duration: 2.0 }];
        let mut cluster = EventCluster::with_router(&cfg3, RouterKind::RoundRobin);
        let err = cluster.run(workload.requests).unwrap_err();
        assert!(err.to_string().contains("overlaps"), "got: {err}");
    }

    #[test]
    fn cluster_experiment_completes_all() {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = PolicyKind::SageSched;
        cfg.workload.n_requests = 40;
        cfg.warmup_fraction = 0.0;
        let reports = run_cluster_experiment(&cfg, 3).unwrap();
        assert_eq!(reports.len(), 3);
        let total: usize = reports.iter().map(|r| r.measured).sum();
        assert_eq!(total, 120);
    }
}
