//! The cluster event kernel: a virtual clock's deterministic event queue.
//!
//! Every timed occurrence in the event-driven cluster — request arrivals,
//! replica outages and recoveries, domain-wide outages, autoscaler decision
//! points, provisioning completions — is a [`KernelEvent`] in one
//! [`EventQueue`]. The queue is a strict priority queue over the key
//! `(time, class, seq)`:
//!
//! * `time` — the virtual instant the event fires (never NaN; every config
//!   surface validates event times before they reach the kernel).
//! * `class` — the [`EventPayload`]'s semantic rank at equal times:
//!   capacity *arrives* (spawn-ready, recover) before capacity *leaves*
//!   (fail), autoscaler decisions observe the post-transition state, and
//!   arrivals route last so a same-instant arrival already sees the
//!   post-transition replica set.
//! * `seq` — a monotone push counter. Events with equal `(time, class)`
//!   pop in exactly the order they were pushed, which is what keeps
//!   same-seed cluster runs byte-identical: no heap/hash iteration order
//!   ever leaks into the event stream.
//!
//! Components ([`crate::cluster::components`]) never hold private timers;
//! they push events here and react when the orchestrator pops them. The
//! kernel also tracks how many events of each class are pending so
//! components can ask cheap questions like "are any arrivals still due?"
//! (the autoscaler's decision chain ends when arrivals are exhausted and
//! the cluster has drained).

use std::collections::BinaryHeap;

use crate::core::Request;
use crate::serve::MigratedRequest;

/// What a kernel event does when it fires. The payload owns any data the
/// handler needs (an arrival owns its [`Request`]), so popping an event
/// transfers ownership to the handling component.
#[derive(Clone, Debug)]
pub enum EventPayload {
    /// A provisioning delay elapsed: the replica becomes routable.
    SpawnReady { replica: usize },
    /// A configured single-replica outage ends.
    Recover { replica: usize },
    /// A configured failure-domain outage ends (all members recover).
    DomainRecover { domain: usize },
    /// A configured single-replica outage begins.
    Fail { replica: usize },
    /// A configured failure-domain outage begins: every member of the
    /// domain fails at this one instant.
    DomainFail { domain: usize },
    /// An autoscaler decision point.
    Decision,
    /// A KV transfer over the disaggregation fabric completes: the
    /// prefilled request (generated prefix, first-token timestamp) lands
    /// in the decode pool. Owns its [`MigratedRequest`] like an arrival
    /// owns its [`Request`].
    TransferDone(MigratedRequest),
    /// A request arrives at the cluster front door.
    Arrival(Request),
}

impl EventPayload {
    /// Tie-break class at equal times (smaller fires first): capacity
    /// arrives before capacity leaves, decisions observe the
    /// post-transition state, completed transfers deliver already-admitted
    /// work before fresh arrivals route, arrivals route over the
    /// post-transition set.
    pub fn class(&self) -> u8 {
        match self {
            EventPayload::SpawnReady { .. } => 0,
            EventPayload::Recover { .. } | EventPayload::DomainRecover { .. } => 1,
            EventPayload::Fail { .. } | EventPayload::DomainFail { .. } => 2,
            EventPayload::Decision => 3,
            EventPayload::TransferDone(_) => 4,
            EventPayload::Arrival(_) => 5,
        }
    }
}

/// Number of distinct [`EventPayload::class`] values (pending-count slots).
const N_CLASSES: usize = 6;

/// Class index of [`EventPayload::Decision`] events.
const CLASS_DECISION: usize = 3;

/// Class index of [`EventPayload::TransferDone`] events.
const CLASS_TRANSFER: usize = 4;

/// Class index of [`EventPayload::Arrival`] events.
const CLASS_ARRIVAL: usize = 5;

/// One scheduled event: fire time, tie-break class, push sequence number,
/// and the payload handed to the handling component.
#[derive(Clone, Debug)]
pub struct KernelEvent {
    /// Virtual fire time (seconds).
    pub at: f64,
    /// Tie-break class (see [`EventPayload::class`]).
    pub class: u8,
    /// Push sequence number (monotone; last key of the priority order).
    pub seq: u64,
    pub payload: EventPayload,
}

impl KernelEvent {
    fn key(&self) -> (f64, u8, u64) {
        (self.at, self.class, self.seq)
    }
}

/// Min-heap entry wrapper: orders by `(at, class, seq)` ascending. `at` is
/// compared with `total_cmp` — identical to `partial_cmp` for the non-NaN
/// times the kernel accepts, and total so `Ord` is sound.
struct Entry(KernelEvent);

impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.0.seq == other.0.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Entry) -> std::cmp::Ordering {
        let (a, b) = (self.0.key(), other.0.key());
        // reversed: BinaryHeap is a max-heap, the kernel wants the
        // smallest key on top
        b.0.total_cmp(&a.0)
            .then(b.1.cmp(&a.1))
            .then(b.2.cmp(&a.2))
    }
}

/// Deterministic event queue for the cluster's virtual clock.
///
/// Ties at equal `(time, class)` break by push order, so pushing events in
/// a deterministic order is sufficient for a byte-identical event stream —
/// the queue never reorders equal-key events.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
    pending: [usize; N_CLASSES],
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `payload` at virtual time `at`. Panics on a NaN time —
    /// every config surface rejects NaN before events are built, so one
    /// reaching the kernel is an internal error, not bad user input.
    pub fn push(&mut self, at: f64, payload: EventPayload) {
        assert!(!at.is_nan(), "NaN event time reached the kernel");
        let class = payload.class();
        self.pending[class as usize] += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry(KernelEvent { at, class, seq, payload }));
    }

    /// Remove and return the next event (smallest `(time, class, seq)`).
    pub fn pop(&mut self) -> Option<KernelEvent> {
        let ev = self.heap.pop().map(|e| e.0)?;
        self.pending[ev.class as usize] -= 1;
        Some(ev)
    }

    /// Fire time of the next event without removing it.
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Pending arrival events (requests not yet routed).
    pub fn pending_arrivals(&self) -> usize {
        self.pending[CLASS_ARRIVAL]
    }

    /// Pending autoscaler decision points.
    pub fn pending_decisions(&self) -> usize {
        self.pending[CLASS_DECISION]
    }

    /// Pending KV-fabric transfer completions (requests in flight between
    /// the prefill and decode pools — live work the cluster still owes).
    pub fn pending_transfers(&self) -> usize {
        self.pending[CLASS_TRANSFER]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventPayload::Decision);
        q.push(1.0, EventPayload::Decision);
        q.push(2.0, EventPayload::Decision);
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn equal_time_breaks_by_class_then_push_order() {
        let mut q = EventQueue::new();
        q.push(1.0, EventPayload::Decision);
        q.push(1.0, EventPayload::Fail { replica: 9 });
        q.push(1.0, EventPayload::SpawnReady { replica: 2 });
        q.push(1.0, EventPayload::Fail { replica: 3 });
        q.push(1.0, EventPayload::Recover { replica: 1 });
        // class order: spawn-ready(0) < recover(1) < fail(2) < decision(3);
        // the two fails keep their push order (9 before 3)
        let order: Vec<u8> = std::iter::from_fn(|| q.pop().map(|e| e.class)).collect();
        assert_eq!(order, vec![0, 1, 2, 2, 3]);
    }

    #[test]
    fn pending_counts_track_pushes_and_pops() {
        let mut q = EventQueue::new();
        assert_eq!(q.pending_decisions(), 0);
        q.push(1.0, EventPayload::Decision);
        q.push(2.0, EventPayload::Decision);
        assert_eq!(q.pending_decisions(), 2);
        q.pop();
        assert_eq!(q.pending_decisions(), 1);
        assert_eq!(q.pending_arrivals(), 0);
    }

    #[test]
    #[should_panic(expected = "NaN event time")]
    fn nan_times_are_rejected() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, EventPayload::Decision);
    }
}
