//! Cluster front-door routing: replica snapshots and the router policies.
//!
//! A [`Router`] sees a slice of [`ReplicaView`]s — one per *routable*
//! replica, snapshotted at routing time — and returns a **position in that
//! slice** (never a replica id: the slice is sparse once any replica is
//! down, provisioning, or draining; the dispatcher maps positions back
//! through [`ReplicaView::id`]). Implementations must be deterministic
//! given the same request/view sequence so cluster runs are exactly
//! reproducible.
//!
//! Every scored router resolves ties with the single shared rule in
//! [`argmin`]: the lowest position wins. Five base policies
//! (round-robin / least-loaded / least-kv / cost-aware / quantile-cost)
//! plus the [`ClassAwareRouter`] wrapper that gives tight SLO tiers
//! tail-risk-averse placement over KV-headroom replicas.
//!
//! Every router additionally declares a [`FastPath`], letting the
//! dispatcher answer it from the incremental indexes in
//! [`crate::cluster::index`] instead of rescanning every view. The fast
//! path must pick the *same replica* the rescan would — the indexes
//! reproduce [`argmin`]'s lowest-position tie-break exactly. Replica-keyed
//! scores map to a single index heap; per-request scores get
//! request-conditional treatment: [`CacheAffinityRouter`] declares
//! [`FastPath::Affinity`] (base-score shortlist + warm-site probes under a
//! dominance bound, rescan when the bound fails) and [`ClassAwareRouter`]
//! declares [`FastPath::TightQuantile`] for Interactive traffic (the score
//! is replica-keyed once the class is known). [`FastPath::Rescan`] remains
//! the always-correct fallback.

use crate::config::RouterKind;
use crate::core::Request;
use crate::slo::SloClass;
use crate::util::stats::normal_quantile_clamped;

/// Quantile the class-aware wrapper places Interactive traffic by. Shared
/// with the index layer so the tight-quantile heaps are keyed with the
/// same z-score the router scores with.
pub const TIGHT_QUANTILE: f64 = 0.95;

/// KV-occupancy ceiling for Interactive-eligible replicas, shared with
/// the index layer's headroom-filtered heap.
pub const TIGHT_KV_HEADROOM: f64 = 0.85;

/// Snapshot of one replica's state at routing time.
#[derive(Clone, Debug)]
pub struct ReplicaView {
    /// Replica index.
    pub id: usize,
    /// Live requests (queued + running + preempted).
    pub live: usize,
    /// Allocated KV blocks.
    pub kv_used_blocks: usize,
    /// Total KV blocks.
    pub kv_total_blocks: usize,
    /// Replica-local virtual clock (seconds).
    pub now: f64,
    /// Speed multiplier of this replica (1.0 = base profile).
    pub speed: f64,
    /// Max decode batch of this replica.
    pub max_batch: usize,
    /// Sum of predicted E[total cost] of requests routed here that have not
    /// completed yet (maintained by the cluster from the shared predictor).
    pub predicted_backlog: f64,
    /// Sum of predicted Var[total cost] of the same requests — the second
    /// moment the distribution-aware router and autoscaler consume (sums of
    /// independent request costs: means and variances both add).
    pub predicted_backlog_var: f64,
    /// Prefix tokens of the *request being routed* already resident in this
    /// replica's KV cache (from `KvManager::cached_prefix_tokens`). Zero
    /// for requests without a prefix chain and for views built outside the
    /// dispatch path (autoscaler sizing, work stealing's generic views).
    pub warm_prefix_tokens: u32,
    /// Predicted service-cost saving (cost-model units) if this request
    /// lands on this replica and reuses its warm prefix — the difference
    /// between the cold predicted cost and the cost with the warm prefix
    /// tokens removed from the prefill term. Zero when nothing is warm.
    pub warm_cost_saving: f64,
}

impl ReplicaView {
    /// KV occupancy fraction in [0, 1]. A replica with zero KV capacity
    /// (possible under heterogeneous `kv_capacities` configs) reads as
    /// fully unoccupied rather than `0/0 = NaN` — a NaN here would poison
    /// every router comparison that touches occupancy, silently skewing
    /// placement toward slot 0.
    pub fn kv_occupancy(&self) -> f64 {
        if self.kv_total_blocks == 0 {
            0.0
        } else {
            self.kv_used_blocks as f64 / self.kv_total_blocks as f64
        }
    }
}

/// Position of the smallest score; ties break to the **lowest position** —
/// the one tie-break rule shared by every scored router (and by
/// [`route_least_loaded`]). A NaN score is never selected (it loses every
/// comparison), but callers are expected to keep NaN out of their scores.
/// Panics on an empty score list: routers are never offered an empty view
/// set.
pub fn argmin<S: PartialOrd>(scores: impl IntoIterator<Item = S>) -> usize {
    let mut it = scores.into_iter();
    let mut best_score = it.next().expect("argmin over an empty score list");
    let mut best = 0usize;
    for (i, s) in it.enumerate() {
        if s < best_score {
            best_score = s;
            best = i + 1;
        }
    }
    best
}

/// Least-loaded routing decision across per-node live counts (exposed for
/// tests and the cluster example). Same implementation and tie-break as
/// [`LeastLoadedRouter`]: both delegate to [`argmin`].
pub fn route_least_loaded(loads: &[usize]) -> usize {
    argmin(loads.iter().copied())
}

/// How the dispatcher may answer a routing decision from the incremental
/// indexes instead of a full view rescan. Declared per router (and per
/// request, for wrappers that split traffic by class).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FastPath {
    /// No index applies: build the views and call [`Router::route`].
    Rescan,
    /// Next roster slot in cursor order ([`RoundRobinRouter`]).
    RoundRobin,
    /// Minimum live count ([`LeastLoadedRouter`]).
    LeastLoaded,
    /// Minimum KV occupancy ([`LeastKvRouter`]).
    LeastKv,
    /// Minimum backlog / speed ([`CostAwareRouter`]).
    CostAware,
    /// Minimum backlog quantile / speed at z-score `z`
    /// ([`QuantileCostRouter`]); the index only applies when `z` matches
    /// the z the index was keyed with.
    QuantileCost { z: f64 },
    /// Cache-affinity placement ([`CacheAffinityRouter`]): resolved from
    /// the cost-metric heap via a bounded shortlist plus the known warm
    /// sites for the request's prefix, accepted only when a dominance
    /// bound proves no other replica can win; otherwise the dispatcher
    /// falls back to the rescan.
    Affinity,
    /// Class-aware Interactive placement ([`ClassAwareRouter`]): minimum
    /// tight-quantile backlog / speed over KV-headroom replicas (the full
    /// scope when none has headroom). Applies only when `z` matches the z
    /// the index's tight heaps were keyed with.
    TightQuantile { z: f64 },
}

/// A cluster front-door routing policy. Implementations must be
/// deterministic given the same request/view sequence so cluster runs are
/// exactly reproducible.
pub trait Router: Send {
    fn kind(&self) -> RouterKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Which incremental index (if any) answers this request's routing
    /// decision identically to [`Router::route`] over the full view set.
    /// Defaults to [`FastPath::Rescan`] (always correct).
    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::Rescan
    }

    /// Advance any per-dispatch router state (the round-robin cursor) as a
    /// fast-path dispatch would, returning the chosen slot in a roster of
    /// `len` routable replicas. Must share state with [`Router::route`] so
    /// fast-path and rescan dispatches interleave without skew. No-op slot
    /// 0 for stateless routers.
    fn advance_cursor(&mut self, _len: usize) -> usize {
        0
    }

    /// Pick a *position in the `replicas` slice* for `req` (the caller maps
    /// it back to a replica through [`ReplicaView::id`]). The slice holds
    /// only routable — alive — replicas, so positions and replica ids
    /// diverge once any replica has failed; returning `ReplicaView::id`
    /// here is a misroute. `predicted_cost` is the shared predictor's
    /// E[total service cost] for this request (cost-model units);
    /// `replicas` is never empty. Out-of-range returns are a hard dispatch
    /// error, never clamped.
    fn route(&mut self, req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize;
}

/// Cycle through replicas in submission order.
#[derive(Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::RoundRobin
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::RoundRobin
    }

    fn advance_cursor(&mut self, len: usize) -> usize {
        // identical arithmetic to route(): one shared cursor, so fast-path
        // and rescan dispatches interleave without skewing the cycle
        let i = self.next % len;
        self.next = self.next.wrapping_add(1);
        i
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        let i = self.next % replicas.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Fewest live requests; ties break to the lowest position.
#[derive(Default)]
pub struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastLoaded
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::LeastLoaded
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        argmin(replicas.iter().map(|r| r.live))
    }
}

/// Lowest KV-block occupancy fraction; ties break to the lowest position.
#[derive(Default)]
pub struct LeastKvRouter;

impl Router for LeastKvRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::LeastKv
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::LeastKv
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        argmin(replicas.iter().map(|r| r.kv_occupancy()))
    }
}

/// Smallest predicted outstanding cost normalized by replica speed — the
/// uncertainty-aware router: it routes by E[remaining work], not by request
/// *count*, so a replica stuck with a few predicted-long generations stops
/// attracting traffic even while its live count is low.
#[derive(Default)]
pub struct CostAwareRouter;

impl Router for CostAwareRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::CostAware
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::CostAware
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        argmin(replicas.iter().map(|r| r.predicted_backlog / r.speed.max(1e-9)))
    }
}

/// The distribution-aware router: smallest *quantile* of the predicted
/// outstanding-cost distribution, normalized by replica speed. Per replica
/// the outstanding cost is a sum of independent per-request cost
/// distributions, so its quantile is taken under the normal approximation
/// `Q_q ≈ μ + z_q·σ` over the tracked (mean, variance) sums. Against
/// [`CostAwareRouter`] this penalizes replicas whose backlog is
/// heavy-tailed: equal means, unequal tails — the quantile router spreads
/// the tail risk, the mean router cannot see it.
pub struct QuantileCostRouter {
    /// z-score of the configured quantile.
    z: f64,
}

impl QuantileCostRouter {
    pub fn new(quantile: f64) -> QuantileCostRouter {
        QuantileCostRouter { z: normal_quantile_clamped(quantile) }
    }
}

impl Router for QuantileCostRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::QuantileCost
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::QuantileCost { z: self.z }
    }

    fn route(&mut self, _req: &Request, _cost: f64, replicas: &[ReplicaView]) -> usize {
        argmin(replicas.iter().map(|r| {
            let q = r.predicted_backlog + self.z * r.predicted_backlog_var.max(0.0).sqrt();
            // negative q (possible at sub-median quantiles) still orders
            // replicas correctly — clamping it would collapse the ordering
            // and skew all ties to slot 0
            q / r.speed.max(1e-9)
        }))
    }
}

/// Session-sticky routing that trades cache affinity against load: the
/// effective cost of placing the request on replica `r` is its outstanding
/// predicted backlog plus this request's predicted cost *minus* what the
/// replica's warm prefix state saves, all normalized by speed:
///
/// ```text
/// score(r) = (backlog(r) + predicted_cost − warm_cost_saving(r)) / speed(r)
/// ```
///
/// A replica holding a session's shared prefix therefore keeps attracting
/// that session's turns — until its backlog exceeds a colder replica's by
/// more than the prefill work the warm prefix saves, at which point the
/// router willingly pays the cold prefill to rebalance. Requests with no
/// warm state anywhere degrade to exactly [`CostAwareRouter`] + the
/// request's own cost (an argmin-invariant constant shift only when speeds
/// are equal; under heterogeneous speeds it also steers big requests to
/// fast replicas).
#[derive(Default)]
pub struct CacheAffinityRouter;

impl Router for CacheAffinityRouter {
    fn kind(&self) -> RouterKind {
        RouterKind::CacheAffinity
    }

    fn fast_path(&self, _req: &Request) -> FastPath {
        FastPath::Affinity
    }

    fn route(&mut self, _req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize {
        argmin(replicas.iter().map(|r| {
            // saving is capped by the request's own cost: stale probes can
            // not make a placement look better than free
            let saving = r.warm_cost_saving.clamp(0.0, predicted_cost.max(0.0));
            (r.predicted_backlog + predicted_cost - saving) / r.speed.max(1e-9)
        }))
    }
}

/// Build a router from its kind; `quantile` parameterizes
/// [`RouterKind::QuantileCost`] (ignored by the others).
pub fn make_router(kind: RouterKind, quantile: f64) -> Box<dyn Router> {
    match kind {
        RouterKind::RoundRobin => Box::new(RoundRobinRouter::default()),
        RouterKind::LeastLoaded => Box::new(LeastLoadedRouter),
        RouterKind::LeastKv => Box::new(LeastKvRouter),
        RouterKind::CostAware => Box::new(CostAwareRouter),
        RouterKind::QuantileCost => Box::new(QuantileCostRouter::new(quantile)),
        RouterKind::CacheAffinity => Box::new(CacheAffinityRouter),
    }
}

/// SLO-class-aware routing wrapper: tight tiers get headroom, loose tiers
/// keep the configured base router.
///
/// * `Interactive` requests are routed over the subset of replicas with KV
///   headroom (occupancy at most `kv_headroom`; the full set when none
///   qualifies), picked by the smallest *high quantile* of the outstanding
///   predicted-cost distribution normalized by speed — the
///   tail-risk-averse placement a tight TTFT budget wants. The per-tier
///   quantile is how the distribution-aware router "provisions headroom"
///   for the tier that cannot absorb a burst.
/// * `Standard` and `Batch` requests are delegated to the wrapped router
///   unchanged.
///
/// Composes with every [`RouterKind`]; it reports the inner router's kind
/// and name so A/B labels stay comparable.
pub struct ClassAwareRouter {
    inner: Box<dyn Router>,
    /// z-score of the Interactive placement quantile.
    z_tight: f64,
    /// KV-occupancy ceiling for Interactive-eligible replicas.
    kv_headroom: f64,
}

impl ClassAwareRouter {
    pub fn new(inner: Box<dyn Router>) -> ClassAwareRouter {
        ClassAwareRouter {
            inner,
            z_tight: normal_quantile_clamped(TIGHT_QUANTILE),
            kv_headroom: TIGHT_KV_HEADROOM,
        }
    }
}

impl Router for ClassAwareRouter {
    fn kind(&self) -> RouterKind {
        self.inner.kind()
    }

    fn fast_path(&self, req: &Request) -> FastPath {
        // Interactive placement filters by KV headroom and scores on the
        // tight quantile — replica-keyed once the class is known, so the
        // index layer's tight heaps answer it
        if req.slo == SloClass::Interactive {
            FastPath::TightQuantile { z: self.z_tight }
        } else {
            self.inner.fast_path(req)
        }
    }

    fn advance_cursor(&mut self, len: usize) -> usize {
        self.inner.advance_cursor(len)
    }

    fn route(&mut self, req: &Request, predicted_cost: f64, replicas: &[ReplicaView]) -> usize {
        if req.slo != SloClass::Interactive {
            return self.inner.route(req, predicted_cost, replicas);
        }
        let eligible: Vec<usize> = (0..replicas.len())
            .filter(|&slot| replicas[slot].kv_occupancy() <= self.kv_headroom)
            .collect();
        let pool: Vec<usize> = if eligible.is_empty() {
            (0..replicas.len()).collect()
        } else {
            eligible
        };
        // pool is ascending, so argmin's lowest-position tie-break is the
        // lowest-slot tie-break here too
        let best = argmin(pool.iter().map(|&slot| {
            let r = &replicas[slot];
            let q = r.predicted_backlog + self.z_tight * r.predicted_backlog_var.max(0.0).sqrt();
            q / r.speed.max(1e-9)
        }));
        pool[best]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadGen;

    fn view(id: usize, live: usize, used: usize, backlog: f64, speed: f64) -> ReplicaView {
        ReplicaView {
            id,
            live,
            kv_used_blocks: used,
            kv_total_blocks: 100,
            now: 0.0,
            speed,
            max_batch: 8,
            predicted_backlog: backlog,
            predicted_backlog_var: 0.0,
            warm_prefix_tokens: 0,
            warm_cost_saving: 0.0,
        }
    }

    fn any_req() -> Request {
        let mut cfg = crate::config::WorkloadConfig::default();
        cfg.n_requests = 1;
        WorkloadGen::new(cfg, 1).generate().requests.pop().unwrap()
    }

    #[test]
    fn route_picks_min() {
        assert_eq!(route_least_loaded(&[3, 1, 2]), 1);
        assert_eq!(route_least_loaded(&[0]), 0);
    }

    #[test]
    fn argmin_ties_break_to_the_lowest_position() {
        assert_eq!(argmin([2.0, 1.0, 1.0, 3.0]), 1);
        assert_eq!(argmin([5usize, 5, 5]), 0);
        assert_eq!(argmin([1.0]), 0);
    }

    #[test]
    fn zero_kv_capacity_reads_as_unoccupied_not_nan() {
        // heterogeneous configs can set a zero KV capacity; 0/0 must not
        // become NaN (NaN loses every router comparison, silently skewing
        // all placement toward slot 0)
        let mut v = view(0, 3, 0, 100.0, 1.0);
        v.kv_total_blocks = 0;
        assert_eq!(v.kv_occupancy(), 0.0);
        // and the least-kv router prefers it over a half-full replica
        let views = vec![view(1, 3, 50, 100.0, 1.0), v];
        let r = any_req();
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 1);
    }

    #[test]
    fn routers_pick_expected_replicas() {
        let views = vec![
            view(0, 4, 80, 500.0, 1.0),
            view(1, 2, 90, 100.0, 1.0),
            view(2, 3, 10, 400.0, 0.1),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        // cost-aware: 500/1, 100/1, 400/0.1=4000 -> replica 1
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        assert_eq!(rr.route(&r, 1.0, &views), 0);
        assert_eq!(rr.route(&r, 1.0, &views), 1);
        assert_eq!(rr.route(&r, 1.0, &views), 2);
        assert_eq!(rr.route(&r, 1.0, &views), 0);
    }

    #[test]
    fn routers_return_positions_not_ids_over_sparse_views() {
        // the surviving view set after failures: ids 3/7/9, positions 0/1/2.
        // returning `ReplicaView::id` here (the old bug) would be out of
        // range or a misroute.
        let views = vec![
            view(3, 4, 80, 500.0, 1.0),
            view(7, 2, 90, 100.0, 1.0),
            view(9, 3, 10, 400.0, 1.0),
        ];
        let r = any_req();
        assert_eq!(LeastLoadedRouter.route(&r, 1.0, &views), 1);
        assert_eq!(LeastKvRouter.route(&r, 1.0, &views), 2);
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 1);
        let mut rr = RoundRobinRouter::default();
        for expect in [0usize, 1, 2, 0] {
            assert_eq!(rr.route(&r, 1.0, &views), expect);
        }
    }

    #[test]
    fn make_router_builds_all_kinds() {
        for kind in RouterKind::ALL {
            assert_eq!(make_router(kind, 0.9).kind(), kind);
        }
    }

    #[test]
    fn quantile_router_avoids_heavy_tailed_backlogs() {
        // equal mean backlogs, very different tails: the mean-based router
        // ties to the lowest index, the quantile router steers to the
        // narrow one
        let mut views = vec![view(0, 3, 50, 400.0, 1.0), view(1, 3, 50, 400.0, 1.0)];
        views[0].predicted_backlog_var = 250_000.0; // sd 500
        views[1].predicted_backlog_var = 100.0; // sd 10
        let r = any_req();
        assert_eq!(CostAwareRouter.route(&r, 1.0, &views), 0);
        let mut q = QuantileCostRouter::new(0.9);
        assert_eq!(q.route(&r, 1.0, &views), 1);
        // at q=0.5 (z=0) it degrades to exactly the mean router's choice
        let mut q50 = QuantileCostRouter::new(0.5);
        assert_eq!(q50.route(&r, 1.0, &views), 0);
    }

    #[test]
    fn cache_affinity_sticks_to_warm_replicas_until_load_outweighs_saving() {
        let r = any_req();
        let mut ca = CacheAffinityRouter;
        // replica 1 holds the session's warm prefix (saving 30); backlogs
        // are close, so stickiness wins: 100+50 = 150 vs 120+50-30 = 140
        let mut views = vec![view(0, 2, 10, 100.0, 1.0), view(1, 4, 40, 120.0, 1.0)];
        views[1].warm_prefix_tokens = 256;
        views[1].warm_cost_saving = 30.0;
        assert_eq!(ca.route(&r, 50.0, &views), 1);
        // once the warm replica's backlog grows past the saving, the router
        // pays the cold prefill: 100+50 = 150 < 200+50-30 = 220
        views[1].predicted_backlog = 200.0;
        assert_eq!(ca.route(&r, 50.0, &views), 0);
        // with no warm state anywhere it matches the cost-aware choice
        views[1].warm_cost_saving = 0.0;
        assert_eq!(ca.route(&r, 50.0, &views), CostAwareRouter.route(&r, 50.0, &views));
    }

    #[test]
    fn cache_affinity_caps_saving_at_the_request_cost() {
        // a stale/overlarge saving must not make a loaded replica look
        // better than free work would: cap at predicted_cost
        let r = any_req();
        let mut ca = CacheAffinityRouter;
        let mut views = vec![view(0, 1, 10, 100.0, 1.0), view(1, 8, 90, 140.0, 1.0)];
        views[1].warm_cost_saving = 1e9;
        // capped: 100+50 = 150 vs 140+50-50 = 140 -> still replica 1, but
        // by the capped margin, not the raw 1e9
        assert_eq!(ca.route(&r, 50.0, &views), 1);
        views[1].predicted_backlog = 200.0;
        // 100+50 = 150 < 200+50-50 = 200 -> rebalances despite the huge
        // claimed saving
        assert_eq!(ca.route(&r, 50.0, &views), 0);
    }

    #[test]
    fn round_robin_cursor_is_shared_between_route_and_advance_cursor() {
        let views = vec![
            view(0, 0, 0, 0.0, 1.0),
            view(1, 0, 0, 0.0, 1.0),
            view(2, 0, 0, 0.0, 1.0),
        ];
        let r = any_req();
        let mut rr = RoundRobinRouter::default();
        // mixed fast-path/rescan dispatches must walk one cycle together
        assert_eq!(rr.route(&r, 1.0, &views), 0);
        assert_eq!(rr.advance_cursor(views.len()), 1);
        assert_eq!(rr.route(&r, 1.0, &views), 2);
        assert_eq!(rr.advance_cursor(views.len()), 0);
    }

    #[test]
    fn fast_path_declarations_match_router_semantics() {
        let req = any_req();
        assert_eq!(
            RoundRobinRouter::default().fast_path(&req),
            FastPath::RoundRobin
        );
        assert_eq!(LeastLoadedRouter.fast_path(&req), FastPath::LeastLoaded);
        assert_eq!(LeastKvRouter.fast_path(&req), FastPath::LeastKv);
        assert_eq!(CostAwareRouter.fast_path(&req), FastPath::CostAware);
        let q = QuantileCostRouter::new(0.9);
        assert_eq!(
            q.fast_path(&req),
            FastPath::QuantileCost { z: normal_quantile_clamped(0.9) }
        );
        // per-request warmth resolves through the shortlist fast path
        assert_eq!(CacheAffinityRouter.fast_path(&req), FastPath::Affinity);
        // the class-aware wrapper fast-paths Interactive onto the tight
        // heaps and delegates everything else to the inner router
        let wrapped = ClassAwareRouter::new(Box::new(CostAwareRouter));
        let mut interactive = any_req();
        interactive.slo = SloClass::Interactive;
        assert_eq!(
            wrapped.fast_path(&interactive),
            FastPath::TightQuantile { z: normal_quantile_clamped(TIGHT_QUANTILE) }
        );
        let mut batch = any_req();
        batch.slo = SloClass::Batch;
        assert_eq!(wrapped.fast_path(&batch), FastPath::CostAware);
    }

    #[test]
    fn class_aware_router_gives_interactive_headroom() {
        let mut r = ClassAwareRouter::new(Box::new(RoundRobinRouter::default()));
        // replica 0: 95% KV occupancy (no headroom), small backlog;
        // replica 1: plenty of headroom, larger backlog
        let mut views = vec![view(0, 3, 95, 100.0, 1.0), view(1, 3, 10, 400.0, 1.0)];
        let mut req = any_req();
        req.slo = SloClass::Interactive;
        // interactive avoids the KV-saturated replica even though its
        // backlog is smaller
        assert_eq!(r.route(&req, 1.0, &views), 1);
        // batch delegates to the inner round-robin (first call -> slot 0)
        req.slo = SloClass::Batch;
        assert_eq!(r.route(&req, 1.0, &views), 0);
        // no replica has KV headroom: fall back to the full set, picked on
        // the p95 quantile of outstanding cost (tail-averse placement)
        views[1].kv_used_blocks = 96;
        views[0].predicted_backlog_var = 250_000.0; // sd 500
        views[1].predicted_backlog_var = 0.0;
        req.slo = SloClass::Interactive;
        // q0 = 100 + 1.645*500 ~= 922 > q1 = 400
        assert_eq!(r.route(&req, 1.0, &views), 1);
        // wrapper is label-transparent for A/B reporting
        assert_eq!(r.kind(), RouterKind::RoundRobin);
    }
}
