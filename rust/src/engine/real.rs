//! Real execution engine: serves actual tokens from the AOT-compiled tiny
//! LM through PJRT (no python anywhere on this path).
//!
//! Fixed lane batch (`decode_batch` from the artifacts, default 8): each
//! admitted request owns a lane; idle lanes run PAD tokens at position 0
//! whose outputs are discarded. Sampling (temperature + EOS detection)
//! happens here in rust, so output lengths are *genuinely stochastic* —
//! the demand-uncertainty property the paper is built around, reproduced
//! with a real model rather than injected noise.
//!
//! Recompute-preemption keeps each request's generated-token history and
//! rebuilds its KV on resume by re-prefilling the prompt and replaying the
//! generated prefix through decode steps (teacher forcing), mirroring
//! vLLM's recompute mode.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::core::{Request, RequestId};
use crate::runtime::Runtime;
use crate::tokenizer;
use crate::util::rng::Rng;

use super::{Engine, EngineStats, LaneState, PrefillResult};

struct LaneInfo {
    #[allow(dead_code)] // kept for debugging / lane-dump introspection
    id: RequestId,
    /// prompt tokens (post-truncation)
    prompt_len: u32,
    /// sampled output tokens so far (first sampled at prefill)
    output: Vec<u32>,
    finished: bool,
}

/// PJRT-backed engine over the compiled artifacts.
pub struct RealEngine {
    rt: Runtime,
    rng: Rng,
    pub temperature: f32,
    /// hard cap on output tokens (bounded by max_seq - prompt)
    pub max_output: u32,
    /// lane slot -> occupant
    lanes: Vec<Option<LaneInfo>>,
    /// request -> lane slot
    by_id: HashMap<RequestId, usize>,
    /// histories kept across recompute-preemption: prompt + sampled output
    parked: HashMap<RequestId, (u32, Vec<u32>)>,
    /// flattened [L, B, H, S, Dh] caches. The authoritative copy lives as
    /// XLA literals chained between decode steps (§Perf: saves ~3 large
    /// host copies per step); the host vectors are synchronized lazily and
    /// only touched on prefill-install / lane-zeroing.
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
    /// device-format caches (valid when `lit_fresh`)
    cache_lit: Option<(xla::Literal, xla::Literal)>,
    /// true when `cache_lit` is the authoritative copy
    lit_fresh: bool,
    // stats
    busy_decode: f64,
    busy_prefill: f64,
    decode_steps: u64,
    decode_tokens: u64,
}

impl RealEngine {
    pub fn new(rt: Runtime, seed: u64) -> RealEngine {
        let ce = rt.meta().cache_elems();
        let b = rt.meta().decode_batch;
        RealEngine {
            rt,
            rng: Rng::new(seed ^ 0x7ea1),
            temperature: 0.6, // the paper's default for all inferences
            max_output: 0,    // 0 = derive from capacity
            lanes: (0..b).map(|_| None).collect(),
            by_id: HashMap::new(),
            parked: HashMap::new(),
            k_cache: vec![0.0; ce],
            v_cache: vec![0.0; ce],
            cache_lit: None,
            lit_fresh: false,
            busy_decode: 0.0,
            busy_prefill: 0.0,
            decode_steps: 0,
            decode_tokens: 0,
        }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn free_lane(&self) -> Option<usize> {
        self.lanes.iter().position(Option::is_none)
    }

    fn effective_max_output(&self, prompt_len: u32) -> u32 {
        let cap = (self.rt.meta().max_seq as u32).saturating_sub(prompt_len + 1);
        if self.max_output == 0 {
            cap
        } else {
            self.max_output.min(cap)
        }
    }

    /// Pull the authoritative cache back to the host vectors (lazy).
    fn sync_host(&mut self) {
        if self.lit_fresh {
            if let Some((kl, vl)) = &self.cache_lit {
                kl.copy_raw_to(&mut self.k_cache).expect("cache sync");
                vl.copy_raw_to(&mut self.v_cache).expect("cache sync");
            }
            self.lit_fresh = false;
        }
    }

    /// Copy one lane's per-layer slices from a prefill output into the big
    /// caches.
    fn install_prefill_kv(&mut self, lane: usize, k: &[f32], v: &[f32]) {
        self.sync_host();
        self.cache_lit = None;
        let m = self.rt.meta();
        let lane_elems = m.lane_elems();
        let layer_stride = m.decode_batch * lane_elems;
        for l in 0..m.n_layers {
            let src = l * lane_elems..(l + 1) * lane_elems;
            let dst = l * layer_stride + lane * lane_elems;
            self.k_cache[dst..dst + lane_elems].copy_from_slice(&k[src.clone()]);
            self.v_cache[dst..dst + lane_elems].copy_from_slice(&v[src]);
        }
    }

    fn zero_lane_kv(&mut self, lane: usize) {
        self.sync_host();
        self.cache_lit = None;
        let m = self.rt.meta();
        let lane_elems = m.lane_elems();
        let layer_stride = m.decode_batch * lane_elems;
        for l in 0..m.n_layers {
            let dst = l * layer_stride + lane * lane_elems;
            self.k_cache[dst..dst + lane_elems].fill(0.0);
            self.v_cache[dst..dst + lane_elems].fill(0.0);
        }
    }

    /// Temperature sampling over a logits row.
    fn sample(&mut self, logits: &[f32]) -> u32 {
        let t = self.temperature.max(1e-3);
        let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&z| (((z - mx) / t) as f64).exp())
            .collect();
        self.rng.categorical(&weights) as u32
    }

    fn prompt_tokens(&self, req: &Request) -> Vec<u32> {
        tokenizer::encode_truncated(&req.prompt, self.rt.meta().prefill_len)
    }

    /// Prefill a prompt into a lane; returns the first sampled token.
    fn do_prefill(&mut self, lane: usize, tokens: &[u32]) -> Result<u32> {
        let out = self.rt.run_prefill(tokens)?;
        self.install_prefill_kv(lane, &out.k, &out.v);
        Ok(self.sample(&out.logits))
    }

    /// One batched decode over the current lanes, teacher-forcing the given
    /// per-lane input tokens. Returns per-lane logits rows. Caches chain
    /// between calls as XLA literals (no host round-trip on this path).
    fn raw_decode(&mut self, toks: &[i32], pos: &[i32]) -> Result<Vec<Vec<f32>>> {
        if self.cache_lit.is_none() {
            self.cache_lit = Some((
                self.rt.cache_literal(&self.k_cache)?,
                self.rt.cache_literal(&self.v_cache)?,
            ));
            // host copy is authoritative until the first step completes
        }
        let (kl, vl) = self.cache_lit.as_ref().unwrap();
        let out = self.rt.run_decode_lit(toks, pos, kl, vl)?;
        self.cache_lit = Some((out.k, out.v));
        self.lit_fresh = true;
        let v = self.rt.meta().vocab;
        Ok(out.logits.chunks(v).map(|c| c.to_vec()).collect())
    }

    /// Build the idle-lane filler inputs, overriding active entries.
    fn lane_inputs(&self, overrides: &[(usize, i32, i32)]) -> (Vec<i32>, Vec<i32>) {
        let b = self.rt.meta().decode_batch;
        let pad = self.rt.meta().pad_id as i32;
        let mut toks = vec![pad; b];
        let mut pos = vec![0i32; b];
        for &(lane, t, p) in overrides {
            toks[lane] = t;
            pos[lane] = p;
        }
        (toks, pos)
    }

    /// Replay a parked request's sampled prefix to rebuild lane KV
    /// (recompute-resume). Returns tokens generated so far.
    fn replay(&mut self, lane: usize, prompt: &[u32], history: &[u32]) -> Result<()> {
        let first = self.do_prefill(lane, prompt)?;
        let _ = first; // history[0] supersedes the resampled first token
        let p0 = prompt.len() as i32;
        // feed history[j] at position prompt+j; we don't resample
        for (j, &tok) in history.iter().enumerate() {
            if j + 1 == history.len() {
                break; // the last token is the next decode input
            }
            let (toks, pos) = self.lane_inputs(&[(lane, tok as i32, p0 + j as i32)]);
            let _ = self.raw_decode(&toks, &pos)?;
        }
        Ok(())
    }
}

impl Engine for RealEngine {
    fn max_batch(&self) -> usize {
        self.rt.meta().decode_batch
    }

    fn kv_capacity(&self) -> usize {
        self.rt.meta().decode_batch * self.rt.meta().max_seq
    }

    fn prefill(&mut self, req: &Request) -> Result<PrefillResult> {
        let t0 = Instant::now();
        let lane = match self.free_lane() {
            Some(l) => l,
            None => bail!("no free decode lane (coordinator over-admitted)"),
        };
        let prompt = self.prompt_tokens(req);
        let prompt_len = prompt.len() as u32;

        let (output, finished) = if let Some((plen, history)) = self.parked.remove(&req.id)
        {
            // recompute-resume: rebuild KV by replaying the sampled prefix
            debug_assert_eq!(plen, prompt_len);
            self.replay(lane, &prompt, &history)?;
            (history, false)
        } else {
            let first = self.do_prefill(lane, &prompt)?;
            let fin = first == self.rt.meta().eos_id || self.effective_max_output(prompt_len) <= 1;
            (vec![first], fin)
        };

        self.lanes[lane] = Some(LaneInfo {
            id: req.id,
            prompt_len,
            output,
            finished,
        });
        self.by_id.insert(req.id, lane);
        let elapsed = t0.elapsed().as_secs_f64();
        self.busy_prefill += elapsed;
        Ok(PrefillResult { elapsed, finished })
    }

    fn decode_step(
        &mut self,
        lanes: &mut [LaneState],
        _resident_kv_tokens: usize,
    ) -> Result<f64> {
        let t0 = Instant::now();
        // assemble inputs: last sampled token at its position
        let mut overrides = Vec::with_capacity(lanes.len());
        for ls in lanes.iter() {
            let &lane = self
                .by_id
                .get(&ls.id)
                .ok_or_else(|| anyhow::anyhow!("decode for unknown request {}", ls.id))?;
            let info = self.lanes[lane].as_ref().unwrap();
            let last = *info.output.last().expect("lane with no tokens");
            let position = info.prompt_len + info.output.len() as u32 - 1;
            overrides.push((lane, last as i32, position as i32));
        }
        let (toks, pos) = self.lane_inputs(&overrides);
        let rows = self.raw_decode(&toks, &pos)?;

        for ls in lanes.iter_mut() {
            let lane = self.by_id[&ls.id];
            let next = self.sample(&rows[lane]);
            let info = self.lanes[lane].as_mut().unwrap();
            info.output.push(next);
            ls.generated = info.output.len() as u32;
            ls.emitted = true;
            let cap = {
                let m = self.rt.meta();
                let hard = (m.max_seq as u32).saturating_sub(info.prompt_len + 1);
                if self.max_output == 0 { hard } else { self.max_output.min(hard) }
            };
            info.finished = next == self.rt.meta().eos_id || ls.generated >= cap;
            ls.finished = info.finished;
            self.decode_tokens += 1;
        }
        self.decode_steps += 1;
        let elapsed = t0.elapsed().as_secs_f64();
        self.busy_decode += elapsed;
        Ok(elapsed)
    }

    fn swap_time(&self, _tokens: usize) -> f64 {
        0.0 // real engine preempts by recompute only
    }

    fn evict(&mut self, id: RequestId) {
        if let Some(lane) = self.by_id.remove(&id) {
            self.lanes[lane] = None;
            self.zero_lane_kv(lane);
        }
        self.parked.remove(&id);
    }

    fn preempt_release(&mut self, id: RequestId) {
        if let Some(lane) = self.by_id.remove(&id) {
            if let Some(info) = self.lanes[lane].take() {
                self.parked.insert(id, (info.prompt_len, info.output));
            }
            self.zero_lane_kv(lane);
        }
    }

    fn stats(&self) -> EngineStats {
        EngineStats {
            busy_decode: self.busy_decode,
            busy_prefill: self.busy_prefill,
            busy_swap: 0.0,
            decode_steps: self.decode_steps,
            decode_tokens: self.decode_tokens,
            mean_utilization: 0.0,
        }
    }
}

impl RealEngine {
    /// Decoded text of a request's sampled output (for examples / the HTTP
    /// server). Only valid while the request is live or parked.
    pub fn output_text(&self, id: RequestId) -> Option<String> {
        if let Some(&lane) = self.by_id.get(&id) {
            let info = self.lanes[lane].as_ref()?;
            return Some(tokenizer::decode(&info.output));
        }
        self.parked.get(&id).map(|(_, out)| tokenizer::decode(out))
    }
}

// SAFETY: `xla::Literal` wraps a raw heap pointer without Send; RealEngine
// is only ever driven by one thread at a time (the coordinator owns it; the
// HTTP server funnels all execution through a single serving thread), so
// moving the engine across threads is sound under the same serialization
// argument as `runtime::Runtime`.
unsafe impl Send for RealEngine {}
