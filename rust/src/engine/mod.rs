//! Execution engines: the physical substrate that actually serves tokens.
//!
//! Two implementations share one trait so the whole coordinator stack runs
//! unchanged on either:
//!
//! * [`SimEngine`] — a calibrated roofline *simulator* of a GPU serving an
//!   LLM (the paper's A40/H800 testbeds; see DESIGN.md §Substitutions).
//!   Step time is `max(compute_term, memory_term)` which reproduces the
//!   compute-/memory-bound regimes of the paper's Fig. 5.
//! * [`RealEngine`] (in [`real`]) — drives the AOT-compiled tiny LM through
//!   PJRT: real prefill/decode HLO execution, real temperature sampling,
//!   genuinely stochastic output lengths.

pub mod real;
pub mod sim;

pub use real::RealEngine;
pub use sim::SimEngine;

use crate::core::{Request, RequestId};

/// Result of prefilling one request: the engine emits the first output
/// token as part of prefill (standard serving semantics — TTFT is measured
/// at prefill completion).
#[derive(Clone, Copy, Debug)]
pub struct PrefillResult {
    /// Engine-busy seconds consumed.
    pub elapsed: f64,
    /// Whether the request already finished (single-token outputs / EOS).
    pub finished: bool,
}

/// Per-request decode lane state passed to the engine each step.
#[derive(Clone, Debug)]
pub struct LaneState {
    pub id: RequestId,
    pub input_len: u32,
    /// Output tokens generated so far (>= 1 once prefilled).
    pub generated: u32,
    /// Ground-truth output length (sim engines finish on this; real
    /// engines ignore it and finish on EOS/capacity).
    pub true_output_len: u32,
    /// Set by the engine: this step emitted a token for the lane.
    pub emitted: bool,
    /// Set by the engine: the request completed this step.
    pub finished: bool,
}

impl LaneState {
    pub fn new(req: &Request, generated: u32) -> LaneState {
        LaneState {
            id: req.id,
            input_len: req.input_len,
            generated,
            true_output_len: req.true_output_len,
            emitted: false,
            finished: false,
        }
    }

    /// Current KV footprint in tokens.
    pub fn kv_tokens(&self) -> usize {
        (self.input_len + self.generated) as usize
    }
}

/// Aggregate engine-side counters for reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub busy_decode: f64,
    pub busy_prefill: f64,
    pub busy_swap: f64,
    pub decode_steps: u64,
    pub decode_tokens: u64,
    pub mean_utilization: f64,
}

/// An execution engine: owns model state, charges time.
pub trait Engine: Send {
    /// Max sequences per decode step.
    fn max_batch(&self) -> usize;

    /// KV capacity in tokens (coordinator enforces via [`crate::kvcache`]).
    fn kv_capacity(&self) -> usize;

    /// Run prefill for a request (exclusive; no decode overlaps).
    fn prefill(&mut self, req: &Request) -> anyhow::Result<PrefillResult>;

    /// Run prefill when the leading `cached_tokens` of the prompt are
    /// already resident in shared KV blocks (prefix-cache hit): only the
    /// uncached remainder is computed. Defaults to a full prefill —
    /// engines that cannot reuse KV (e.g. the real PJRT engine, which
    /// replays the whole prompt) simply ignore the hint.
    fn prefill_cached(
        &mut self,
        req: &Request,
        _cached_tokens: u32,
    ) -> anyhow::Result<PrefillResult> {
        self.prefill(req)
    }

    /// One decode step over the given lanes. `resident_kv_tokens` is the
    /// total KV resident on the device (memory-pressure input to the
    /// roofline). Returns elapsed engine-busy seconds; sets
    /// `emitted`/`finished`/`generated` on each lane.
    fn decode_step(
        &mut self,
        lanes: &mut [LaneState],
        resident_kv_tokens: usize,
    ) -> anyhow::Result<f64>;

    /// Seconds to move `tokens` KV tokens between GPU and host (one way).
    fn swap_time(&self, tokens: usize) -> f64;

    /// Forget per-request engine state entirely (request finished/aborted).
    fn evict(&mut self, id: RequestId);

    /// Release a request's execution slot on recompute-preemption, keeping
    /// whatever the engine needs to rebuild it on resume (defaults to
    /// [`Engine::evict`]; the real engine keeps the generated-token history
    /// so it can replay the prefix).
    fn preempt_release(&mut self, id: RequestId) {
        self.evict(id);
    }

    /// Account externally-incurred swap traffic (busy-time bookkeeping).
    fn charge_swap(&mut self, _seconds: f64) {}

    /// Aggregate counters for the run report.
    fn stats(&self) -> EngineStats {
        EngineStats::default()
    }
}
