//! Roofline GPU simulator (the paper's testbed substitute).
//!
//! Decode step time is `max(compute, memory)`:
//!
//! * compute term `c0 + c1·B` — per-step kernel launch + GEMM work growing
//!   with batch size (FFN dominated; amortizes with batch, Fig. 5(a) short
//!   sequences);
//! * memory term `m0 + m1·ΣKV` — streaming every resident KV token once per
//!   step (attention IO; dominates for long sequences, Fig. 5(a) long
//!   sequences, and grows linearly per step exactly as Fig. 5(b) measures).
//!
//! Prefill is quadratic-in-`I` (`p0 + p1·I + p2·I²`) and runs exclusively,
//! as in vLLM v0.8.2's default non-chunked prefill.

use std::collections::BTreeMap;

use crate::config::EngineProfile;
use crate::core::{Request, RequestId};

use super::{Engine, EngineStats, LaneState, PrefillResult};

/// Simulated engine. Deterministic: all timing is derived from the profile;
/// completion is derived from each request's hidden true output length.
pub struct SimEngine {
    profile: EngineProfile,
    /// engine-busy seconds accumulated (observability)
    pub busy_decode: f64,
    pub busy_prefill: f64,
    pub busy_swap: f64,
    pub decode_steps: u64,
    pub decode_tokens: u64,
    /// time-weighted GPU "utilization" integral (compute_term / step_time)
    util_weighted: f64,
    /// per-request amount of prefill recomputation performed (tokens)
    prefilled: BTreeMap<RequestId, u32>,
    /// last step's terms, for the fig5 instrumentation
    pub last_compute_term: f64,
    pub last_memory_term: f64,
}

impl SimEngine {
    pub fn new(profile: EngineProfile) -> SimEngine {
        SimEngine {
            profile,
            busy_decode: 0.0,
            busy_prefill: 0.0,
            busy_swap: 0.0,
            decode_steps: 0,
            decode_tokens: 0,
            util_weighted: 0.0,
            prefilled: BTreeMap::new(),
            last_compute_term: 0.0,
            last_memory_term: 0.0,
        }
    }

    pub fn profile(&self) -> &EngineProfile {
        &self.profile
    }

    /// Seconds to prefill `tokens` prompt tokens.
    pub fn prefill_time(&self, tokens: u32) -> f64 {
        let t = tokens as f64;
        self.profile.prefill_p0 + self.profile.prefill_p1 * t + self.profile.prefill_p2 * t * t
    }

    /// The roofline step time and its two terms for given batch/memory
    /// pressure. Exposed for fig5a/fig5b instrumentation.
    pub fn step_terms(&self, batch: usize, resident_kv: usize) -> (f64, f64, f64) {
        let compute = self.profile.decode_c0 + self.profile.decode_c1 * batch as f64;
        let memory = self.profile.decode_m0 + self.profile.decode_m1 * resident_kv as f64;
        (compute.max(memory), compute, memory)
    }

    /// Mean achieved "GPU utilization" estimate over the run: per-sequence
    /// GEMM work amortizing the weight-streaming constant (rises with
    /// batch size — fig5a's y-axis).
    pub fn mean_utilization(&self) -> f64 {
        if self.busy_decode == 0.0 {
            0.0
        } else {
            self.util_weighted / self.busy_decode
        }
    }

    /// Record external swap traffic (coordinator calls this so busy-time
    /// accounting stays inside the engine).
    pub fn charge_swap(&mut self, seconds: f64) {
        self.busy_swap += seconds;
    }
}

impl Engine for SimEngine {
    fn max_batch(&self) -> usize {
        self.profile.max_batch
    }

    fn kv_capacity(&self) -> usize {
        self.profile.kv_capacity
    }

    fn prefill(&mut self, req: &Request) -> anyhow::Result<PrefillResult> {
        self.prefill_cached(req, 0)
    }

    fn prefill_cached(
        &mut self,
        req: &Request,
        cached_tokens: u32,
    ) -> anyhow::Result<PrefillResult> {
        let cached = cached_tokens.min(req.input_len);
        // the warm prefix skips its share of the quadratic prefill cost:
        // what remains is extending a `cached`-token KV to `input_len`
        let elapsed = (self.prefill_time(req.input_len) - self.prefill_time(cached)).max(0.0);
        self.busy_prefill += elapsed;
        self.prefilled.insert(req.id, req.input_len - cached);
        // prefill emits the first output token
        let finished = req.true_output_len <= 1;
        Ok(PrefillResult { elapsed, finished })
    }

    fn decode_step(
        &mut self,
        lanes: &mut [LaneState],
        resident_kv_tokens: usize,
    ) -> anyhow::Result<f64> {
        assert!(!lanes.is_empty(), "decode_step with empty batch");
        assert!(lanes.len() <= self.max_batch());
        let (step, compute, memory) = self.step_terms(lanes.len(), resident_kv_tokens);
        self.last_compute_term = compute;
        self.last_memory_term = memory;
        self.busy_decode += step;
        // achieved/peak FLOPs estimate: per-sequence GEMM work (c1·B)
        // amortizing the weight-streaming constant (c0)
        let util = (self.profile.decode_c1 * 2.0 * lanes.len() as f64 / step).min(1.0);
        self.util_weighted += step * util;
        self.decode_steps += 1;
        for lane in lanes.iter_mut() {
            lane.generated += 1;
            lane.emitted = true;
            lane.finished = lane.generated >= lane.true_output_len;
            self.decode_tokens += 1;
        }
        Ok(step)
    }

    fn swap_time(&self, tokens: usize) -> f64 {
        self.profile.swap_per_token * tokens as f64
    }

    fn evict(&mut self, id: RequestId) {
        self.prefilled.remove(&id);
    }

    fn charge_swap(&mut self, seconds: f64) {
        self.busy_swap += seconds;
    }

    fn stats(&self) -> EngineStats {
        EngineStats {
            busy_decode: self.busy_decode,
            busy_prefill: self.busy_prefill,
            busy_swap: self.busy_swap,
            decode_steps: self.decode_steps,
            decode_tokens: self.decode_tokens,
            mean_utilization: self.mean_utilization(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, EngineProfile};
    use crate::distribution::LengthDist;
    use crate::embedding::Embedding;

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            prompt: String::new(),
            input_len: input,
            true_output_len: output,
            arrival: 0.0,
            dataset: DatasetKind::ShareGpt,
            topic: 0,
            embedding: Embedding::normalize(vec![1.0, 0.0]),
            true_dist: Some(LengthDist::point(output as f64)),
            slo: crate::slo::SloClass::Standard,
            prefix_key: Vec::new(),
        }
    }

    fn eng() -> SimEngine {
        SimEngine::new(EngineProfile::a40_llama8b())
    }

    #[test]
    fn prefill_time_grows_superlinearly() {
        let e = eng();
        let t100 = e.prefill_time(100);
        let t1000 = e.prefill_time(1000);
        assert!(t1000 > 9.0 * t100 / 2.0, "t100={t100} t1000={t1000}");
    }

    #[test]
    fn decode_step_advances_lanes_and_finishes() {
        let mut e = eng();
        let r = req(1, 10, 2);
        let pr = e.prefill(&r).unwrap();
        assert!(!pr.finished);
        let mut lanes = vec![LaneState::new(&r, 1)];
        let dt = e.decode_step(&mut lanes, 12).unwrap();
        assert!(dt > 0.0);
        assert_eq!(lanes[0].generated, 2);
        assert!(lanes[0].finished);
    }

    #[test]
    fn single_token_output_finishes_at_prefill() {
        let mut e = eng();
        let pr = e.prefill(&req(1, 10, 1)).unwrap();
        assert!(pr.finished);
    }

    #[test]
    fn roofline_compute_vs_memory_bound() {
        // the A40/H800 presets are weight-streaming dominated (memory
        // pressure binds through KV *capacity*); verify the roofline max
        // itself with a profile whose KV-streaming term can dominate
        let mut p = EngineProfile::a40_llama8b();
        p.decode_m1 = 2.0e-6;
        let e = SimEngine::new(p);
        let (t1, c1, m1) = e.step_terms(4, 200);
        assert_eq!(t1, c1.max(m1));
        assert!(c1 > m1, "expected compute-bound: c={c1} m={m1}");
        let (t2, c2, m2) = e.step_terms(4, 55_000);
        assert!(m2 > c2, "expected memory-bound: c={c2} m={m2}");
        assert_eq!(t2, m2);
    }

    #[test]
    fn step_time_monotone_in_batch_and_kv() {
        let e = eng();
        let (a, _, _) = e.step_terms(1, 1000);
        let (b, _, _) = e.step_terms(64, 1000);
        let (c, _, _) = e.step_terms(64, 60_000);
        assert!(b >= a);
        assert!(c >= b);
    }

    #[test]
    fn utilization_rises_with_batch() {
        let r = req(1, 10, 1000);
        let mut small = eng();
        let mut lanes1 = vec![LaneState::new(&r, 1); 2];
        let mut big = eng();
        let mut lanes64 = vec![LaneState::new(&r, 1); 64];
        for _ in 0..10 {
            small.decode_step(&mut lanes1, 200).unwrap();
            big.decode_step(&mut lanes64, 6400).unwrap();
        }
        assert!(big.mean_utilization() > 2.0 * small.mean_utilization());
    }

    #[test]
    fn cached_prefill_charges_only_the_remainder() {
        let mut e = eng();
        let r = req(1, 500, 10);
        let full = e.prefill(&r).unwrap().elapsed;
        let hit = e.prefill_cached(&r, 400).unwrap().elapsed;
        let expect = e.prefill_time(500) - e.prefill_time(400);
        assert!((hit - expect).abs() < 1e-12);
        assert!(hit < full);
        // a hit covering the whole prompt still charges nothing negative
        let over = e.prefill_cached(&r, 10_000).unwrap().elapsed;
        assert!(over >= 0.0);
    }

    #[test]
    fn swap_time_linear() {
        let e = eng();
        assert!((e.swap_time(2000) - 2.0 * e.swap_time(1000)).abs() < 1e-12);
    }

    #[test]
    fn busy_accounting_accumulates() {
        let mut e = eng();
        let r = req(1, 50, 10);
        e.prefill(&r).unwrap();
        let mut lanes = vec![LaneState::new(&r, 1)];
        let dt = e.decode_step(&mut lanes, 60).unwrap();
        assert!(e.busy_prefill > 0.0);
        assert!((e.busy_decode - dt).abs() < 1e-15);
        assert_eq!(e.decode_steps, 1);
        assert_eq!(e.decode_tokens, 1);
    }
}
