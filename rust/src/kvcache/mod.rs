//! Paged KV-cache accounting (vLLM-style block allocator).
//!
//! Tracks GPU KV memory in fixed-size token blocks with per-request block
//! tables, plus swap-out/swap-in to host memory for preemption. This is the
//! *memory* half of demand hybridity: admission and preemption decisions in
//! [`crate::serve`] are gated on whether a request's next token still fits.

use std::collections::BTreeMap;

use crate::core::RequestId;

/// Block identifier.
pub type BlockId = u32;

/// Where a request's KV currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvResidence {
    Gpu,
    Swapped,
}

/// Per-request KV state.
#[derive(Clone, Debug)]
struct SeqState {
    blocks: Vec<BlockId>,
    tokens: usize,
    residence: KvResidence,
}

/// Paged block allocator over a fixed GPU KV budget.
#[derive(Debug)]
pub struct KvManager {
    block_tokens: usize,
    total_blocks: usize,
    free: Vec<BlockId>,
    seqs: BTreeMap<RequestId, SeqState>,
    /// cumulative counters (observability / fig5a)
    pub swap_out_events: u64,
    pub swap_in_events: u64,
    pub peak_used_blocks: usize,
}

impl KvManager {
    /// `capacity_tokens` is rounded down to whole blocks.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> KvManager {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        assert!(total_blocks > 0, "capacity smaller than one block");
        KvManager {
            block_tokens,
            total_blocks,
            free: (0..total_blocks as BlockId).rev().collect(),
            seqs: BTreeMap::new(),
            swap_out_events: 0,
            swap_in_events: 0,
            peak_used_blocks: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Tokens resident on GPU (counts whole sequences, not block padding).
    pub fn resident_tokens(&self) -> usize {
        self.seqs
            .values()
            .filter(|s| s.residence == KvResidence::Gpu)
            .map(|s| s.tokens)
            .sum()
    }

    /// GPU utilization of the KV pool in blocks, 0..=1.
    pub fn occupancy(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can `tokens` KV tokens be newly allocated right now?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Would growing request `id` to `tokens` total tokens fit?
    pub fn can_grow_to(&self, id: RequestId, tokens: usize) -> bool {
        let have = self.seqs.get(&id).map(|s| s.blocks.len()).unwrap_or(0);
        let need = self.blocks_for(tokens);
        need.saturating_sub(have) <= self.free.len()
    }

    /// Allocate (or grow) the sequence to hold `tokens` tokens on GPU.
    /// Returns false (and changes nothing) if blocks are insufficient.
    pub fn grow_to(&mut self, id: RequestId, tokens: usize) -> bool {
        let entry = self.seqs.entry(id).or_insert(SeqState {
            blocks: Vec::new(),
            tokens: 0,
            residence: KvResidence::Gpu,
        });
        assert_eq!(
            entry.residence,
            KvResidence::Gpu,
            "grow_to on swapped sequence {id}"
        );
        let need = tokens.div_ceil(self.block_tokens);
        if need > entry.blocks.len() {
            let extra = need - entry.blocks.len();
            if extra > self.free.len() {
                if entry.blocks.is_empty() {
                    self.seqs.remove(&id);
                }
                return false;
            }
            for _ in 0..extra {
                entry.blocks.push(self.free.pop().unwrap());
            }
        }
        entry.tokens = entry.tokens.max(tokens);
        let used = self.total_blocks - self.free.len();
        if used > self.peak_used_blocks {
            self.peak_used_blocks = used;
        }
        true
    }

    /// Release all blocks of a finished request.
    pub fn release(&mut self, id: RequestId) {
        if let Some(seq) = self.seqs.remove(&id) {
            if seq.residence == KvResidence::Gpu {
                self.free.extend(seq.blocks);
            }
        }
    }

    /// Swap a sequence out to host memory; its GPU blocks are freed but its
    /// token count is remembered. Returns the number of tokens moved.
    pub fn swap_out(&mut self, id: RequestId) -> usize {
        let seq = self.seqs.get_mut(&id).expect("swap_out of unknown seq");
        assert_eq!(seq.residence, KvResidence::Gpu);
        let blocks = std::mem::take(&mut seq.blocks);
        self.free.extend(blocks);
        seq.residence = KvResidence::Swapped;
        self.swap_out_events += 1;
        seq.tokens
    }

    /// Bring a swapped sequence back to GPU. Returns tokens moved, or None
    /// if blocks are insufficient (nothing changes).
    pub fn swap_in(&mut self, id: RequestId) -> Option<usize> {
        let need = {
            let seq = self.seqs.get(&id).expect("swap_in of unknown seq");
            assert_eq!(seq.residence, KvResidence::Swapped);
            self.blocks_for(seq.tokens)
        };
        if need > self.free.len() {
            return None;
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            blocks.push(self.free.pop().unwrap());
        }
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.blocks = blocks;
        seq.residence = KvResidence::Gpu;
        self.swap_in_events += 1;
        let used = self.total_blocks - self.free.len();
        if used > self.peak_used_blocks {
            self.peak_used_blocks = used;
        }
        Some(seq.tokens)
    }

    /// Drop a sequence's KV entirely (recompute-mode preemption).
    pub fn drop_seq(&mut self, id: RequestId) {
        self.release(id);
    }

    pub fn residence(&self, id: RequestId) -> Option<KvResidence> {
        self.seqs.get(&id).map(|s| s.residence)
    }

    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.seqs.get(&id).map(|s| s.tokens).unwrap_or(0)
    }

    /// Internal-fragmentation ratio: wasted tail tokens / allocated tokens.
    pub fn fragmentation(&self) -> f64 {
        let mut alloc = 0usize;
        let mut used = 0usize;
        for s in self.seqs.values() {
            if s.residence == KvResidence::Gpu {
                alloc += s.blocks.len() * self.block_tokens;
                used += s.tokens;
            }
        }
        if alloc == 0 {
            0.0
        } else {
            (alloc - used) as f64 / alloc as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(160, 16) // 10 blocks
    }

    #[test]
    fn allocation_and_growth() {
        let mut m = mgr();
        assert!(m.grow_to(1, 10)); // 1 block
        assert_eq!(m.used_blocks(), 1);
        assert!(m.grow_to(1, 17)); // 2 blocks
        assert_eq!(m.used_blocks(), 2);
        assert_eq!(m.tokens_of(1), 17);
        assert!(m.grow_to(1, 17)); // no-op
        assert_eq!(m.used_blocks(), 2);
    }

    #[test]
    fn capacity_enforced_atomically() {
        let mut m = mgr();
        assert!(m.grow_to(1, 160));
        assert_eq!(m.free_blocks(), 0);
        assert!(!m.grow_to(2, 1));
        assert_eq!(m.tokens_of(2), 0); // rolled back
        m.release(1);
        assert_eq!(m.free_blocks(), 10);
        assert!(m.grow_to(2, 1));
    }

    #[test]
    fn can_grow_accounts_existing_blocks() {
        let mut m = mgr();
        assert!(m.grow_to(1, 16));
        assert!(m.can_grow_to(1, 32));
        assert!(m.grow_to(2, 128)); // 8 blocks → 9 used
        assert!(m.can_grow_to(1, 32)); // needs 1 more, 1 free
        assert!(!m.can_grow_to(1, 48)); // needs 2 more, only 1 free
    }

    #[test]
    fn swap_out_frees_blocks_and_remembers_tokens() {
        let mut m = mgr();
        m.grow_to(1, 40);
        let moved = m.swap_out(1);
        assert_eq!(moved, 40);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.residence(1), Some(KvResidence::Swapped));
        assert_eq!(m.tokens_of(1), 40);

        let back = m.swap_in(1);
        assert_eq!(back, Some(40));
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(m.residence(1), Some(KvResidence::Gpu));
    }

    #[test]
    fn swap_in_fails_when_full() {
        let mut m = mgr();
        m.grow_to(1, 40);
        m.swap_out(1);
        m.grow_to(2, 160);
        assert_eq!(m.swap_in(1), None);
        assert_eq!(m.residence(1), Some(KvResidence::Swapped));
    }

    #[test]
    fn release_swapped_sequence_is_safe() {
        let mut m = mgr();
        m.grow_to(1, 16);
        m.swap_out(1);
        m.release(1);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.residence(1), None);
    }

    #[test]
    fn resident_tokens_excludes_swapped() {
        let mut m = mgr();
        m.grow_to(1, 20);
        m.grow_to(2, 30);
        assert_eq!(m.resident_tokens(), 50);
        m.swap_out(1);
        assert_eq!(m.resident_tokens(), 30);
    }

    #[test]
    fn fragmentation_measured() {
        let mut m = mgr();
        m.grow_to(1, 17); // 2 blocks = 32 alloc, 17 used
        let f = m.fragmentation();
        assert!((f - 15.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn peak_usage_tracked() {
        let mut m = mgr();
        m.grow_to(1, 80);
        m.grow_to(2, 48);
        m.release(1);
        assert_eq!(m.peak_used_blocks, 8);
    }

    #[test]
    #[should_panic]
    fn grow_swapped_panics() {
        let mut m = mgr();
        m.grow_to(1, 16);
        m.swap_out(1);
        m.grow_to(1, 32);
    }
}
