//! Paged KV-cache accounting with ref-counted shared blocks and a prefix
//! index (vLLM/SGLang-style).
//!
//! Tracks GPU KV memory in fixed-size token blocks. Unlike a plain
//! per-request block table, blocks here are **ref-counted and shareable**:
//! a request arriving with a prefix token-key chain
//! ([`crate::core::Request::prefix_key`]) matches its leading full blocks
//! against the prefix index and reuses any block already holding that
//! content — the matched tokens skip prefill entirely. When the last
//! reference to an indexed block drops, the block is *retained* in an LRU
//! pool instead of freed: still warm for the session's next turn, but
//! reclaimable on demand (the LRU budget is the whole free pool — warm
//! blocks are evicted oldest-first the moment a fresh allocation needs
//! them). Swap-out/swap-in respect sharing: a block another live sequence
//! references is never freed, and only the private (non-indexed) portion
//! of a sequence actually moves to host memory.
//!
//! This is the *memory* half of demand hybridity: admission and preemption
//! decisions in [`crate::serve`] are gated on whether a request's next
//! token still fits, and with sessions enabled the hit-rate/tokens-saved
//! counters here feed the cache-affinity router and the run reports.
//!
//! Sharing only ever arises through chain keys. A request with an empty
//! chain allocates private blocks, nothing is ever indexed, and every code
//! path below reduces exactly to the old private-table behavior — which is
//! what keeps seeded single-shot traces byte-identical.

use std::collections::BTreeMap;

use crate::core::RequestId;

/// Block identifier.
pub type BlockId = u32;

/// Where a request's KV currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvResidence {
    Gpu,
    Swapped,
}

/// Result of a prefix-aware allocation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheOutcome {
    /// Prompt tokens served from warm shared blocks (skip prefill).
    pub cached_tokens: usize,
    /// Blocks reused from the prefix index.
    pub cached_blocks: usize,
    /// Blocks newly taken from the free/LRU pools.
    pub fresh_blocks: usize,
}

/// Per-block shared state.
#[derive(Clone, Debug, Default)]
struct Block {
    /// Live sequences referencing this block.
    refs: u32,
    /// Content key under which this block is registered in the prefix
    /// index (`None` = private content, never shareable).
    key: Option<u64>,
    /// LRU stamp while unreferenced-but-warm (`None` while referenced or
    /// free).
    stamp: Option<u64>,
}

/// Per-request KV state.
#[derive(Clone, Debug)]
struct SeqState {
    blocks: Vec<BlockId>,
    tokens: usize,
    residence: KvResidence,
    /// Prefix token-key chain (one key per full block of content).
    chain: Vec<u64>,
    /// Per-position flag: `true` = this block's content lives on host
    /// while swapped (private blocks); `false` = the content stayed on GPU
    /// in an indexed block and is re-acquired through the prefix index at
    /// swap-in. Empty while resident.
    swap_hosted: Vec<bool>,
    /// Tokens this sequence currently holds in host memory (non-zero only
    /// while swapped).
    host_tokens: usize,
}

/// Paged block allocator over a fixed GPU KV budget, with ref-counted
/// shared blocks and an LRU-retained prefix index.
#[derive(Debug)]
pub struct KvManager {
    block_tokens: usize,
    total_blocks: usize,
    free: Vec<BlockId>,
    blocks: Vec<Block>,
    /// content key -> block currently holding that content
    prefix_index: BTreeMap<u64, BlockId>,
    /// LRU of unreferenced-but-indexed blocks: stamp -> block. Oldest
    /// stamp is evicted first when a fresh allocation finds `free` empty.
    lru: BTreeMap<u64, BlockId>,
    /// Monotone LRU clock.
    next_stamp: u64,
    seqs: BTreeMap<RequestId, SeqState>,
    /// Incremental counters (kept in sync at every grow/release/swap so
    /// the per-dispatch read paths never scan the sequence map; the
    /// `debug_assert_counters` scan cross-checks them in debug builds).
    resident_tokens_ctr: usize,
    frag_alloc_tokens: usize,
    /// cumulative counters (observability / fig5a / reports)
    pub swap_out_events: u64,
    pub swap_in_events: u64,
    pub peak_used_blocks: usize,
    /// Prefix-aware allocations attempted (non-empty chain only).
    pub prefix_lookups: u64,
    /// Prefix-aware allocations that reused at least one warm block.
    pub prefix_hits: u64,
    /// Prompt tokens served warm instead of prefilled, cumulative.
    pub prefill_tokens_saved: u64,
    /// Warm LRU blocks reclaimed to satisfy fresh allocations.
    pub prefix_evictions: u64,
    /// Tokens currently swapped out to host memory (the swapped-token
    /// occupancy: grows at swap-out, shrinks at swap-in *and* when a
    /// swapped sequence is dropped).
    pub swapped_tokens: usize,
    /// High-water mark of `swapped_tokens`.
    pub peak_swapped_tokens: usize,
}

impl KvManager {
    /// `capacity_tokens` is rounded down to whole blocks.
    pub fn new(capacity_tokens: usize, block_tokens: usize) -> KvManager {
        assert!(block_tokens > 0);
        let total_blocks = capacity_tokens / block_tokens;
        assert!(total_blocks > 0, "capacity smaller than one block");
        KvManager {
            block_tokens,
            total_blocks,
            free: (0..total_blocks as BlockId).rev().collect(),
            blocks: vec![Block::default(); total_blocks],
            prefix_index: BTreeMap::new(),
            lru: BTreeMap::new(),
            next_stamp: 0,
            seqs: BTreeMap::new(),
            resident_tokens_ctr: 0,
            frag_alloc_tokens: 0,
            swap_out_events: 0,
            swap_in_events: 0,
            peak_used_blocks: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefill_tokens_saved: 0,
            prefix_evictions: 0,
            swapped_tokens: 0,
            peak_swapped_tokens: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks available to fresh allocations: truly free plus warm LRU
    /// blocks (evictable on demand).
    pub fn free_blocks(&self) -> usize {
        self.free.len() + self.lru.len()
    }

    /// Blocks referenced by live sequences. Warm LRU-retained blocks do
    /// *not* count — they are reclaimable capacity, so an idle manager
    /// with a warm cache still reads as fully released.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len() - self.lru.len()
    }

    /// Warm unreferenced blocks currently retained in the LRU pool.
    pub fn warm_blocks(&self) -> usize {
        self.lru.len()
    }

    /// Tokens resident on GPU, summed per sequence (a shared block counts
    /// once per referencing sequence — each sequence's attention streams
    /// its full logical KV every decode step, so the *logical* sum is what
    /// the roofline memory term wants). O(1): maintained incrementally.
    pub fn resident_tokens(&self) -> usize {
        self.debug_assert_counters();
        self.resident_tokens_ctr
    }

    /// GPU utilization of the KV pool in blocks, 0..=1.
    pub fn occupancy(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can `tokens` KV tokens be newly allocated right now (counting warm
    /// LRU blocks as reclaimable)?
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free_blocks()
    }

    /// Would growing request `id` to `tokens` total tokens fit?
    pub fn can_grow_to(&self, id: RequestId, tokens: usize) -> bool {
        let have = self.seqs.get(&id).map(|s| s.blocks.len()).unwrap_or(0);
        let need = self.blocks_for(tokens);
        need.saturating_sub(have) <= self.free_blocks()
    }

    /// Tokens of `chain` currently servable warm from the prefix index for
    /// a prompt of `input_len` tokens — the read-only probe behind
    /// predicted post-hit cost and the cache-affinity router. Matches
    /// leading chain keys only (a prefix is a *chain*: a later block is
    /// meaningless without everything before it) and caps the hit so at
    /// least one prompt token is always computed fresh, mirroring
    /// [`KvManager::allocate_with_prefix`].
    pub fn cached_prefix_tokens(&self, chain: &[u64], input_len: usize) -> usize {
        let cap = input_len.saturating_sub(1) / self.block_tokens;
        let mut hit = 0usize;
        for key in chain.iter().take(cap) {
            if self.prefix_index.contains_key(key) {
                hit += 1;
            } else {
                break;
            }
        }
        hit * self.block_tokens
    }

    /// Take one block for fresh content: the free list first, then the
    /// oldest warm LRU block (evicting its index entry). `None` when every
    /// block is referenced by a live sequence.
    fn take_block(&mut self) -> Option<BlockId> {
        if let Some(b) = self.free.pop() {
            return Some(b);
        }
        let (&stamp, &bid) = self.lru.iter().next()?;
        self.lru.remove(&stamp);
        let blk = &mut self.blocks[bid as usize];
        debug_assert_eq!(blk.refs, 0, "LRU block {bid} has live readers");
        if let Some(key) = blk.key.take() {
            self.prefix_index.remove(&key);
        }
        blk.stamp = None;
        self.prefix_evictions += 1;
        Some(bid)
    }

    /// Acquire a reference on an indexed block (removing it from the LRU
    /// pool if it was unreferenced).
    fn acquire(&mut self, bid: BlockId) {
        let blk = &mut self.blocks[bid as usize];
        if blk.refs == 0 {
            let stamp = blk.stamp.take().expect("unreferenced block not in LRU");
            self.lru.remove(&stamp);
        }
        blk.refs += 1;
    }

    /// Drop one reference; an unreferenced indexed block is retained in
    /// the LRU pool, an unreferenced private block is freed.
    fn drop_ref(&mut self, bid: BlockId) {
        let blk = &mut self.blocks[bid as usize];
        debug_assert!(blk.refs > 0, "drop_ref on unreferenced block {bid}");
        blk.refs -= 1;
        if blk.refs > 0 {
            return;
        }
        if blk.key.is_some() {
            let stamp = self.next_stamp;
            self.next_stamp += 1;
            blk.stamp = Some(stamp);
            self.lru.insert(stamp, bid);
        } else {
            self.free.push(bid);
        }
    }

    fn note_peak(&mut self) {
        let used = self.used_blocks();
        if used > self.peak_used_blocks {
            self.peak_used_blocks = used;
        }
    }

    /// Allocate a fresh sequence of `tokens` total tokens, reusing warm
    /// shared blocks for the leading portion of `chain` that is already
    /// resident. Returns `None` (and changes nothing) if blocks are
    /// insufficient even after evicting every warm block.
    ///
    /// `tokens` is the prompt plus one slot for the first output token
    /// (`input_len + 1`, as the coordinator allocates); the hit is capped
    /// so at least one prompt token always prefills — emitting the first
    /// token requires a real forward pass even on a full prefix hit.
    /// Fresh blocks that will hold full-block chain content are registered
    /// in the prefix index immediately, so concurrent requests of the same
    /// session/system-prompt can share them.
    pub fn allocate_with_prefix(
        &mut self,
        id: RequestId,
        chain: &[u64],
        tokens: usize,
    ) -> Option<CacheOutcome> {
        assert!(
            !self.seqs.contains_key(&id),
            "allocate_with_prefix on live sequence {id}"
        );
        if !chain.is_empty() {
            self.prefix_lookups += 1;
        }
        let input_len = tokens.saturating_sub(1);
        // cap: at least one prompt token computes fresh
        let hit_cap = input_len.saturating_sub(1) / self.block_tokens;
        let mut matched: Vec<BlockId> = Vec::new();
        // remember (block, stamp-before-acquire) for exact rollback: a
        // failed allocation must not reorder the LRU
        let mut taken_stamps: Vec<(BlockId, Option<u64>)> = Vec::new();
        for key in chain.iter().take(hit_cap) {
            match self.prefix_index.get(key) {
                Some(&bid) => {
                    taken_stamps.push((bid, self.blocks[bid as usize].stamp));
                    self.acquire(bid);
                    matched.push(bid);
                }
                None => break,
            }
        }
        let need = self.blocks_for(tokens);
        debug_assert!(matched.len() <= need);
        let fresh_needed = need - matched.len();
        let mut fresh: Vec<BlockId> = Vec::with_capacity(fresh_needed);
        for _ in 0..fresh_needed {
            match self.take_block() {
                Some(b) => fresh.push(b),
                None => {
                    // atomic rollback: return fresh blocks, restore every
                    // matched block's refcount and original LRU stamp
                    self.free.extend(fresh);
                    for &(bid, stamp) in taken_stamps.iter().rev() {
                        let blk = &mut self.blocks[bid as usize];
                        blk.refs -= 1;
                        if blk.refs == 0 {
                            let stamp = stamp.expect("matched block was in LRU");
                            blk.stamp = Some(stamp);
                            self.lru.insert(stamp, bid);
                        }
                    }
                    return None;
                }
            }
        }
        let cached_blocks = matched.len();
        let cached_tokens = cached_blocks * self.block_tokens;
        // register fresh blocks that will hold full-block chain content
        // once the prompt is prefilled (a key another sequence registered
        // first keeps its existing block; this copy stays private)
        for (offset, &bid) in fresh.iter().enumerate() {
            let pos = cached_blocks + offset;
            let full = (pos + 1) * self.block_tokens <= input_len;
            if !full || pos >= chain.len() {
                continue;
            }
            let key = chain[pos];
            if let std::collections::btree_map::Entry::Vacant(e) =
                self.prefix_index.entry(key)
            {
                e.insert(bid);
                self.blocks[bid as usize].key = Some(key);
            }
        }
        let mut all_blocks = matched;
        all_blocks.extend(&fresh);
        for &bid in &fresh {
            self.blocks[bid as usize].refs = 1;
        }
        self.seqs.insert(
            id,
            SeqState {
                blocks: all_blocks,
                tokens,
                residence: KvResidence::Gpu,
                chain: chain.to_vec(),
                swap_hosted: Vec::new(),
                host_tokens: 0,
            },
        );
        self.resident_tokens_ctr += tokens;
        self.frag_alloc_tokens += need * self.block_tokens;
        if cached_blocks > 0 {
            self.prefix_hits += 1;
            self.prefill_tokens_saved += cached_tokens as u64;
        }
        self.note_peak();
        self.debug_assert_counters();
        Some(CacheOutcome {
            cached_tokens,
            cached_blocks,
            fresh_blocks: fresh.len(),
        })
    }

    /// Allocate (or grow) the sequence to hold `tokens` tokens on GPU.
    /// Growth blocks are always private (decode output is unique to the
    /// sequence until its release registers it). Returns false (and
    /// changes nothing) if blocks are insufficient.
    pub fn grow_to(&mut self, id: RequestId, tokens: usize) -> bool {
        if !self.seqs.contains_key(&id) {
            return self.allocate_with_prefix(id, &[], tokens).is_some();
        }
        {
            let entry = self.seqs.get(&id).unwrap();
            assert_eq!(
                entry.residence,
                KvResidence::Gpu,
                "grow_to on swapped sequence {id}"
            );
        }
        let need = self.blocks_for(tokens);
        let have = self.seqs.get(&id).unwrap().blocks.len();
        if need > have {
            let extra = need - have;
            let mut fresh = Vec::with_capacity(extra);
            for _ in 0..extra {
                match self.take_block() {
                    Some(b) => fresh.push(b),
                    None => {
                        self.free.extend(fresh);
                        return false;
                    }
                }
            }
            for &bid in &fresh {
                self.blocks[bid as usize].refs = 1;
            }
            self.frag_alloc_tokens += fresh.len() * self.block_tokens;
            self.seqs.get_mut(&id).unwrap().blocks.extend(fresh);
        }
        let entry = self.seqs.get_mut(&id).unwrap();
        if tokens > entry.tokens {
            self.resident_tokens_ctr += tokens - entry.tokens;
            entry.tokens = tokens;
        }
        self.note_peak();
        self.debug_assert_counters();
        true
    }

    /// Register the sequence's completed full-block content in the prefix
    /// index (called on release, so a finished turn's reply blocks are
    /// warm for the session's next turn).
    fn register_output_blocks(&mut self, seq: &SeqState) {
        let full = seq.tokens / self.block_tokens;
        for pos in 0..full.min(seq.chain.len()).min(seq.blocks.len()) {
            let bid = seq.blocks[pos];
            if self.blocks[bid as usize].key.is_some() {
                continue;
            }
            let key = seq.chain[pos];
            if let std::collections::btree_map::Entry::Vacant(e) =
                self.prefix_index.entry(key)
            {
                e.insert(bid);
                self.blocks[bid as usize].key = Some(key);
            }
        }
    }

    /// Release all blocks of a finished (or dropped) request. Shared
    /// blocks only lose this sequence's reference; indexed blocks whose
    /// last reference drops are retained warm in the LRU pool. Dropping a
    /// *swapped* sequence releases its host-side occupancy (the old
    /// allocator silently forgot those tokens).
    pub fn release(&mut self, id: RequestId) {
        let Some(seq) = self.seqs.remove(&id) else {
            return;
        };
        match seq.residence {
            KvResidence::Gpu => {
                self.resident_tokens_ctr -= seq.tokens;
                self.frag_alloc_tokens -= seq.blocks.len() * self.block_tokens;
                self.register_output_blocks(&seq);
                for &bid in &seq.blocks {
                    self.drop_ref(bid);
                }
            }
            KvResidence::Swapped => {
                self.swapped_tokens -= seq.host_tokens;
            }
        }
        self.debug_assert_counters();
    }

    /// Tokens of content block `pos` holds for a sequence of `tokens`
    /// total tokens.
    fn block_content(&self, pos: usize, tokens: usize) -> usize {
        tokens.saturating_sub(pos * self.block_tokens).min(self.block_tokens)
    }

    /// Swap a sequence out to host memory. Only its *private* blocks move
    /// (and are freed on GPU): indexed blocks stay resident — either still
    /// referenced by another live sequence or retained warm in the LRU —
    /// and are re-acquired through the prefix index at swap-in. Returns
    /// the number of tokens actually moved to host.
    pub fn swap_out(&mut self, id: RequestId) -> usize {
        let mut seq = self.seqs.remove(&id).expect("swap_out of unknown seq");
        assert_eq!(seq.residence, KvResidence::Gpu);
        self.resident_tokens_ctr -= seq.tokens;
        self.frag_alloc_tokens -= seq.blocks.len() * self.block_tokens;
        let blocks = std::mem::take(&mut seq.blocks);
        let mut moved = 0usize;
        seq.swap_hosted = Vec::with_capacity(blocks.len());
        for (pos, &bid) in blocks.iter().enumerate() {
            let hosted = self.blocks[bid as usize].key.is_none();
            seq.swap_hosted.push(hosted);
            if hosted {
                moved += self.block_content(pos, seq.tokens);
            }
            self.drop_ref(bid);
        }
        seq.host_tokens = moved;
        seq.residence = KvResidence::Swapped;
        self.seqs.insert(id, seq);
        self.swap_out_events += 1;
        self.swapped_tokens += moved;
        if self.swapped_tokens > self.peak_swapped_tokens {
            self.peak_swapped_tokens = self.swapped_tokens;
        }
        self.debug_assert_counters();
        moved
    }

    /// Bring a swapped sequence back to GPU: hosted blocks get fresh GPU
    /// blocks (the host->GPU copy), GPU-kept blocks are re-acquired through
    /// the prefix index. Returns tokens moved from host, or `None` —
    /// nothing changes — when blocks are insufficient *or* a GPU-kept
    /// block was evicted while this sequence was out (its content exists
    /// nowhere anymore; the caller must fall back to recompute).
    pub fn swap_in(&mut self, id: RequestId) -> Option<usize> {
        {
            let seq = self.seqs.get(&id).expect("swap_in of unknown seq");
            assert_eq!(seq.residence, KvResidence::Swapped);
        }
        let (chain, swap_hosted, tokens, host_tokens) = {
            let s = self.seqs.get(&id).unwrap();
            (s.chain.clone(), s.swap_hosted.clone(), s.tokens, s.host_tokens)
        };
        let need = self.blocks_for(tokens);
        debug_assert_eq!(swap_hosted.len(), need);
        let mut blocks: Vec<BlockId> = Vec::with_capacity(need);
        let mut taken_stamps: Vec<(BlockId, Option<u64>)> = Vec::new();
        let mut fresh: Vec<BlockId> = Vec::new();
        let mut ok = true;
        for (pos, &hosted) in swap_hosted.iter().enumerate() {
            if hosted {
                match self.take_block() {
                    Some(b) => {
                        fresh.push(b);
                        blocks.push(b);
                    }
                    None => {
                        ok = false;
                        break;
                    }
                }
            } else {
                // content stayed on GPU in an indexed block; find it again
                let found = chain
                    .get(pos)
                    .and_then(|key| self.prefix_index.get(key).copied());
                match found {
                    Some(bid) => {
                        taken_stamps.push((bid, self.blocks[bid as usize].stamp));
                        self.acquire(bid);
                        blocks.push(bid);
                    }
                    None => {
                        // evicted while we were out: unrecoverable by swap
                        ok = false;
                        break;
                    }
                }
            }
        }
        if !ok {
            self.free.extend(fresh);
            for &(bid, stamp) in taken_stamps.iter().rev() {
                let blk = &mut self.blocks[bid as usize];
                blk.refs -= 1;
                if blk.refs == 0 {
                    let stamp = stamp.expect("re-acquired block was in LRU");
                    blk.stamp = Some(stamp);
                    self.lru.insert(stamp, bid);
                }
            }
            return None;
        }
        for &bid in &fresh {
            self.blocks[bid as usize].refs = 1;
        }
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.blocks = blocks;
        seq.residence = KvResidence::Gpu;
        seq.swap_hosted.clear();
        seq.host_tokens = 0;
        self.resident_tokens_ctr += tokens;
        self.frag_alloc_tokens += need * self.block_tokens;
        self.swapped_tokens -= host_tokens;
        self.swap_in_events += 1;
        self.note_peak();
        self.debug_assert_counters();
        Some(host_tokens)
    }

    /// Drop a sequence's KV entirely (recompute-mode preemption). Indexed
    /// blocks stay warm in the LRU, so the resume's re-prefill can re-hit
    /// its own prefix.
    pub fn drop_seq(&mut self, id: RequestId) {
        self.release(id);
    }

    pub fn residence(&self, id: RequestId) -> Option<KvResidence> {
        self.seqs.get(&id).map(|s| s.residence)
    }

    pub fn tokens_of(&self, id: RequestId) -> usize {
        self.seqs.get(&id).map(|s| s.tokens).unwrap_or(0)
    }

    /// Internal-fragmentation ratio: wasted tail tokens / allocated tokens
    /// over GPU-resident sequences. O(1): maintained incrementally (the
    /// logical per-sequence view — a shared block counts toward each
    /// referencing sequence, matching [`KvManager::resident_tokens`]).
    pub fn fragmentation(&self) -> f64 {
        self.debug_assert_counters();
        if self.frag_alloc_tokens == 0 {
            0.0
        } else {
            (self.frag_alloc_tokens - self.resident_tokens_ctr) as f64
                / self.frag_alloc_tokens as f64
        }
    }

    /// Cross-check the incremental counters against a full scan (debug
    /// builds only — the scan is exactly what the counters exist to
    /// avoid on the per-dispatch path).
    fn debug_assert_counters(&self) {
        #[cfg(debug_assertions)]
        {
            let mut resident = 0usize;
            let mut alloc = 0usize;
            let mut swapped = 0usize;
            for s in self.seqs.values() {
                match s.residence {
                    KvResidence::Gpu => {
                        resident += s.tokens;
                        alloc += s.blocks.len() * self.block_tokens;
                    }
                    KvResidence::Swapped => swapped += s.host_tokens,
                }
            }
            debug_assert_eq!(resident, self.resident_tokens_ctr);
            debug_assert_eq!(alloc, self.frag_alloc_tokens);
            debug_assert_eq!(swapped, self.swapped_tokens);
        }
    }

    /// Full conservation check, for property tests: every block is in
    /// exactly one of {free, LRU-warm, referenced}, refcounts equal the
    /// number of live GPU sequences holding each block, and the index maps
    /// keys only to blocks that carry them. Panics on violation.
    pub fn assert_conserved(&self) {
        let mut refs = vec![0u32; self.total_blocks];
        for s in self.seqs.values() {
            if s.residence == KvResidence::Gpu {
                for &b in &s.blocks {
                    refs[b as usize] += 1;
                }
            }
        }
        let mut seen = vec![0u32; self.total_blocks];
        for &b in &self.free {
            seen[b as usize] += 1;
            assert_eq!(refs[b as usize], 0, "free block {b} referenced");
            assert!(self.blocks[b as usize].stamp.is_none());
        }
        for (&stamp, &b) in &self.lru {
            seen[b as usize] += 1;
            assert_eq!(refs[b as usize], 0, "LRU block {b} referenced");
            assert_eq!(self.blocks[b as usize].stamp, Some(stamp));
            assert!(
                self.blocks[b as usize].key.is_some(),
                "LRU block {b} not indexed"
            );
        }
        for b in 0..self.total_blocks {
            assert_eq!(
                self.blocks[b].refs, refs[b],
                "block {b} refcount out of sync"
            );
            if refs[b] > 0 {
                seen[b] += 1;
            }
            assert_eq!(
                seen[b], 1,
                "block {b} owned by {} of {{free, lru, referenced}}",
                seen[b]
            );
        }
        for (&key, &b) in &self.prefix_index {
            assert_eq!(
                self.blocks[b as usize].key,
                Some(key),
                "index key {key:#x} maps to block {b} that does not carry it"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvManager {
        KvManager::new(160, 16) // 10 blocks
    }

    /// A chain of n distinct keys derived from a tag.
    fn chain(tag: u64, n: usize) -> Vec<u64> {
        (0..n as u64).map(|i| tag.wrapping_mul(1000) + i).collect()
    }

    #[test]
    fn allocation_and_growth() {
        let mut m = mgr();
        assert!(m.grow_to(1, 10)); // 1 block
        assert_eq!(m.used_blocks(), 1);
        assert!(m.grow_to(1, 17)); // 2 blocks
        assert_eq!(m.used_blocks(), 2);
        assert_eq!(m.tokens_of(1), 17);
        assert!(m.grow_to(1, 17)); // no-op
        assert_eq!(m.used_blocks(), 2);
        m.assert_conserved();
    }

    #[test]
    fn capacity_enforced_atomically() {
        let mut m = mgr();
        assert!(m.grow_to(1, 160));
        assert_eq!(m.free_blocks(), 0);
        assert!(!m.grow_to(2, 1));
        assert_eq!(m.tokens_of(2), 0); // rolled back
        m.release(1);
        assert_eq!(m.free_blocks(), 10);
        assert!(m.grow_to(2, 1));
        m.assert_conserved();
    }

    #[test]
    fn can_grow_accounts_existing_blocks() {
        let mut m = mgr();
        assert!(m.grow_to(1, 16));
        assert!(m.can_grow_to(1, 32));
        assert!(m.grow_to(2, 128)); // 8 blocks → 9 used
        assert!(m.can_grow_to(1, 32)); // needs 1 more, 1 free
        assert!(!m.can_grow_to(1, 48)); // needs 2 more, only 1 free
    }

    #[test]
    fn swap_out_frees_blocks_and_remembers_tokens() {
        let mut m = mgr();
        m.grow_to(1, 40);
        let moved = m.swap_out(1);
        assert_eq!(moved, 40);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.residence(1), Some(KvResidence::Swapped));
        assert_eq!(m.tokens_of(1), 40);
        assert_eq!(m.swapped_tokens, 40);

        let back = m.swap_in(1);
        assert_eq!(back, Some(40));
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(m.residence(1), Some(KvResidence::Gpu));
        assert_eq!(m.swapped_tokens, 0);
        m.assert_conserved();
    }

    #[test]
    fn swap_in_fails_when_full() {
        let mut m = mgr();
        m.grow_to(1, 40);
        m.swap_out(1);
        m.grow_to(2, 160);
        assert_eq!(m.swap_in(1), None);
        assert_eq!(m.residence(1), Some(KvResidence::Swapped));
        m.assert_conserved();
    }

    #[test]
    fn release_swapped_sequence_is_safe() {
        let mut m = mgr();
        m.grow_to(1, 16);
        m.swap_out(1);
        m.release(1);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.residence(1), None);
    }

    #[test]
    fn release_swapped_sequence_decrements_host_occupancy() {
        // the PR-7 bugfix: dropping a swapped sequence used to leave its
        // host-resident tokens accounted nowhere
        let mut m = mgr();
        m.grow_to(1, 48);
        m.grow_to(2, 32);
        assert_eq!(m.swap_out(1), 48);
        assert_eq!(m.swap_out(2), 32);
        assert_eq!(m.swapped_tokens, 80);
        assert_eq!(m.peak_swapped_tokens, 80);
        m.release(1); // drop while swapped
        assert_eq!(m.swapped_tokens, 32);
        assert_eq!(m.swap_in(2), Some(32));
        assert_eq!(m.swapped_tokens, 0);
        assert_eq!(m.peak_swapped_tokens, 80);
        m.assert_conserved();
    }

    #[test]
    fn resident_tokens_excludes_swapped() {
        let mut m = mgr();
        m.grow_to(1, 20);
        m.grow_to(2, 30);
        assert_eq!(m.resident_tokens(), 50);
        m.swap_out(1);
        assert_eq!(m.resident_tokens(), 30);
    }

    #[test]
    fn fragmentation_measured() {
        let mut m = mgr();
        m.grow_to(1, 17); // 2 blocks = 32 alloc, 17 used
        let f = m.fragmentation();
        assert!((f - 15.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn peak_usage_tracked() {
        let mut m = mgr();
        m.grow_to(1, 80);
        m.grow_to(2, 48);
        m.release(1);
        assert_eq!(m.peak_used_blocks, 8);
    }

    #[test]
    #[should_panic]
    fn grow_swapped_panics() {
        let mut m = mgr();
        m.grow_to(1, 16);
        m.swap_out(1);
        m.grow_to(1, 32);
    }

    // ------------------------- prefix sharing ----------------------------

    #[test]
    fn prefix_hit_reuses_blocks_and_skips_tokens() {
        let mut m = mgr();
        let c = chain(7, 3); // 3 full blocks = 48 prefix tokens
        // first request: 60-token prompt (+1) covering the whole chain
        let o1 = m.allocate_with_prefix(1, &c, 61).unwrap();
        assert_eq!(o1.cached_tokens, 0);
        assert_eq!(o1.fresh_blocks, 4);
        // second request, same prefix: the 3 chain blocks are warm
        let o2 = m.allocate_with_prefix(2, &c, 61).unwrap();
        assert_eq!(o2.cached_tokens, 48);
        assert_eq!(o2.cached_blocks, 3);
        assert_eq!(o2.fresh_blocks, 1);
        // physical: 4 + 1 blocks, not 8
        assert_eq!(m.used_blocks(), 5);
        // logical: both sequences count in full
        assert_eq!(m.resident_tokens(), 122);
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens_saved, 48);
        m.assert_conserved();
    }

    #[test]
    fn hit_capped_so_one_token_always_prefills() {
        let mut m = mgr();
        let c = chain(3, 2); // 32 prefix tokens
        m.allocate_with_prefix(1, &c, 33).unwrap(); // input 32 = exactly 2 blocks
        m.release(1);
        // input_len 32: a full 2-block hit would leave nothing to prefill,
        // so the hit is capped at 1 block
        let o = m.allocate_with_prefix(2, &c, 33).unwrap();
        assert_eq!(o.cached_tokens, 16);
        m.assert_conserved();
    }

    #[test]
    fn release_retains_warm_blocks_without_counting_them_used() {
        let mut m = mgr();
        let c = chain(9, 2);
        m.allocate_with_prefix(1, &c, 40).unwrap();
        m.release(1);
        // fully released for admission purposes...
        assert_eq!(m.used_blocks(), 0);
        assert_eq!(m.free_blocks(), 10);
        // ...but the 2 chain blocks stay warm and probe hot
        assert_eq!(m.warm_blocks(), 2);
        assert_eq!(m.cached_prefix_tokens(&c, 40), 32);
        m.assert_conserved();
    }

    #[test]
    fn cached_prefix_probe_matches_allocation() {
        let mut m = mgr();
        let c = chain(11, 3);
        m.allocate_with_prefix(1, &c, 61).unwrap();
        m.release(1);
        assert_eq!(m.cached_prefix_tokens(&c, 60), 48);
        let o = m.allocate_with_prefix(2, &c, 61).unwrap();
        assert_eq!(o.cached_tokens, 48);
        // empty chain probes cold
        assert_eq!(m.cached_prefix_tokens(&[], 60), 0);
    }

    #[test]
    fn shared_block_not_freed_while_reader_lives() {
        let mut m = mgr();
        let c = chain(5, 2);
        m.allocate_with_prefix(1, &c, 40).unwrap(); // 3 blocks, 2 indexed
        m.allocate_with_prefix(2, &c, 40).unwrap(); // shares the 2, 1 fresh
        m.release(1);
        // releasing 1 freed only its private block; the 2 shared chain
        // blocks still serve request 2 and stay referenced (not warm)
        assert_eq!(m.used_blocks(), 3);
        assert_eq!(m.warm_blocks(), 0);
        assert_eq!(m.cached_prefix_tokens(&c, 40), 32);
        m.assert_conserved();
        m.release(2);
        assert_eq!(m.used_blocks(), 0);
        m.assert_conserved();
    }

    #[test]
    fn lru_eviction_never_evicts_referenced_blocks() {
        let mut m = mgr(); // 10 blocks
        let warm = chain(1, 2);
        m.allocate_with_prefix(1, &warm, 40).unwrap(); // 3 blocks
        m.release(1); // 2 warm, 8+1 reclaimable
        assert_eq!(m.warm_blocks(), 2);
        let live = chain(2, 4);
        m.allocate_with_prefix(2, &live, 90).unwrap(); // 6 blocks
        // fill the rest: needs 4 more than truly free -> evicts warm blocks
        assert!(m.grow_to(3, 64)); // 4 blocks
        assert_eq!(m.free_blocks(), 0);
        assert!(m.prefix_evictions >= 1);
        // request 2's blocks were never touched
        assert_eq!(m.tokens_of(2), 90);
        m.assert_conserved();
        // and nothing can evict the referenced blocks now
        assert!(!m.grow_to(4, 16));
        m.assert_conserved();
    }

    #[test]
    fn swap_out_respects_sharing_and_moves_only_private_tokens() {
        let mut m = mgr();
        let c = chain(8, 2); // 32 shared prefix tokens
        m.allocate_with_prefix(1, &c, 61).unwrap(); // 4 blocks: 2 indexed + 2 private
        m.allocate_with_prefix(2, &c, 61).unwrap();
        // swap out 1: only its private tail moves to host
        let moved = m.swap_out(1);
        assert_eq!(moved, 61 - 32);
        assert_eq!(m.swapped_tokens, 29);
        // request 2 still sees its shared prefix intact
        assert_eq!(m.cached_prefix_tokens(&c, 60), 32);
        m.assert_conserved();
        // swap back in: shared blocks re-acquired, private re-allocated
        assert_eq!(m.swap_in(1), Some(29));
        assert_eq!(m.resident_tokens(), 122);
        m.assert_conserved();
    }

    #[test]
    fn swap_in_fails_when_kept_prefix_was_evicted() {
        let mut m = KvManager::new(96, 16); // 6 blocks
        let c = chain(4, 2);
        m.allocate_with_prefix(1, &c, 61).unwrap(); // 4 blocks (2 indexed)
        m.swap_out(1); // 2 indexed blocks -> LRU, 2 private -> host
        assert_eq!(m.warm_blocks(), 2);
        // churn through the pool so the LRU blocks get evicted
        assert!(m.grow_to(2, 96)); // all 6 blocks, evicting the warm pair
        assert_eq!(m.warm_blocks(), 0);
        m.release(2);
        // swap-in now fails: the kept prefix content is gone
        assert_eq!(m.swap_in(1), None);
        assert_eq!(m.residence(1), Some(KvResidence::Swapped));
        m.assert_conserved();
        // recompute path: drop + fresh allocate still works
        m.drop_seq(1);
        assert_eq!(m.swapped_tokens, 0);
        assert!(m.allocate_with_prefix(1, &c, 61).is_some());
        m.assert_conserved();
    }

    #[test]
    fn release_registers_output_blocks_for_next_turn() {
        let mut m = mgr();
        // turn 1: prompt 20 tokens, chain covers 3 blocks of (prompt+reply)
        let c = chain(6, 3);
        m.allocate_with_prefix(1, &c, 21).unwrap();
        assert!(m.grow_to(1, 52)); // decode to 52 tokens (3 full blocks + tail)
        m.release(1);
        // blocks 0..3 are warm: turn 2 with a longer prompt re-hits them
        let mut c2 = c.clone();
        c2.extend(chain(66, 2));
        let o = m.allocate_with_prefix(2, &c2, 81).unwrap();
        assert_eq!(o.cached_tokens, 48);
        m.assert_conserved();
    }

    #[test]
    fn empty_chain_behaves_like_private_tables() {
        let mut m = mgr();
        let o = m.allocate_with_prefix(1, &[], 40).unwrap();
        assert_eq!(o.cached_tokens, 0);
        m.release(1);
        assert_eq!(m.warm_blocks(), 0);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.prefix_lookups, 0);
        assert_eq!(m.prefix_hits, 0);
        m.assert_conserved();
    }

    #[test]
    fn failed_prefix_allocation_rolls_back_exactly() {
        let mut m = KvManager::new(64, 16); // 4 blocks
        let c = chain(2, 2);
        m.allocate_with_prefix(1, &c, 40).unwrap(); // 3 blocks
        // no room for 2 more blocks beyond the hit: 1 free, needs 40+ tokens
        let before_free = m.free_blocks();
        assert!(m.allocate_with_prefix(2, &c, 72).is_none()); // needs 5 blocks total, 2 shared + 3 fresh > 1 free
        assert_eq!(m.free_blocks(), before_free);
        assert_eq!(m.tokens_of(2), 0);
        m.assert_conserved();
    }
}
