//! Workload generation: the three evaluation datasets, latent-topic
//! structure, and pluggable arrival processes (Poisson / bursty MMPP /
//! diurnal — see [`arrivals`]).
//!
//! The paper's datasets (ShareGPT, Alpaca-PubMed-summarization,
//! Document-Write) are external downloads; we build synthetic equivalents
//! matching the input/output-length characteristics reported in the paper's
//! Fig. 1(b), with one extra, crucial ingredient: a **latent topic model**.
//! Each dataset owns `topics_per_dataset` topics; a topic has a direction in
//! embedding space, a phrase pool (for prompt text) and its own output-length
//! distribution. Prompts from the same topic are near in cosine similarity
//! *and* share an output-length distribution — exactly the empirical
//! correlation (paper Fig. 4) that SageSched's semantic-aware history
//! predictor exploits. Predictors only ever see (prompt, embedding,
//! input_len); the topic id and true distribution stay hidden ground truth.

pub mod arrivals;
pub mod trace;

use crate::config::{DatasetKind, WorkloadConfig};
use crate::core::{Request, KV_BLOCK_TOKENS};
use crate::distribution::LengthDist;
use crate::embedding::Embedding;
use crate::slo::{ClassAssigner, SloClass};
use crate::util::rng::Rng;

/// Length statistics for one dataset (lognormal parameters + clamps).
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub kind: DatasetKind,
    /// lognormal location/scale of the input length
    pub input_mu: f64,
    pub input_sigma: f64,
    pub input_min: u32,
    pub input_max: u32,
    /// dataset-level lognormal location/scale of the output length; topics
    /// perturb the location
    pub output_mu: f64,
    pub output_sigma_within: f64,
    pub output_mu_topic_spread: f64,
    pub output_min: u32,
    pub output_max: u32,
    /// Range of the per-topic *short-mode* weight: LLM outputs for a fixed
    /// prompt are strongly bimodal (paper Fig. 1(a)/Fig. 6 — a reply either
    /// ends quickly or runs long), so each topic mixes a short-completion
    /// mode (at `short_factor` × the long mode) with weight drawn here.
    pub short_weight: (f64, f64),
    /// Short mode location as a fraction of the long mode.
    pub short_factor: f64,
}

impl DatasetProfile {
    /// Characteristics per the paper's Fig. 1(b): ShareGPT mid-in/wide-out,
    /// Alpaca long-in/short-out, Write short-in/long-out.
    pub fn of(kind: DatasetKind) -> DatasetProfile {
        match kind {
            DatasetKind::ShareGpt => DatasetProfile {
                kind,
                input_mu: (180.0f64).ln(),
                input_sigma: 0.6,
                input_min: 8,
                input_max: 1024,
                output_mu: (170.0f64).ln(),
                output_sigma_within: 0.45,
                output_mu_topic_spread: 0.55,
                output_min: 4,
                output_max: 1200,
                short_weight: (0.25, 0.55),
                short_factor: 0.12,
            },
            DatasetKind::Alpaca => DatasetProfile {
                kind,
                input_mu: (1100.0f64).ln(),
                input_sigma: 0.35,
                input_min: 256,
                input_max: 3000,
                output_mu: (90.0f64).ln(),
                output_sigma_within: 0.35,
                output_mu_topic_spread: 0.5,
                output_min: 8,
                output_max: 400,
                short_weight: (0.05, 0.15),
                short_factor: 0.25,
            },
            DatasetKind::Write => DatasetProfile {
                kind,
                input_mu: (60.0f64).ln(),
                input_sigma: 0.5,
                input_min: 4,
                input_max: 300,
                output_mu: (380.0f64).ln(),
                output_sigma_within: 0.4,
                output_mu_topic_spread: 0.4,
                output_min: 32,
                output_max: 1600,
                short_weight: (0.10, 0.35),
                short_factor: 0.10,
            },
        }
    }
}

/// One latent topic: embedding direction + conditional length distributions.
#[derive(Clone, Debug)]
pub struct Topic {
    pub dataset: DatasetKind,
    pub id: usize,
    pub direction: Embedding,
    /// lognormal location of this topic's *long* output mode
    pub output_mu: f64,
    pub output_sigma: f64,
    /// probability of the short-completion mode
    pub short_weight: f64,
    /// lognormal location of the short mode
    pub short_mu: f64,
    /// discretized ground-truth output distribution (for oracle / fig4)
    pub true_dist: LengthDist,
    /// phrase stem used to synthesize prompt text
    pub stem: String,
    profile: DatasetProfile,
}

impl Topic {
    fn sample_output(&self, rng: &mut Rng) -> u32 {
        let o = if rng.f64() < self.short_weight {
            rng.lognormal(self.short_mu, 0.35)
        } else {
            rng.lognormal(self.output_mu, self.output_sigma)
        };
        (o.round() as u32).clamp(self.profile.output_min, self.profile.output_max)
    }

    fn sample_input(&self, rng: &mut Rng) -> u32 {
        let i = rng.lognormal(self.profile.input_mu, self.profile.input_sigma);
        (i.round() as u32).clamp(self.profile.input_min, self.profile.input_max)
    }
}

/// Discretize a sampling process into a support of `n` quantile points.
/// Monte-Carlo keeps this dependency-free and exact enough (sample count
/// >> support points).
fn discretize_sampler(
    mut sample: impl FnMut(&mut Rng) -> f64,
    n: usize,
    rng: &mut Rng,
) -> LengthDist {
    let mut samples = Vec::with_capacity(4096);
    for _ in 0..4096 {
        samples.push(sample(rng));
    }
    LengthDist::from_samples(&samples).compress(n)
}

const STEM_WORDS: [&str; 24] = [
    "galaxies", "recipes", "contracts", "proteins", "poems", "engines",
    "markets", "theorems", "violins", "glaciers", "novels", "circuits",
    "gardens", "planets", "statutes", "enzymes", "ballads", "turbines",
    "auctions", "lemmas", "cellos", "fjords", "essays", "antennas",
];

fn dataset_stem(kind: DatasetKind, topic_id: usize, rng: &mut Rng) -> String {
    let noun = STEM_WORDS[topic_id % STEM_WORDS.len()];
    let salt = rng.below(1000);
    match kind {
        DatasetKind::ShareGpt => {
            format!("let's chat about {noun} and related questions ({salt})")
        }
        DatasetKind::Alpaca => {
            format!("summarize the following article about {noun} ({salt})")
        }
        DatasetKind::Write => {
            format!("write a long detailed document about {noun} ({salt})")
        }
    }
}

/// Build the post-drift topic set: within each dataset, rotate the
/// *length*-related fields (long/short modes, short weight, ground-truth
/// distribution) among its topics by half the block while keeping every
/// topic's embedding direction, stem, and profile in place. Prompts still
/// look identical to the predictor — same cosine neighbourhoods — but the
/// lengths those neighbourhoods imply are now wrong, so a history window
/// full of pre-drift observations confidently mispredicts until it turns
/// over. Deterministic and RNG-free: drift never perturbs seeded streams.
fn remap_topic_lengths(topics: &[Topic]) -> Vec<Topic> {
    let mut out = topics.to_vec();
    let datasets: Vec<DatasetKind> = {
        let mut ds = Vec::new();
        for t in topics {
            if !ds.contains(&t.dataset) {
                ds.push(t.dataset);
            }
        }
        ds
    };
    for kind in datasets {
        let block: Vec<usize> = topics
            .iter()
            .enumerate()
            .filter(|(_, t)| t.dataset == kind)
            .map(|(i, _)| i)
            .collect();
        let n = block.len();
        if n < 2 {
            continue;
        }
        let shift = (n / 2).max(1);
        for (j, &dst) in block.iter().enumerate() {
            let src = &topics[block[(j + shift) % n]];
            let t = &mut out[dst];
            t.output_mu = src.output_mu;
            t.output_sigma = src.output_sigma;
            t.short_weight = src.short_weight;
            t.short_mu = src.short_mu;
            t.true_dist = src.true_dist.clone();
        }
    }
    out
}

/// The generated workload: requests sorted by arrival time.
#[derive(Clone, Debug)]
pub struct Workload {
    pub requests: Vec<Request>,
    pub topics: Vec<Topic>,
}

/// Total-context ceiling for a session: once the conversation-so-far plus
/// the next user message would exceed this, the session retires instead of
/// sending another turn. Matches the largest single-shot prompt the dataset
/// profiles emit (Alpaca's `input_max`), so session traffic never needs
/// more KV headroom than the worst single-shot request.
const SESSION_CONTEXT_CAP: u32 = 3000;

/// Content key for block `pos` of a prefix owned by `owner` (a system-prompt
/// pool or one session's conversation) — splitmix64-style hash so distinct
/// (owner, pos) pairs collide with negligible probability.
fn chain_key(owner: u64, pos: usize) -> u64 {
    let mut z = owner ^ (pos as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One in-flight conversation: a user who keeps returning with the whole
/// exchange so far as a growing shared prefix.
struct SessionState {
    topic_idx: usize,
    /// Identifies this dataset's system-prompt pool entry (shared across
    /// every session drawing the same pool index).
    pool_key: u64,
    /// Identifies this session's private conversation content.
    session_salt: u64,
    /// The class the session was admitted under (a conversation keeps its
    /// latency tier across turns).
    slo: SloClass,
    /// Tokens of conversation so far (system prompt + all prompts+replies).
    prefix_len: u32,
    turns_left: u32,
    turn: u32,
    /// Arrival time of the next turn (previous turn + think time).
    next_at: f64,
}

/// Workload generator: builds topics once, then streams requests paced by
/// the configured [`arrivals::ArrivalProcess`].
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    topics: Vec<Topic>,
    /// post-drift topic set (same directions/stems, remapped length
    /// profiles); `None` when drift is off or `remap_topics` is false
    drifted: Option<Vec<Topic>>,
    /// request index at which the drift applies
    drift_at: usize,
    arrivals: Box<dyn arrivals::ArrivalProcess>,
    rng: Rng,
    /// SLO-class stamping stream — its own RNG so the class mix never
    /// perturbs the arrival/sampling streams of a seeded trace.
    slo: ClassAssigner,
    /// Session-structure stream (initiation coin, pools, think times, turn
    /// lengths) — dedicated so that with sessions disabled *nothing* here
    /// is drawn and seeded single-shot traces stay byte-identical.
    session_rng: Rng,
    /// Conversations waiting out a think time.
    sessions: Vec<SessionState>,
    /// Arrival time of the next session-or-single-shot *initiation*, drawn
    /// from the arrival process one step ahead so it can be interleaved
    /// with pending session turns in time order.
    next_init: Option<f64>,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig, seed: u64) -> WorkloadGen {
        // topics come from the *topic* seed: every generator over the same
        // WorkloadConfig sees the same topic universe regardless of its
        // request-stream seed (pre-warm corpora must match serving traces)
        let mut rng = Rng::new(cfg.topic_seed ^ 0x5eed_0001);
        let mut topics = Vec::new();
        // post-drift-mix datasets need topics too; appending them *after*
        // the base mix leaves the existing topic universe untouched
        let mut kinds: Vec<DatasetKind> = cfg.mix.iter().map(|(k, _)| *k).collect();
        for (k, _) in &cfg.drift.mix {
            if !kinds.contains(k) {
                kinds.push(*k);
            }
        }
        for kind in &kinds {
            let profile = DatasetProfile::of(*kind);
            // hierarchical topics: a few super-topics per dataset, each with
            // related sub-topics (cosine ~0.6 apart, partially-related
            // output statistics). This mirrors real prompt populations —
            // and gives the fig4 middle similarity band its semantics.
            let n_super = (cfg.topics_per_dataset / 4).max(1);
            let supers: Vec<(Embedding, f64)> = (0..n_super)
                .map(|_| {
                    (
                        Embedding::random_unit(cfg.embed_dim, &mut rng),
                        profile.output_mu
                            + rng.normal() * profile.output_mu_topic_spread,
                    )
                })
                .collect();
            for t in 0..cfg.topics_per_dataset {
                let (super_dir, super_mu) = &supers[t % n_super];
                let direction = super_dir.perturbed(0.10, &mut rng);
                let output_mu =
                    super_mu + rng.normal() * profile.output_mu_topic_spread * 0.45;
                let output_sigma = profile.output_sigma_within;
                let short_weight =
                    rng.range_f64(profile.short_weight.0, profile.short_weight.1);
                let short_mu = output_mu + profile.short_factor.ln();
                let (lo, hi) = (profile.output_min as f64, profile.output_max as f64);
                let true_dist = discretize_sampler(
                    |r| {
                        let o = if r.f64() < short_weight {
                            r.lognormal(short_mu, 0.35)
                        } else {
                            r.lognormal(output_mu, output_sigma)
                        };
                        o.round().clamp(lo, hi)
                    },
                    32,
                    &mut rng,
                );
                let stem = dataset_stem(*kind, t, &mut rng);
                topics.push(Topic {
                    dataset: *kind,
                    id: topics.len(),
                    direction,
                    output_mu,
                    output_sigma,
                    short_weight,
                    short_mu,
                    true_dist,
                    stem,
                    profile: profile.clone(),
                });
            }
        }
        // derive the post-drift topic set *without* consuming any RNG, so
        // enabling drift never perturbs arrivals or pre-drift sampling
        let drifted = if cfg.drift.enabled() && cfg.drift.remap_topics && cfg.n_requests > 0
        {
            Some(remap_topic_lengths(&topics))
        } else {
            None
        };
        let drift_at = (cfg.drift.at_fraction * cfg.n_requests as f64).floor() as usize;
        // switch to the request-stream seed for arrivals/sampling
        let rng = Rng::new(seed ^ 0x5eed_0002);
        let arrivals = arrivals::make_arrival_process(&cfg);
        let slo = ClassAssigner::new(&cfg.slo_mix, seed);
        let session_rng = Rng::new(seed ^ 0x5e55_0001);
        WorkloadGen {
            cfg,
            topics,
            drifted,
            drift_at,
            arrivals,
            rng,
            slo,
            session_rng,
            sessions: Vec::new(),
            next_init: None,
            next_id: 0,
            clock: 0.0,
        }
    }

    /// True once `drift_at` requests have been emitted (always false with
    /// drift disabled or in streaming mode with `n_requests == 0`).
    pub fn drift_active(&self) -> bool {
        self.cfg.drift.enabled()
            && self.cfg.n_requests > 0
            && self.next_id as usize >= self.drift_at
    }

    fn active_topics(&self) -> &[Topic] {
        match &self.drifted {
            Some(d) if self.drift_active() => d,
            _ => &self.topics,
        }
    }

    fn active_mix(&self) -> &[(DatasetKind, f64)] {
        if self.drift_active() && !self.cfg.drift.mix.is_empty() {
            &self.cfg.drift.mix
        } else {
            &self.cfg.mix
        }
    }

    pub fn topics(&self) -> &[Topic] {
        &self.topics
    }

    /// Topics belonging to one dataset.
    pub fn topics_of(&self, kind: DatasetKind) -> Vec<&Topic> {
        self.topics.iter().filter(|t| t.dataset == kind).collect()
    }

    /// Sample the next request (advances the arrival-process clock). With
    /// sessions enabled, initiations drawn from the arrival process are
    /// interleaved in time order with the returning turns of open sessions;
    /// disabled, this is exactly the original single-shot stream.
    pub fn next_request(&mut self) -> Request {
        if !self.cfg.sessions.enabled {
            let gap = self.arrivals.next_gap(self.clock, &mut self.rng);
            self.clock += gap;
            return self.request_at(self.clock);
        }
        // one-step lookahead on the arrival process so pending turns can
        // jump ahead of later initiations
        let init_at = *self.next_init.get_or_insert_with(|| {
            self.clock + self.arrivals.next_gap(self.clock, &mut self.rng)
        });
        let next_turn = self
            .sessions
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.next_at.partial_cmp(&b.next_at).unwrap()
            })
            .map(|(i, s)| (i, s.next_at));
        match next_turn {
            Some((i, at)) if at < init_at => {
                self.clock = self.clock.max(at);
                self.session_turn(i)
            }
            _ => {
                self.next_init = None;
                self.clock = init_at;
                self.initiate(init_at)
            }
        }
    }

    /// Prefix token-key chain covering every full block of `total_tokens`
    /// of this session's context: leading system-prompt blocks are keyed by
    /// the shared pool, the rest by the session's private salt. Key is a
    /// pure function of (owner, position), so successive turns of one
    /// session — and initial blocks of sibling sessions on the same pool —
    /// produce byte-identical leading chains.
    fn session_chain(&self, s: &SessionState, total_tokens: u32) -> Vec<u64> {
        let bt = KV_BLOCK_TOKENS as u32;
        let sys = self.cfg.sessions.system_prompt_tokens;
        (0..(total_tokens / bt) as usize)
            .map(|pos| {
                let owner = if (pos as u32 + 1) * bt <= sys {
                    s.pool_key
                } else {
                    s.session_salt
                };
                chain_key(owner, pos)
            })
            .collect()
    }

    /// Handle one arrival-process initiation: with probability
    /// `prefix_share` it opens a session (first turn carries the shared
    /// system prompt and seeds the conversation); otherwise it is a plain
    /// single-shot request.
    fn initiate(&mut self, arrival: f64) -> Request {
        let mut req = self.request_at(arrival);
        if self.session_rng.f64() >= self.cfg.sessions.prefix_share {
            return req;
        }
        let sc = self.cfg.sessions.clone();
        let ds_idx = DatasetKind::ALL
            .iter()
            .position(|&k| k == req.dataset)
            .unwrap_or(0) as u64;
        let pool_idx = self.session_rng.below(sc.prompts_per_dataset as u64);
        let pool_key = chain_key(0x7001_5eed_u64 ^ (ds_idx << 32), pool_idx as usize);
        let session_salt = self.session_rng.next_u64() | 1;
        // geometric turn count with mean `turns_mean`
        let go_on = 1.0 - 1.0 / sc.turns_mean.max(1.0);
        let mut extra_turns = 0u32;
        while self.session_rng.f64() < go_on && extra_turns < 64 {
            extra_turns += 1;
        }
        // the shared system prompt precedes the user's first message
        req.input_len += sc.system_prompt_tokens;
        let mut s = SessionState {
            topic_idx: req.topic,
            pool_key,
            session_salt,
            slo: req.slo,
            prefix_len: req.input_len + req.true_output_len,
            turns_left: extra_turns,
            turn: 1,
            next_at: arrival + self.session_rng.exp(1.0 / sc.think_mean.max(1e-9)),
        };
        req.prefix_key = self.session_chain(&s, req.input_len + req.true_output_len);
        if s.turns_left > 0 {
            s.turn += 1;
            self.sessions.push(s);
        }
        req
    }

    /// Emit the pending turn of session `i`: the whole conversation so far
    /// returns as the prompt prefix, plus a fresh user message.
    fn session_turn(&mut self, i: usize) -> Request {
        let arrival = self.sessions[i].next_at;
        let topic_idx = self.sessions[i].topic_idx;
        let topic = self.active_topics()[topic_idx].clone();
        let user_tokens = topic.sample_input(&mut self.session_rng);
        let over_cap =
            self.sessions[i].prefix_len + user_tokens > SESSION_CONTEXT_CAP;
        if over_cap {
            // context window exhausted: the conversation retires and the
            // generator moves on to whatever is due next
            self.sessions.swap_remove(i);
            return self.next_request();
        }
        let input_len = self.sessions[i].prefix_len + user_tokens;
        let true_output_len = topic.sample_output(&mut self.session_rng);
        let embedding = topic
            .direction
            .perturbed(self.cfg.embed_sigma, &mut self.session_rng);
        let (prompt, slo, prefix_key) = {
            let s = &self.sessions[i];
            (
                format!(
                    "{} session-{:x} turn-{} len-{user_tokens}",
                    topic.stem, s.session_salt, s.turn
                ),
                s.slo,
                self.session_chain(s, input_len + true_output_len),
            )
        };
        // advance or retire the session
        {
            let think = self.session_rng.exp(1.0 / self.cfg.sessions.think_mean.max(1e-9));
            let s = &mut self.sessions[i];
            s.prefix_len = input_len + true_output_len;
            s.turn += 1;
            s.turns_left -= 1;
            s.next_at = arrival + think;
            if s.turns_left == 0 {
                self.sessions.swap_remove(i);
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        Request {
            id,
            prompt,
            input_len,
            true_output_len,
            arrival,
            dataset: topic.dataset,
            topic: topic_idx,
            embedding,
            true_dist: Some(topic.true_dist.clone()),
            slo,
            prefix_key,
        }
    }

    /// Sample a request with an explicit arrival time (used by figure
    /// benches needing deterministic arrivals).
    pub fn request_at(&mut self, arrival: f64) -> Request {
        let mix = self.active_mix();
        let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
        let kind = mix[self.rng.categorical(&weights)].0;
        let topic_ids: Vec<usize> = self
            .topics
            .iter()
            .enumerate()
            .filter(|(_, t)| t.dataset == kind)
            .map(|(i, _)| i)
            .collect();
        let topic_idx = *self.rng.choose(&topic_ids);
        self.sample_from_topic(topic_idx, arrival)
    }

    /// Sample a request from a specific topic (fig4 uses this to replay one
    /// prompt many times). Post-drift, the topic's length profile comes
    /// from the remapped set — its embedding direction and stem do not
    /// change, which is exactly what poisons stale history.
    pub fn sample_from_topic(&mut self, topic_idx: usize, arrival: f64) -> Request {
        let topic = self.active_topics()[topic_idx].clone();
        let input_len = topic.sample_input(&mut self.rng);
        let true_output_len = topic.sample_output(&mut self.rng);
        let embedding = topic.direction.perturbed(self.cfg.embed_sigma, &mut self.rng);
        let salt = self.rng.below(100_000);
        let prompt = format!("{} variant-{salt} len-{input_len}", topic.stem);
        let id = self.next_id;
        self.next_id += 1;
        Request {
            id,
            prompt,
            input_len,
            true_output_len,
            arrival,
            dataset: topic.dataset,
            topic: topic_idx,
            embedding,
            true_dist: Some(topic.true_dist.clone()),
            slo: self.slo.next_class(),
            prefix_key: Vec::new(),
        }
    }

    /// Generate the full workload of `cfg.n_requests` requests.
    pub fn generate(mut self) -> Workload {
        let n = self.cfg.n_requests;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            requests.push(self.next_request());
        }
        Workload { requests, topics: self.topics }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn gen(kind: DatasetKind, n: usize) -> Workload {
        let mut cfg = WorkloadConfig::single(kind);
        cfg.n_requests = n;
        WorkloadGen::new(cfg, 7).generate()
    }

    #[test]
    fn arrival_times_sorted_and_poisson_rate() {
        let w = gen(DatasetKind::ShareGpt, 2000);
        for pair in w.requests.windows(2) {
            assert!(pair[0].arrival <= pair[1].arrival);
        }
        let span = w.requests.last().unwrap().arrival;
        let rate = 2000.0 / span;
        assert!((rate - 8.0).abs() < 0.8, "rate={rate}");
    }

    #[test]
    fn dataset_length_characteristics() {
        // the Fig 1(b) shape: alpaca long-in/short-out, write the reverse
        let alpaca = gen(DatasetKind::Alpaca, 500);
        let write = gen(DatasetKind::Write, 500);
        let ai = mean(&alpaca.requests.iter().map(|r| r.input_len as f64).collect::<Vec<_>>());
        let ao = mean(&alpaca.requests.iter().map(|r| r.true_output_len as f64).collect::<Vec<_>>());
        let wi = mean(&write.requests.iter().map(|r| r.input_len as f64).collect::<Vec<_>>());
        let wo = mean(&write.requests.iter().map(|r| r.true_output_len as f64).collect::<Vec<_>>());
        assert!(ai > 4.0 * wi, "alpaca in {ai} vs write in {wi}");
        assert!(wo > 3.0 * ao, "write out {wo} vs alpaca out {ao}");
    }

    #[test]
    fn same_topic_embeddings_similar_cross_topic_less() {
        let w = gen(DatasetKind::ShareGpt, 400);
        let mut same = Vec::new();
        let mut cross = Vec::new();
        for a in &w.requests[..80] {
            for b in &w.requests[..80] {
                if a.id >= b.id {
                    continue;
                }
                let s = a.embedding.cosine(&b.embedding) as f64;
                if a.topic == b.topic {
                    same.push(s);
                } else {
                    cross.push(s);
                }
            }
        }
        assert!(!same.is_empty() && !cross.is_empty());
        assert!(
            mean(&same) > mean(&cross) + 0.3,
            "same {} cross {}",
            mean(&same),
            mean(&cross)
        );
        assert!(mean(&same) > 0.8, "same-topic similarity too low");
    }

    #[test]
    fn same_topic_output_lengths_share_distribution() {
        // Fig 4's premise: within-topic output length distributions are
        // closer (in W1) than across topics.
        let mut cfg = WorkloadConfig::single(DatasetKind::Write);
        cfg.n_requests = 0;
        let mut g = WorkloadGen::new(cfg, 9);
        let topic_a = 0;
        let topic_b = 3;
        let mut a1 = Vec::new();
        let mut a2 = Vec::new();
        let mut b = Vec::new();
        for i in 0..200 {
            a1.push(g.sample_from_topic(topic_a, i as f64).true_output_len as f64);
            a2.push(g.sample_from_topic(topic_a, i as f64).true_output_len as f64);
            b.push(g.sample_from_topic(topic_b, i as f64).true_output_len as f64);
        }
        let d_a1 = LengthDist::from_samples(&a1);
        let d_a2 = LengthDist::from_samples(&a2);
        let d_b = LengthDist::from_samples(&b);
        assert!(d_a1.w1_distance(&d_a2) < d_a1.w1_distance(&d_b));
    }

    #[test]
    fn true_dist_mean_tracks_samples() {
        let w = gen(DatasetKind::ShareGpt, 600);
        // group by topic; empirical mean of true_output_len should be near
        // the topic's true_dist mean
        let mut by_topic: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
        for r in &w.requests {
            by_topic.entry(r.topic).or_default().push(r.true_output_len as f64);
        }
        let mut checked = 0;
        for (topic, lens) in by_topic {
            if lens.len() < 25 {
                continue;
            }
            let emp = mean(&lens);
            let td = w.topics[topic].true_dist.mean();
            assert!(
                (emp - td).abs() / td < 0.35,
                "topic {topic}: emp {emp} vs dist {td}"
            );
            checked += 1;
        }
        assert!(checked > 0);
    }

    #[test]
    fn ids_unique_and_dense() {
        let w = gen(DatasetKind::Write, 100);
        let ids: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen(DatasetKind::ShareGpt, 50);
        let b = gen(DatasetKind::ShareGpt, 50);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.true_output_len, y.true_output_len);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn drift_remaps_topic_lengths_but_not_embeddings() {
        let mut cfg = WorkloadConfig::single(DatasetKind::Write);
        cfg.n_requests = 1200;
        cfg.drift.at_fraction = 0.5;
        let w = WorkloadGen::new(cfg.clone(), 11).generate();
        let base = WorkloadGen::new(
            WorkloadConfig { drift: Default::default(), ..cfg.clone() },
            11,
        )
        .generate();
        // pre-drift segment is byte-identical to the undrifted trace
        for (a, b) in w.requests[..600].iter().zip(&base.requests[..600]) {
            assert_eq!(a.true_output_len, b.true_output_len);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.embedding, b.embedding);
        }
        // arrivals and topic assignment never change — only the lengths do
        let mut changed = 0;
        for (a, b) in w.requests[600..].iter().zip(&base.requests[600..]) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.topic, b.topic);
            assert_eq!(a.embedding, b.embedding);
            if a.true_output_len != b.true_output_len {
                changed += 1;
            }
        }
        assert!(changed > 300, "only {changed}/600 post-drift lengths changed");
        // per-topic ground truth actually moved for at least half the topics
        let mut moved = 0;
        let mut total = 0;
        for (post, pre) in w.requests[600..].iter().zip(&base.requests[600..]) {
            if post.topic == pre.topic && total < 50 {
                let d = post.true_dist.as_ref().unwrap();
                let p = pre.true_dist.as_ref().unwrap();
                total += 1;
                if d.w1_distance(p) > 1.0 {
                    moved += 1;
                }
            }
        }
        assert!(moved * 2 >= total, "true_dist moved for {moved}/{total}");
    }

    #[test]
    fn drift_mix_switches_datasets_post_shift() {
        let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
        cfg.n_requests = 400;
        cfg.drift.at_fraction = 0.5;
        cfg.drift.remap_topics = false;
        cfg.drift.mix = vec![(DatasetKind::Alpaca, 1.0)];
        let w = WorkloadGen::new(cfg, 13).generate();
        assert!(w.requests[..200].iter().all(|r| r.dataset == DatasetKind::ShareGpt));
        assert!(w.requests[200..].iter().all(|r| r.dataset == DatasetKind::Alpaca));
    }

    #[test]
    fn drift_disabled_is_identity() {
        let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
        cfg.n_requests = 150;
        let a = WorkloadGen::new(cfg.clone(), 3).generate();
        cfg.drift.remap_topics = true; // at_fraction still 0 => off
        let b = WorkloadGen::new(cfg, 3).generate();
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.true_output_len, y.true_output_len);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn nonstationary_arrivals_deterministic_and_sorted() {
        use crate::config::ArrivalKind;
        for kind in ArrivalKind::ALL {
            let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
            cfg.n_requests = 400;
            cfg.arrival.kind = kind;
            let a = WorkloadGen::new(cfg.clone(), 5).generate();
            let b = WorkloadGen::new(cfg, 5).generate();
            for (x, y) in a.requests.iter().zip(&b.requests) {
                assert_eq!(x.arrival, y.arrival, "{kind:?} arrivals not reproducible");
                assert_eq!(x.true_output_len, y.true_output_len);
            }
            for pair in a.requests.windows(2) {
                assert!(pair[0].arrival < pair[1].arrival, "{kind:?} not increasing");
            }
        }
    }

    #[test]
    fn sessions_disabled_is_byte_identical() {
        // the session RNG stream must never be touched when disabled
        let mut cfg = WorkloadConfig::default();
        cfg.n_requests = 200;
        let base = WorkloadGen::new(cfg.clone(), 17).generate();
        cfg.sessions.prefix_share = 0.9; // everything but `enabled`
        cfg.sessions.turns_mean = 8.0;
        let off = WorkloadGen::new(cfg, 17).generate();
        for (a, b) in base.requests.iter().zip(&off.requests) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.true_output_len, b.true_output_len);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.slo, b.slo);
            assert!(a.prefix_key.is_empty());
            assert!(b.prefix_key.is_empty());
        }
    }

    #[test]
    fn session_turns_extend_a_consistent_prefix_chain() {
        let mut cfg = WorkloadConfig::single(DatasetKind::ShareGpt);
        cfg.n_requests = 400;
        cfg.sessions.enabled = true;
        cfg.sessions.prefix_share = 1.0;
        let w = WorkloadGen::new(cfg, 23).generate();
        // group turns by their first *private* key (the session identity
        // is not exposed on Request, but the chain is)
        let mut chains: std::collections::BTreeMap<u64, Vec<&Request>> = Default::default();
        let sys_blocks = 256 / 16;
        for r in &w.requests {
            if r.prefix_key.len() > sys_blocks {
                chains.entry(r.prefix_key[sys_blocks]).or_default().push(r);
            }
        }
        let mut multi_turn = 0;
        for turns in chains.values() {
            if turns.len() < 2 {
                continue;
            }
            multi_turn += 1;
            let mut sorted: Vec<&&Request> = turns.iter().collect();
            sorted.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
            for pair in sorted.windows(2) {
                let (a, b) = (pair[0], pair[1]);
                // a later turn's prompt contains the earlier conversation:
                // chains agree on the earlier turn's full extent
                assert!(b.input_len > a.input_len);
                assert!(b.prefix_key.len() >= a.prefix_key.len());
                assert_eq!(
                    &b.prefix_key[..a.prefix_key.len()],
                    &a.prefix_key[..],
                    "turn chains diverge"
                );
                // the same SLO class rides the whole conversation
                assert_eq!(a.slo, b.slo);
            }
        }
        assert!(multi_turn > 5, "only {multi_turn} multi-turn sessions");
        // arrivals stay sorted through the interleave
        for pair in w.requests.windows(2) {
            assert!(pair[0].arrival <= pair[1].arrival);
        }
        // ids dense + unique
        let mut ids: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.requests.len());
    }

    #[test]
    fn sessions_share_system_prompt_pools_across_users() {
        let mut cfg = WorkloadConfig::single(DatasetKind::Write);
        cfg.n_requests = 300;
        cfg.sessions.enabled = true;
        cfg.sessions.prefix_share = 1.0;
        cfg.sessions.prompts_per_dataset = 2;
        let w = WorkloadGen::new(cfg, 29).generate();
        // every session's first chain key identifies its system-prompt
        // pool; with 2 pools there are exactly 2 distinct leading keys
        let leading: std::collections::BTreeSet<u64> = w
            .requests
            .iter()
            .filter(|r| !r.prefix_key.is_empty())
            .map(|r| r.prefix_key[0])
            .collect();
        assert_eq!(leading.len(), 2, "expected 2 shared pools, got {leading:?}");
        // and context never exceeds the generator's cap
        for r in &w.requests {
            assert!(r.input_len <= SESSION_CONTEXT_CAP);
        }
    }

    #[test]
    fn session_traces_deterministic_given_seed() {
        let mut cfg = WorkloadConfig::default();
        cfg.n_requests = 250;
        cfg.sessions.enabled = true;
        let a = WorkloadGen::new(cfg.clone(), 31).generate();
        let b = WorkloadGen::new(cfg, 31).generate();
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.prefix_key, y.prefix_key);
        }
    }

    #[test]
    fn arrival_kinds_produce_distinct_traces() {
        let mut base = WorkloadConfig::single(DatasetKind::ShareGpt);
        base.n_requests = 200;
        let poisson = WorkloadGen::new(base.clone(), 5).generate();
        let mut bursty_cfg = base.clone();
        bursty_cfg.arrival.kind = crate::config::ArrivalKind::Mmpp;
        let bursty = WorkloadGen::new(bursty_cfg, 5).generate();
        let differs = poisson
            .requests
            .iter()
            .zip(&bursty.requests)
            .any(|(a, b)| a.arrival != b.arrival);
        assert!(differs, "mmpp trace identical to poisson");
    }
}
