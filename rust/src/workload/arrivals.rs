//! Pluggable arrival processes pacing the workload's request stream.
//!
//! The paper's evaluation (and PR 1's cluster layer) assumed homogeneous
//! Poisson arrivals, but uncertainty-aware scheduling claims only become
//! meaningful under *non-stationary* demand — bursty on/off traffic and
//! diurnal load swings are exactly where routing by predicted cost should
//! pay off (cf. LLMSched and adaptively-robust inference scheduling). Every
//! process here is normalized to the same **long-run mean rate** (the
//! `rps` in [`WorkloadConfig`]), so traces generated under different kinds
//! carry the same total load and reports stay comparable: the kind only
//! redistributes arrivals in time.
//!
//! All sampling goes through the caller-supplied [`Rng`], so a trace is a
//! pure function of `(WorkloadConfig, seed)` regardless of process kind.

use crate::config::{ArrivalConfig, ArrivalKind, WorkloadConfig};
use crate::util::rng::Rng;

/// A stateful arrival process: hands out inter-arrival gaps one at a time.
///
/// Implementations must be deterministic given the same `(now, rng)`
/// sequence so that workload generation stays exactly reproducible.
pub trait ArrivalProcess: Send {
    fn name(&self) -> &'static str;

    /// Sample the gap (seconds, > 0) between the arrival at `now` and the
    /// next one. `now` is the absolute clock of the previous arrival.
    fn next_gap(&mut self, now: f64, rng: &mut Rng) -> f64;

    /// Long-run mean arrival rate (requests/second).
    fn mean_rate(&self) -> f64;
}

/// Homogeneous Poisson arrivals at a fixed rate (the classic default).
pub struct PoissonArrivals {
    rps: f64,
}

impl PoissonArrivals {
    pub fn new(rps: f64) -> PoissonArrivals {
        PoissonArrivals { rps }
    }
}

impl ArrivalProcess for PoissonArrivals {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn next_gap(&mut self, _now: f64, rng: &mut Rng) -> f64 {
        rng.exp(self.rps.max(1e-9))
    }

    fn mean_rate(&self) -> f64 {
        self.rps
    }
}

/// Two-state Markov-modulated Poisson process (on/off bursts).
///
/// The process alternates between an ON (burst) state and an OFF (quiet)
/// state with exponentially distributed sojourn times; arrivals are Poisson
/// at `rate_on` / `rate_off` within a state. Rates are derived from the
/// target mean: with `p_on = on_mean / (on_mean + off_mean)` the OFF rate is
/// `rps / (p_on * burst_factor + (1 - p_on))` and the ON rate is
/// `burst_factor` times that, so the long-run mean stays at `rps`.
pub struct MmppArrivals {
    mean_rps: f64,
    rate_on: f64,
    rate_off: f64,
    on_mean: f64,
    off_mean: f64,
    /// Whether the process is currently in the ON (burst) state.
    on: bool,
    /// Absolute time at which the current state ends.
    state_until: f64,
}

impl MmppArrivals {
    pub fn new(mean_rps: f64, burst_factor: f64, on_mean: f64, off_mean: f64) -> MmppArrivals {
        assert!(burst_factor >= 1.0, "burst_factor must be >= 1");
        assert!(on_mean > 0.0 && off_mean > 0.0, "state durations must be positive");
        let p_on = on_mean / (on_mean + off_mean);
        let rate_off = mean_rps / (p_on * burst_factor + (1.0 - p_on));
        MmppArrivals {
            mean_rps,
            rate_on: rate_off * burst_factor,
            rate_off,
            on_mean,
            off_mean,
            // state_until = 0 makes the first call at t=0 enter the ON
            // state deterministically, so short traces always see a burst
            on: false,
            state_until: 0.0,
        }
    }

    /// The (rate_on, rate_off) pair the normalization derived.
    pub fn rates(&self) -> (f64, f64) {
        (self.rate_on, self.rate_off)
    }
}

impl ArrivalProcess for MmppArrivals {
    fn name(&self) -> &'static str {
        "mmpp"
    }

    fn next_gap(&mut self, now: f64, rng: &mut Rng) -> f64 {
        let mut t = now;
        loop {
            if t >= self.state_until {
                self.on = !self.on;
                let mean = if self.on { self.on_mean } else { self.off_mean };
                self.state_until = t + rng.exp(1.0 / mean);
            }
            let rate = if self.on { self.rate_on } else { self.rate_off };
            // memorylessness lets us resample the residual gap after each
            // state switch — this is the exact MMPP construction
            let gap = rng.exp(rate.max(1e-9));
            if t + gap <= self.state_until {
                return (t + gap - now).max(1e-12);
            }
            t = self.state_until;
        }
    }

    fn mean_rate(&self) -> f64 {
        self.mean_rps
    }
}

/// Inhomogeneous Poisson with a sinusoidal rate (diurnal load cycle):
/// `rate(t) = rps * (1 + amplitude * sin(2*pi*t / period))`, sampled by
/// Lewis-Shedler thinning against the peak rate.
pub struct DiurnalArrivals {
    rps: f64,
    period: f64,
    amplitude: f64,
}

impl DiurnalArrivals {
    pub fn new(rps: f64, period: f64, amplitude: f64) -> DiurnalArrivals {
        assert!(period > 0.0, "diurnal period must be positive");
        DiurnalArrivals { rps, period, amplitude: amplitude.clamp(0.0, 0.99) }
    }

    /// Instantaneous rate at absolute time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        self.rps * (1.0 + self.amplitude * (std::f64::consts::TAU * t / self.period).sin())
    }
}

impl ArrivalProcess for DiurnalArrivals {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn next_gap(&mut self, now: f64, rng: &mut Rng) -> f64 {
        let rate_max = self.rps * (1.0 + self.amplitude);
        let mut t = now;
        loop {
            t += rng.exp(rate_max.max(1e-9));
            if rng.f64() * rate_max <= self.rate_at(t) {
                return (t - now).max(1e-12);
            }
        }
    }

    fn mean_rate(&self) -> f64 {
        self.rps
    }
}

/// Build the configured arrival process for a workload.
pub fn make_arrival_process(cfg: &WorkloadConfig) -> Box<dyn ArrivalProcess> {
    let a: &ArrivalConfig = &cfg.arrival;
    match a.kind {
        ArrivalKind::Poisson => Box::new(PoissonArrivals::new(cfg.rps)),
        ArrivalKind::Mmpp => Box::new(MmppArrivals::new(
            cfg.rps,
            a.burst_factor,
            a.burst_on_mean,
            a.burst_off_mean,
        )),
        ArrivalKind::Diurnal => {
            Box::new(DiurnalArrivals::new(cfg.rps, a.diurnal_period, a.diurnal_amplitude))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(p: &mut dyn ArrivalProcess, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += p.next_gap(t, &mut rng);
            out.push(t);
        }
        out
    }

    fn cv_of_gaps(arrivals: &[f64]) -> f64 {
        let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
        var.sqrt() / m
    }

    #[test]
    fn mmpp_normalization_preserves_mean_rate() {
        // fast-mixing states so the long-run mean converges in-test
        let mut p = MmppArrivals::new(8.0, 6.0, 2.0, 8.0);
        let (on, off) = p.rates();
        assert!(on > off);
        let arr = trace(&mut p, 20_000, 11);
        let rate = arr.len() as f64 / arr.last().unwrap();
        assert!((rate - 8.0).abs() < 1.2, "long-run rate {rate} != 8");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let mut pois = PoissonArrivals::new(8.0);
        let mut mmpp = MmppArrivals::new(8.0, 8.0, 5.0, 20.0);
        let a = trace(&mut pois, 8_000, 3);
        let b = trace(&mut mmpp, 8_000, 3);
        // Poisson gaps have CV 1; MMPP mixes two rates, inflating it
        let (cva, cvb) = (cv_of_gaps(&a), cv_of_gaps(&b));
        assert!(cva < 1.2, "poisson CV {cva}");
        assert!(cvb > cva + 0.2, "mmpp CV {cvb} not burstier than {cva}");
    }

    #[test]
    fn diurnal_rate_oscillates_with_period() {
        let p = DiurnalArrivals::new(8.0, 100.0, 0.8);
        assert!((p.rate_at(25.0) - 8.0 * 1.8).abs() < 1e-9); // peak
        assert!((p.rate_at(75.0) - 8.0 * 0.2).abs() < 1e-9); // trough
        let mut p = DiurnalArrivals::new(8.0, 100.0, 0.8);
        let arr = trace(&mut p, 20_000, 7);
        // peak half-cycles [0,50) mod 100 must collect far more arrivals
        let peak = arr.iter().filter(|&&t| t.rem_euclid(100.0) < 50.0).count();
        let trough = arr.len() - peak;
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn processes_deterministic_given_seed() {
        for kind in ArrivalKind::ALL {
            let mut cfg = WorkloadConfig::default();
            cfg.arrival.kind = kind;
            let mut a = make_arrival_process(&cfg);
            let mut b = make_arrival_process(&cfg);
            let ta = trace(a.as_mut(), 500, 42);
            let tb = trace(b.as_mut(), 500, 42);
            assert_eq!(ta, tb, "{kind:?} not deterministic");
            assert!(ta.windows(2).all(|w| w[1] > w[0]), "{kind:?} not increasing");
        }
    }

    #[test]
    fn factory_builds_configured_kind() {
        for kind in ArrivalKind::ALL {
            let mut cfg = WorkloadConfig::default();
            cfg.arrival.kind = kind;
            assert_eq!(make_arrival_process(&cfg).name(), kind.name());
        }
    }
}
