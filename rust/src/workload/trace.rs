//! Trace record / replay: serialize generated workloads to JSONL so
//! experiments are exactly reproducible across machines and the same
//! arrival sequence can be replayed against every scheduler.
//!
//! One JSON object per line per request; ground-truth fields (true output
//! length, topic distribution) are included so oracle baselines replay
//! identically.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::DatasetKind;
use crate::core::Request;
use crate::distribution::LengthDist;
use crate::embedding::Embedding;
use crate::slo::SloClass;
use crate::util::json::Json;

fn request_to_json(r: &Request) -> Json {
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        ("prompt", Json::str(r.prompt.clone())),
        ("input_len", Json::num(r.input_len as f64)),
        ("true_output_len", Json::num(r.true_output_len as f64)),
        ("arrival", Json::num(r.arrival)),
        ("dataset", Json::str(r.dataset.name())),
        ("slo", Json::str(r.slo.name())),
        ("topic", Json::num(r.topic as f64)),
        (
            "embedding",
            Json::arr(r.embedding.0.iter().map(|&x| Json::num(x as f64))),
        ),
    ];
    if !r.prefix_key.is_empty() {
        // hex strings, not numbers: the keys are full 64-bit hashes and
        // would lose precision through an f64 JSON number
        fields.push((
            "prefix_key",
            Json::arr(r.prefix_key.iter().map(|k| Json::str(format!("{k:016x}")))),
        ));
    }
    if let Some(d) = &r.true_dist {
        fields.push((
            "dist_values",
            Json::arr(d.support().iter().map(|&v| Json::num(v))),
        ));
        fields.push((
            "dist_probs",
            Json::arr(d.probs().iter().map(|&p| Json::num(p))),
        ));
    }
    Json::obj(fields)
}

fn request_from_json(j: &Json) -> Result<Request> {
    let need_num = |k: &str| -> Result<f64> {
        j.get(k).and_then(Json::as_f64).with_context(|| format!("missing field {k}"))
    };
    let dataset = DatasetKind::from_name(j.str_or("dataset", ""))
        .context("bad dataset name")?;
    // older traces predate SLO classes: default them to Standard
    let slo = match j.get("slo") {
        None => SloClass::Standard,
        Some(v) => SloClass::from_name(v.as_str().unwrap_or(""))
            .context("bad slo class name")?,
    };
    let embedding: Vec<f32> = j
        .get("embedding")
        .and_then(Json::as_arr)
        .context("missing embedding")?
        .iter()
        .filter_map(Json::as_f64)
        .map(|x| x as f32)
        .collect();
    let true_dist = match (j.get("dist_values"), j.get("dist_probs")) {
        (Some(Json::Arr(vs)), Some(Json::Arr(ps))) if vs.len() == ps.len() && !vs.is_empty() => {
            let pairs: Vec<(f64, f64)> = vs
                .iter()
                .zip(ps)
                .filter_map(|(v, p)| Some((v.as_f64()?, p.as_f64()?)))
                .collect();
            Some(LengthDist::from_weighted(&pairs))
        }
        _ => None,
    };
    Ok(Request {
        id: need_num("id")? as u64,
        prompt: j.str_or("prompt", "").to_string(),
        input_len: need_num("input_len")? as u32,
        true_output_len: need_num("true_output_len")? as u32,
        arrival: need_num("arrival")?,
        dataset,
        topic: need_num("topic")? as usize,
        embedding: Embedding(embedding),
        true_dist,
        slo,
        prefix_key: j
            .get("prefix_key")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|v| v.as_str())
                    .filter_map(|s| u64::from_str_radix(s, 16).ok())
                    .collect()
            })
            .unwrap_or_default(),
    })
}

/// Write a workload trace as JSONL.
pub fn save(path: impl AsRef<Path>, requests: &[Request]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    for r in requests {
        writeln!(f, "{}", request_to_json(r))?;
    }
    Ok(())
}

/// Load a workload trace from JSONL (sorted by arrival).
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut out = Vec::new();
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
        out.push(request_from_json(&j).with_context(|| format!("line {}", i + 1))?);
    }
    if out.is_empty() {
        bail!("empty trace {}", path.as_ref().display());
    }
    out.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::WorkloadGen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sagesched-trace-{}-{name}.jsonl", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_preserves_requests() {
        let mut cfg = WorkloadConfig::default();
        cfg.n_requests = 40;
        let wl = WorkloadGen::new(cfg, 5).generate();
        let path = tmp("roundtrip");
        save(&path, &wl.requests).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 40);
        for (a, b) in wl.requests.iter().zip(&loaded) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.true_output_len, b.true_output_len);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.dataset, b.dataset);
            assert_eq!(a.slo, b.slo);
            let cos = a.embedding.cosine(&b.embedding);
            assert!(cos > 0.9999, "embedding drift {cos}");
            let (da, db) = (a.true_dist.as_ref().unwrap(), b.true_dist.as_ref().unwrap());
            assert!(da.w1_distance(db) < 1e-6 * da.mean().max(1.0));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replayed_trace_gives_identical_experiment() {
        use crate::config::ExperimentConfig;
        use crate::serve::build_sim_coordinator;
        let mut cfg = ExperimentConfig::default();
        cfg.workload.n_requests = 60;
        let wl = WorkloadGen::new(cfg.workload.clone(), cfg.seed).generate();
        let path = tmp("replay");
        save(&path, &wl.requests).unwrap();
        let loaded = load(&path).unwrap();

        let mut c1 = build_sim_coordinator(&cfg);
        c1.run_workload(wl.requests).unwrap();
        let mut c2 = build_sim_coordinator(&cfg);
        c2.run_workload(loaded).unwrap();
        let r1 = c1.report(0.0);
        let r2 = c2.report(0.0);
        assert!((r1.ttlt.mean - r2.ttlt.mean).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmp("garbage");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
