//! Run-level metrics: TTLT / TTFT / TPOT summaries, engine counters,
//! scheduling overheads, and report emission (markdown rows + JSON) —
//! plus cluster-level aggregation ([`ClusterReport`]) for the event-driven
//! multi-replica simulation in [`crate::cluster`].

use std::collections::BTreeMap;

use crate::autoscale::ScalingEvent;
use crate::config::DatasetKind;
use crate::core::RequestOutcome;
use crate::slo::{SloClass, SloSpecs};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Per-SLO-class accounting of one run: loss counters over the full run,
/// latency summaries over the measured (post-warmup) portion, and the
/// attainment rate against the class targets.
#[derive(Clone, Debug, Default)]
pub struct SloClassStats {
    /// Goodput weight of this class (from the tier spec).
    pub weight: f64,
    pub ttft_target: f64,
    pub ttlt_target: f64,
    /// Full-run completions of this class.
    pub completed: u64,
    /// Full-run admission rejections of this class.
    pub rejected: u64,
    /// Full-run queue-timeout aborts of this class.
    pub aborted: u64,
    /// Full-run completions that met both the TTFT and TTLT targets.
    pub attained: u64,
    /// Full-run completions that met the TTFT target alone — the
    /// first-token responsiveness headline disaggregated serving
    /// optimizes for (a request may still miss its completion deadline).
    pub ttft_attained: u64,
    /// Post-warmup outcomes the summaries below cover.
    pub measured: usize,
    pub ttft: Summary,
    pub ttlt: Summary,
}

impl SloClassStats {
    /// Requests of this class the system accepted responsibility for.
    pub fn submitted(&self) -> u64 {
        self.completed + self.rejected + self.aborted
    }

    /// Fraction of *submitted* requests that completed within their SLO
    /// (a rejection or timeout is an SLO miss, not a statistical no-show).
    pub fn attainment(&self) -> f64 {
        let n = self.submitted();
        if n == 0 {
            0.0
        } else {
            self.attained as f64 / n as f64
        }
    }

    /// Fraction of *submitted* requests whose first token met the TTFT
    /// target (same denominator discipline as [`attainment`]).
    ///
    /// [`attainment`]: SloClassStats::attainment
    pub fn ttft_attainment(&self) -> f64 {
        let n = self.submitted();
        if n == 0 {
            0.0
        } else {
            self.ttft_attained as f64 / n as f64
        }
    }
}

/// Assemble the per-class stats map: loss counters indexed by
/// [`SloClass::index`], attainment judged over `all` (full-run) outcomes,
/// latency summaries over the `measured` (post-warmup) subset.
pub fn slo_class_stats(
    specs: &SloSpecs,
    measured: &[RequestOutcome],
    all: &[RequestOutcome],
    rejected_by_class: &[u64; 3],
    aborted_by_class: &[u64; 3],
) -> BTreeMap<&'static str, SloClassStats> {
    let mut map = BTreeMap::new();
    for class in SloClass::ALL {
        let spec = specs.spec(class);
        let mut s = SloClassStats {
            weight: spec.weight,
            ttft_target: spec.ttft_target,
            ttlt_target: spec.ttlt_target,
            rejected: rejected_by_class[class.index()],
            aborted: aborted_by_class[class.index()],
            ..SloClassStats::default()
        };
        for o in all.iter().filter(|o| o.slo == class) {
            s.completed += 1;
            if spec.attained(o.ttft(), o.ttlt()) {
                s.attained += 1;
            }
            if o.ttft() <= spec.ttft_target {
                s.ttft_attained += 1;
            }
        }
        let sub: Vec<&RequestOutcome> =
            measured.iter().filter(|o| o.slo == class).collect();
        s.measured = sub.len();
        s.ttft = Summary::of(&sub.iter().map(|o| o.ttft()).collect::<Vec<_>>());
        s.ttlt = Summary::of(&sub.iter().map(|o| o.ttlt()).collect::<Vec<_>>());
        map.insert(class.name(), s);
    }
    map
}

/// Full accounting of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub policy: String,
    pub predictor: String,
    pub cost_model: String,
    /// requests measured (post-warmup)
    pub measured: usize,
    pub ttlt: Summary,
    pub ttft: Summary,
    pub tpot: Summary,
    /// per-dataset TTLT
    pub ttlt_by_dataset: BTreeMap<&'static str, Summary>,
    /// Per-SLO-class latency/attainment/loss accounting (see
    /// [`slo_class_stats`]; filled by the coordinator/cluster report
    /// builders, empty when built via [`RunReport::from_outcomes`] alone).
    pub slo: BTreeMap<&'static str, SloClassStats>,
    /// end-to-end span of the measured portion (s)
    pub makespan: f64,
    /// measured request throughput (req/s)
    pub throughput: f64,
    /// total completions over the whole run (not warmup-filtered)
    pub completed: u64,
    /// requests refused at admission (queue full)
    pub rejected: u64,
    /// requests aborted after timing out in the queue
    pub aborted: u64,
    pub preemptions: u64,
    pub swap_out_events: u64,
    pub swap_in_events: u64,
    /// engine busy-time split (s)
    pub busy_decode: f64,
    pub busy_prefill: f64,
    pub busy_swap: f64,
    pub mean_utilization: f64,
    /// cumulative wallclock spent in predictor calls (s)
    pub predict_overhead: f64,
    /// cumulative wallclock spent computing priorities / sorting (s)
    pub sched_overhead: f64,
    /// decode steps executed
    pub decode_steps: u64,
    /// windowed Kendall's tau of the predictor's ranking scores against
    /// realized output lengths (0 with fewer than 2 decisive pairs)
    pub pred_tau: f64,
    /// observations currently inside the tau window
    pub pred_tau_n: u64,
    /// predictor retrieval accounting: predictions served from enough
    /// above-threshold matches / topped up by nearest-neighbour fallback /
    /// answered with the cold-start prior (all zero for predictors with
    /// no retrieval stage)
    pub pred_threshold_hits: u64,
    pub pred_fallback: u64,
    pub pred_cold: u64,
    /// Peak KV blocks allocated at any instant of the run.
    pub kv_peak_used_blocks: u64,
    /// Final internal fragmentation of the KV pool (fraction of allocated
    /// block capacity not holding tokens; 0 for an idle manager). In the
    /// cluster aggregate this is the *max* across replicas (worst case),
    /// not a sum — fractions don't add.
    pub kv_fragmentation: f64,
    /// Prefix-cache probes at admission (one per request carrying a
    /// prefix-key chain).
    pub kv_prefix_lookups: u64,
    /// Probes that found at least one warm prefix block.
    pub kv_prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped via warm prefix blocks.
    pub kv_prefill_tokens_saved: u64,
    /// Warm (unreferenced, retained) prefix blocks evicted under memory
    /// pressure.
    pub kv_prefix_evictions: u64,
    /// Peak host-side swapped-out token occupancy.
    pub kv_swapped_tokens_peak: u64,
}

impl RunReport {
    /// Build the latency summaries from outcomes (already warmup-filtered).
    pub fn from_outcomes(outcomes: &[RequestOutcome]) -> RunReport {
        let mut r = RunReport::default();
        r.measured = outcomes.len();
        let ttlt: Vec<f64> = outcomes.iter().map(|o| o.ttlt()).collect();
        let ttft: Vec<f64> = outcomes.iter().map(|o| o.ttft()).collect();
        let tpot: Vec<f64> = outcomes.iter().map(|o| o.tpot()).collect();
        r.ttlt = Summary::of(&ttlt);
        r.ttft = Summary::of(&ttft);
        r.tpot = Summary::of(&tpot);
        for ds in DatasetKind::ALL {
            let sub: Vec<f64> = outcomes
                .iter()
                .filter(|o| o.dataset == ds)
                .map(|o| o.ttlt())
                .collect();
            if !sub.is_empty() {
                r.ttlt_by_dataset.insert(ds.name(), Summary::of(&sub));
            }
        }
        if let (Some(first), Some(last)) = (
            outcomes.iter().map(|o| o.arrival).fold(None, |m: Option<f64>, x| {
                Some(m.map_or(x, |m| m.min(x)))
            }),
            outcomes.iter().map(|o| o.completion).fold(None, |m: Option<f64>, x| {
                Some(m.map_or(x, |m| m.max(x)))
            }),
        ) {
            r.makespan = last - first;
            if r.makespan > 0.0 {
                r.throughput = outcomes.len() as f64 / r.makespan;
            }
        }
        r
    }

    /// Fraction of prefix-cache probes that found warm blocks (0.0 when no
    /// request carried a prefix chain).
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        if self.kv_prefix_lookups == 0 {
            0.0
        } else {
            self.kv_prefix_hits as f64 / self.kv_prefix_lookups as f64
        }
    }

    /// Fraction of submitted requests that completed (1.0 when lossless).
    /// Lossy runs — admission rejections, queue timeouts — look identical
    /// to lossless ones on latency alone; goodput is the honesty metric.
    pub fn goodput(&self) -> f64 {
        let total = self.completed + self.rejected + self.aborted;
        if total == 0 {
            1.0
        } else {
            self.completed as f64 / total as f64
        }
    }

    /// SLO-weighted goodput: Σ_c weight_c · attained_c over
    /// Σ_c weight_c · submitted_c — the production "overall efficiency"
    /// where a completion only counts if it met its class targets, scaled
    /// by what that class is worth. 1.0 when every submitted request
    /// attained its SLO; 1.0 (vacuously) when the per-class map is empty.
    pub fn slo_weighted_goodput(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for s in self.slo.values() {
            num += s.weight * s.attained as f64;
            den += s.weight * s.submitted() as f64;
        }
        if den == 0.0 {
            1.0
        } else {
            num / den
        }
    }

    /// One markdown table row (pairs with [`RunReport::markdown_header`]).
    pub fn markdown_row(&self) -> String {
        format!(
            "| {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.4} | {:.2} | {} |",
            self.policy,
            self.ttlt.mean,
            self.ttlt.p90,
            self.ttft.mean,
            self.ttft.p90,
            self.tpot.mean,
            self.throughput,
            self.preemptions,
        )
    }

    pub fn markdown_header() -> String {
        "| policy | TTLT mean | TTLT p90 | TTFT mean | TTFT p90 | TPOT | thru (r/s) | preempt |\n\
         |---|---|---|---|---|---|---|---|"
            .to_string()
    }

    pub fn to_json(&self) -> Json {
        fn summary(s: &Summary) -> Json {
            Json::obj(vec![
                ("count", Json::num(s.count as f64)),
                ("mean", Json::num(s.mean)),
                ("p50", Json::num(s.p50)),
                ("p90", Json::num(s.p90)),
                ("p99", Json::num(s.p99)),
                ("max", Json::num(s.max)),
            ])
        }
        let mut by_ds = Vec::new();
        for (name, s) in &self.ttlt_by_dataset {
            by_ds.push((*name, summary(s)));
        }
        let mut by_slo = Vec::new();
        for (name, s) in &self.slo {
            by_slo.push((
                *name,
                Json::obj(vec![
                    ("weight", Json::num(s.weight)),
                    ("ttft_target", Json::num(s.ttft_target)),
                    ("ttlt_target", Json::num(s.ttlt_target)),
                    ("completed", Json::num(s.completed as f64)),
                    ("rejected", Json::num(s.rejected as f64)),
                    ("aborted", Json::num(s.aborted as f64)),
                    ("attained", Json::num(s.attained as f64)),
                    ("attainment", Json::num(s.attainment())),
                    ("ttft_attained", Json::num(s.ttft_attained as f64)),
                    ("ttft_attainment", Json::num(s.ttft_attainment())),
                    ("measured", Json::num(s.measured as f64)),
                    ("ttft", summary(&s.ttft)),
                    ("ttlt", summary(&s.ttlt)),
                ]),
            ));
        }
        Json::obj(vec![
            ("policy", Json::str(self.policy.clone())),
            ("predictor", Json::str(self.predictor.clone())),
            ("cost_model", Json::str(self.cost_model.clone())),
            ("measured", Json::num(self.measured as f64)),
            ("ttlt", summary(&self.ttlt)),
            ("ttft", summary(&self.ttft)),
            ("tpot", summary(&self.tpot)),
            ("ttlt_by_dataset", Json::obj(by_ds)),
            ("slo", Json::obj(by_slo)),
            ("slo_weighted_goodput", Json::num(self.slo_weighted_goodput())),
            ("makespan", Json::num(self.makespan)),
            ("throughput", Json::num(self.throughput)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("aborted", Json::num(self.aborted as f64)),
            ("goodput", Json::num(self.goodput())),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("swap_out_events", Json::num(self.swap_out_events as f64)),
            ("swap_in_events", Json::num(self.swap_in_events as f64)),
            ("busy_decode", Json::num(self.busy_decode)),
            ("busy_prefill", Json::num(self.busy_prefill)),
            ("busy_swap", Json::num(self.busy_swap)),
            ("mean_utilization", Json::num(self.mean_utilization)),
            ("predict_overhead", Json::num(self.predict_overhead)),
            ("sched_overhead", Json::num(self.sched_overhead)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("pred_tau", Json::num(self.pred_tau)),
            ("pred_tau_n", Json::num(self.pred_tau_n as f64)),
            ("pred_threshold_hits", Json::num(self.pred_threshold_hits as f64)),
            ("pred_fallback", Json::num(self.pred_fallback as f64)),
            ("pred_cold", Json::num(self.pred_cold as f64)),
            ("kv_peak_used_blocks", Json::num(self.kv_peak_used_blocks as f64)),
            ("kv_fragmentation", Json::num(self.kv_fragmentation)),
            ("kv_prefix_lookups", Json::num(self.kv_prefix_lookups as f64)),
            ("kv_prefix_hits", Json::num(self.kv_prefix_hits as f64)),
            (
                "kv_prefix_hit_rate",
                Json::num(self.kv_prefix_hit_rate()),
            ),
            (
                "kv_prefill_tokens_saved",
                Json::num(self.kv_prefill_tokens_saved as f64),
            ),
            ("kv_prefix_evictions", Json::num(self.kv_prefix_evictions as f64)),
            (
                "kv_swapped_tokens_peak",
                Json::num(self.kv_swapped_tokens_peak as f64),
            ),
        ])
    }
}

/// Aggregate accounting of one multi-replica cluster run: the cluster-wide
/// report over the merged completion stream, per-replica reports, and a
/// load-imbalance indicator.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    /// Router that produced this run (e.g. "least-loaded").
    pub router: String,
    pub replicas: usize,
    /// Cluster-wide report over all replicas' merged outcomes.
    pub aggregate: RunReport,
    /// Per-replica reports (index = replica id).
    pub per_replica: Vec<RunReport>,
    /// Requests routed to each replica.
    pub routed: Vec<u64>,
    /// Requests re-dispatched through the router after a replica failure.
    pub re_routed: u64,
    /// Queued requests re-routed off scale-in victims at drain time.
    pub drained: u64,
    /// Partially-generated requests migrated off scale-in victims (KV
    /// shipped, generated prefix preserved) instead of waiting out the
    /// drain.
    pub migrated: u64,
    /// Queued requests migrated to an idle replica by work stealing.
    pub stolen: u64,
    /// Steal candidates rejected by the transfer-cost benefit gate.
    pub steals_skipped: u64,
    /// Failure-domain outages that fired (each may down several replicas
    /// in one event).
    pub domain_outages: u64,
    /// Per-replica accumulated downtime (seconds; index = replica id).
    pub downtime: Vec<f64>,
    /// Per-replica provisioned lifetime minus downtime (seconds) — what
    /// each replica is "billed" for: replicas added or retired mid-run by
    /// the autoscaler are charged only for their own span.
    pub replica_seconds: Vec<f64>,
    /// Replica lifecycle timeline (provision/up/drain/retire/fail/recover).
    pub scaling_events: Vec<ScalingEvent>,
    /// Successfully completed requests per total replica-second — the
    /// provisioning-efficiency headline: a static fleet pays replica-seconds
    /// through every trough, an elastic one only for capacity it asked for.
    pub goodput_per_replica_second: f64,
    /// SLO-weighted attained requests per total replica-second: the same
    /// efficiency headline, but a completion only counts if it met its
    /// class targets, scaled by the class weight.
    pub slo_weighted_goodput_per_replica_second: f64,
    /// Completion imbalance: max replica completions / mean replica
    /// completions (1.0 = perfectly balanced; 0.0 when nothing completed).
    pub imbalance: f64,
    /// Prefill→decode handoffs delivered over the KV-transfer fabric
    /// (disaggregated serving; 0 colocated).
    pub transfers: u64,
    /// KV tokens shipped across the fabric (prompt + generated prefix per
    /// handoff).
    pub transfer_tokens: u64,
    /// Fabric busy-time / (links × horizon): the fraction of aggregate
    /// link capacity the handoffs consumed. 0 when colocated.
    pub transfer_utilization: f64,
    /// Billed replica-seconds by pool (`[prefill, decode]` in
    /// [`PoolRole::ALL`](crate::config::PoolRole) order); empty when
    /// colocated.
    pub pool_replica_seconds: Vec<f64>,
    /// Per-scope dispatch fast-path accounting (index-answered hits,
    /// shortlist/dominance-bound fallbacks, unconditional rescans). The
    /// only mode-dependent report section: oracle-vs-indexed byte
    /// comparisons strip it, same-mode determinism gates keep it.
    pub fastpath: FastPathStats,
}

/// Which dispatch seam a routing decision belongs to, for fast-path
/// accounting: fresh intake (and crash re-dispatch), decode-side delivery
/// off the KV-transfer fabric, scale-in drain re-admission, and scale-in
/// migration of partially-generated requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchScope {
    Intake,
    Decode,
    Drain,
    Migration,
}

impl DispatchScope {
    pub const ALL: [DispatchScope; 4] = [
        DispatchScope::Intake,
        DispatchScope::Decode,
        DispatchScope::Drain,
        DispatchScope::Migration,
    ];

    pub fn name(self) -> &'static str {
        match self {
            DispatchScope::Intake => "intake",
            DispatchScope::Decode => "decode",
            DispatchScope::Drain => "drain",
            DispatchScope::Migration => "migration",
        }
    }
}

/// Fast-path accounting for one dispatch scope. Every routing decision on
/// the scope lands in exactly one bucket.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FastPathScope {
    /// Decisions answered straight from the incremental indexes (for the
    /// affinity shortlist: the dominance bound held).
    pub hits: u64,
    /// Fast path attempted but not conclusive — shortlist dominance bound
    /// failed, or an index answer was unavailable — so the full rescan ran.
    pub fallbacks: u64,
    /// Fast path not attempted at all: indexes off, a `Rescan`-declared
    /// router, no index instance covering the scope, or a per-request
    /// precondition (e.g. a non-vacuous KV-fit filter) held.
    pub rescans: u64,
}

impl FastPathScope {
    /// Total routing decisions taken on this scope.
    pub fn decisions(&self) -> u64 {
        self.hits + self.fallbacks + self.rescans
    }
}

/// Per-scope [`FastPathScope`] counters carried on a [`ClusterReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FastPathStats {
    pub intake: FastPathScope,
    pub decode: FastPathScope,
    pub drain: FastPathScope,
    pub migration: FastPathScope,
}

impl FastPathStats {
    pub fn scope(&self, s: DispatchScope) -> &FastPathScope {
        match s {
            DispatchScope::Intake => &self.intake,
            DispatchScope::Decode => &self.decode,
            DispatchScope::Drain => &self.drain,
            DispatchScope::Migration => &self.migration,
        }
    }

    pub fn scope_mut(&mut self, s: DispatchScope) -> &mut FastPathScope {
        match s {
            DispatchScope::Intake => &mut self.intake,
            DispatchScope::Decode => &mut self.decode,
            DispatchScope::Drain => &mut self.drain,
            DispatchScope::Migration => &mut self.migration,
        }
    }

    /// Index-answered decisions across every scope.
    pub fn hits(&self) -> u64 {
        DispatchScope::ALL.iter().map(|&s| self.scope(s).hits).sum()
    }

    /// All routing decisions across every scope.
    pub fn decisions(&self) -> u64 {
        DispatchScope::ALL
            .iter()
            .map(|&s| self.scope(s).decisions())
            .sum()
    }

    /// hits / decisions over all scopes (0.0 when nothing was dispatched).
    pub fn hit_rate(&self) -> f64 {
        let total = self.decisions();
        if total > 0 {
            self.hits() as f64 / total as f64
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            DispatchScope::ALL
                .iter()
                .map(|&s| {
                    let fp = self.scope(s);
                    (
                        s.name().to_string(),
                        Json::obj(vec![
                            ("hits", Json::num(fp.hits as f64)),
                            ("fallbacks", Json::num(fp.fallbacks as f64)),
                            ("rescans", Json::num(fp.rescans as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

/// Cluster lifecycle counters feeding a [`ClusterReport`] (kept separate so
/// `ClusterReport::new` stays readable as the cluster grows more telemetry).
#[derive(Clone, Debug, Default)]
pub struct ClusterCounters {
    /// Requests routed to each replica.
    pub routed: Vec<u64>,
    /// Requests re-dispatched after replica failures.
    pub re_routed: u64,
    /// Requests re-routed off scale-in victims at drain time.
    pub drained: u64,
    /// Partially-generated requests migrated off scale-in victims.
    pub migrated: u64,
    /// Requests migrated by idle-replica work stealing.
    pub stolen: u64,
    /// Steal candidates rejected by the transfer-cost benefit gate.
    pub steals_skipped: u64,
    /// Failure-domain outages that fired.
    pub domain_outages: u64,
    /// Per-replica accumulated downtime (seconds).
    pub downtime: Vec<f64>,
    /// Per-replica provisioned lifetime minus downtime (seconds).
    pub replica_seconds: Vec<f64>,
    /// Replica lifecycle timeline.
    pub scaling_events: Vec<ScalingEvent>,
    /// Prefill→decode handoffs delivered over the KV-transfer fabric.
    pub transfers: u64,
    /// KV tokens shipped across the fabric.
    pub transfer_tokens: u64,
    /// Fabric busy-time / (links × horizon).
    pub transfer_utilization: f64,
    /// Billed replica-seconds by pool (empty when colocated).
    pub pool_replica_seconds: Vec<f64>,
    /// Per-scope dispatch fast-path accounting.
    pub fastpath: FastPathStats,
}

impl ClusterReport {
    /// Assemble from per-replica reports and the merged outcome stream.
    /// `warmup_fraction` trims the earliest-arriving fraction of merged
    /// outcomes from the aggregate, matching single-node report semantics.
    pub fn new(
        router: String,
        per_replica: Vec<RunReport>,
        counters: ClusterCounters,
        merged: &[RequestOutcome],
        warmup_fraction: f64,
        slo_specs: &SloSpecs,
    ) -> ClusterReport {
        let mut by_arrival = merged.to_vec();
        by_arrival.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let skip = ((by_arrival.len() as f64) * warmup_fraction).floor() as usize;
        let measured = &by_arrival[skip.min(by_arrival.len())..];
        let mut aggregate = RunReport::from_outcomes(measured);
        // cluster-wide engine/scheduler counters are the per-replica sums;
        // the policy/predictor labels are shared by construction
        if let Some(first) = per_replica.first() {
            aggregate.policy = first.policy.clone();
            aggregate.predictor = first.predictor.clone();
            aggregate.cost_model = first.cost_model.clone();
        }
        for r in &per_replica {
            aggregate.completed += r.completed;
            aggregate.rejected += r.rejected;
            aggregate.aborted += r.aborted;
            aggregate.preemptions += r.preemptions;
            aggregate.swap_out_events += r.swap_out_events;
            aggregate.swap_in_events += r.swap_in_events;
            aggregate.busy_decode += r.busy_decode;
            aggregate.busy_prefill += r.busy_prefill;
            aggregate.busy_swap += r.busy_swap;
            aggregate.decode_steps += r.decode_steps;
            aggregate.predict_overhead += r.predict_overhead;
            aggregate.sched_overhead += r.sched_overhead;
            aggregate.pred_threshold_hits += r.pred_threshold_hits;
            aggregate.pred_fallback += r.pred_fallback;
            aggregate.pred_cold += r.pred_cold;
            aggregate.kv_peak_used_blocks += r.kv_peak_used_blocks;
            // summing lookups and hits separately makes the aggregate
            // `kv_prefix_hit_rate()` *lookup-weighted*: a hot replica
            // serving most of the probes dominates the cluster rate, while
            // an idle replica's (vacuous) per-replica rate contributes
            // nothing — averaging the per-replica rates would instead let
            // it drag the cluster number toward 0
            aggregate.kv_prefix_lookups += r.kv_prefix_lookups;
            aggregate.kv_prefix_hits += r.kv_prefix_hits;
            aggregate.kv_prefill_tokens_saved += r.kv_prefill_tokens_saved;
            aggregate.kv_prefix_evictions += r.kv_prefix_evictions;
            aggregate.kv_swapped_tokens_peak += r.kv_swapped_tokens_peak;
            // a fraction doesn't sum across replicas: report the worst case
            aggregate.kv_fragmentation = aggregate.kv_fragmentation.max(r.kv_fragmentation);
        }
        // pred_tau is *not* summable across replicas; the cluster context
        // overwrites it from its shared predictor's tau tracker
        // per-class loss counters live on the replicas' reports (each
        // coordinator owns its rejection/abort counts); attainment and
        // latency summaries come from the merged outcome stream
        let mut rejected_by_class = [0u64; 3];
        let mut aborted_by_class = [0u64; 3];
        for r in &per_replica {
            for class in SloClass::ALL {
                if let Some(s) = r.slo.get(class.name()) {
                    rejected_by_class[class.index()] += s.rejected;
                    aborted_by_class[class.index()] += s.aborted;
                }
            }
        }
        aggregate.slo = slo_class_stats(
            slo_specs,
            measured,
            &by_arrival,
            &rejected_by_class,
            &aborted_by_class,
        );
        let counts: Vec<f64> = per_replica.iter().map(|r| r.measured as f64).collect();
        let total: f64 = counts.iter().sum();
        let imbalance = if total > 0.0 && !counts.is_empty() {
            let mean = total / counts.len() as f64;
            counts.iter().cloned().fold(0.0, f64::max) / mean
        } else {
            0.0
        };
        let total_replica_seconds: f64 = counters.replica_seconds.iter().sum();
        let goodput_per_replica_second = if total_replica_seconds > 0.0 {
            aggregate.completed as f64 / total_replica_seconds
        } else {
            0.0
        };
        let weighted_attained: f64 = aggregate
            .slo
            .values()
            .map(|s| s.weight * s.attained as f64)
            .sum();
        let slo_weighted_goodput_per_replica_second = if total_replica_seconds > 0.0 {
            weighted_attained / total_replica_seconds
        } else {
            0.0
        };
        ClusterReport {
            router,
            replicas: per_replica.len(),
            aggregate,
            per_replica,
            routed: counters.routed,
            re_routed: counters.re_routed,
            drained: counters.drained,
            migrated: counters.migrated,
            stolen: counters.stolen,
            steals_skipped: counters.steals_skipped,
            domain_outages: counters.domain_outages,
            downtime: counters.downtime,
            replica_seconds: counters.replica_seconds,
            scaling_events: counters.scaling_events,
            goodput_per_replica_second,
            slo_weighted_goodput_per_replica_second,
            imbalance,
            transfers: counters.transfers,
            transfer_tokens: counters.transfer_tokens,
            transfer_utilization: counters.transfer_utilization,
            pool_replica_seconds: counters.pool_replica_seconds,
            fastpath: counters.fastpath,
        }
    }

    /// Sum of per-replica billed seconds.
    pub fn total_replica_seconds(&self) -> f64 {
        self.replica_seconds.iter().sum()
    }

    pub fn markdown_header() -> String {
        "| router | replicas | TTLT mean | TTLT p90 | TTFT mean | TTFT p90 | thru (r/s) | imbalance | re-routed | stolen | rejected | aborted | goodput | rep-s | gp/rep-s |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"
            .to_string()
    }

    pub fn markdown_row(&self) -> String {
        format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.2} | {:.2} | {} | {} | {} | {} | {:.3} | {:.0} | {:.3} |",
            self.router,
            self.replicas,
            self.aggregate.ttlt.mean,
            self.aggregate.ttlt.p90,
            self.aggregate.ttft.mean,
            self.aggregate.ttft.p90,
            self.aggregate.throughput,
            self.imbalance,
            self.re_routed,
            self.stolen,
            self.aggregate.rejected,
            self.aggregate.aborted,
            self.aggregate.goodput(),
            self.total_replica_seconds(),
            self.goodput_per_replica_second,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("router", Json::str(self.router.clone())),
            ("replicas", Json::num(self.replicas as f64)),
            ("aggregate", self.aggregate.to_json()),
            (
                "per_replica",
                Json::arr(self.per_replica.iter().map(RunReport::to_json)),
            ),
            (
                "routed",
                Json::arr(self.routed.iter().map(|&n| Json::num(n as f64))),
            ),
            ("re_routed", Json::num(self.re_routed as f64)),
            ("drained", Json::num(self.drained as f64)),
            ("migrated", Json::num(self.migrated as f64)),
            ("stolen", Json::num(self.stolen as f64)),
            ("steals_skipped", Json::num(self.steals_skipped as f64)),
            ("domain_outages", Json::num(self.domain_outages as f64)),
            (
                "downtime",
                Json::arr(self.downtime.iter().map(|&d| Json::num(d))),
            ),
            (
                "replica_seconds",
                Json::arr(self.replica_seconds.iter().map(|&s| Json::num(s))),
            ),
            (
                "scaling_events",
                Json::arr(self.scaling_events.iter().map(|e| {
                    Json::obj(vec![
                        ("at", Json::num(e.at)),
                        ("replica", Json::num(e.replica as f64)),
                        ("action", Json::str(e.action.name())),
                    ])
                })),
            ),
            (
                "goodput_per_replica_second",
                Json::num(self.goodput_per_replica_second),
            ),
            (
                "slo_weighted_goodput_per_replica_second",
                Json::num(self.slo_weighted_goodput_per_replica_second),
            ),
            ("imbalance", Json::num(self.imbalance)),
            ("transfers", Json::num(self.transfers as f64)),
            ("transfer_tokens", Json::num(self.transfer_tokens as f64)),
            ("transfer_utilization", Json::num(self.transfer_utilization)),
            (
                "pool_replica_seconds",
                Json::arr(self.pool_replica_seconds.iter().map(|&s| Json::num(s))),
            ),
            // the only mode-dependent section; oracle-vs-indexed
            // comparisons zero the `fastpath` field before serializing
            ("fastpath", self.fastpath.to_json()),
        ])
    }
}

/// Performance counters of one bench run — what `benches/cluster_scale.rs`
/// records into `BENCH_cluster.json` so every later PR can show the perf
/// trajectory. Not part of any `ClusterReport`: aside from the `fastpath`
/// accounting block (which oracle comparisons strip), report JSON stays
/// byte-identical across perf work by construction.
#[derive(Clone, Debug, Default)]
pub struct PerfStats {
    /// Wall-clock seconds of the measured phase.
    pub wall_s: f64,
    /// Kernel events popped (arrivals, decisions, failures, transfers...).
    pub kernel_events: u64,
    /// Replica scheduling iterations driven.
    pub replica_steps: u64,
    /// (kernel_events + replica_steps) / wall_s — the headline rate.
    pub events_per_sec: f64,
    /// Peak RSS proxy in MiB (VmHWM; 0.0 where /proc is unavailable).
    pub peak_rss_mb: f64,
    /// Per-phase wall-clock breakdown, in phase order.
    pub phases: Vec<(String, f64)>,
}

impl PerfStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wall_s", Json::num(self.wall_s)),
            ("kernel_events", Json::num(self.kernel_events as f64)),
            ("replica_steps", Json::num(self.replica_steps as f64)),
            ("events_per_sec", Json::num(self.events_per_sec)),
            ("peak_rss_mb", Json::num(self.peak_rss_mb)),
            (
                "phases",
                Json::Obj(
                    self.phases
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Peak resident set size in MiB, read from `/proc/self/status` (`VmHWM`).
/// A cheap high-water-mark proxy — good enough to track allocation-churn
/// regressions run-over-run. Returns 0.0 on platforms without procfs.
pub fn peak_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(id: u64, ds: DatasetKind, arr: f64, ft: f64, done: f64) -> RequestOutcome {
        RequestOutcome {
            id,
            dataset: ds,
            slo: SloClass::Standard,
            input_len: 10,
            output_len: 10,
            arrival: arr,
            first_token: ft,
            completion: done,
            preemptions: 0,
        }
    }

    #[test]
    fn aggregates_latencies() {
        let outs = vec![
            outcome(1, DatasetKind::ShareGpt, 0.0, 1.0, 5.0),
            outcome(2, DatasetKind::Alpaca, 1.0, 3.0, 11.0),
        ];
        let r = RunReport::from_outcomes(&outs);
        assert_eq!(r.measured, 2);
        assert!((r.ttlt.mean - 7.5).abs() < 1e-12);
        assert!((r.ttft.mean - 1.5).abs() < 1e-12);
        assert!((r.makespan - 11.0).abs() < 1e-12);
        assert_eq!(r.ttlt_by_dataset.len(), 2);
    }

    #[test]
    fn empty_outcomes_safe() {
        let r = RunReport::from_outcomes(&[]);
        assert_eq!(r.measured, 0);
        assert_eq!(r.ttlt.mean, 0.0);
    }

    #[test]
    fn json_roundtrip_has_fields() {
        let outs = vec![outcome(1, DatasetKind::Write, 0.0, 0.5, 2.0)];
        let mut r = RunReport::from_outcomes(&outs);
        r.policy = "sagesched".into();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.str_or("policy", ""), "sagesched");
        assert!(j.get("ttlt").unwrap().f64_or("mean", -1.0) > 0.0);
    }

    #[test]
    fn cluster_report_aggregates_and_measures_imbalance() {
        let r0 = RunReport::from_outcomes(&[
            outcome(1, DatasetKind::ShareGpt, 0.0, 1.0, 2.0),
            outcome(2, DatasetKind::ShareGpt, 1.0, 2.0, 3.0),
            outcome(3, DatasetKind::ShareGpt, 2.0, 3.0, 4.0),
        ]);
        let r1 = RunReport::from_outcomes(&[outcome(4, DatasetKind::Write, 0.5, 1.5, 2.5)]);
        let merged: Vec<RequestOutcome> = vec![
            outcome(1, DatasetKind::ShareGpt, 0.0, 1.0, 2.0),
            outcome(2, DatasetKind::ShareGpt, 1.0, 2.0, 3.0),
            outcome(3, DatasetKind::ShareGpt, 2.0, 3.0, 4.0),
            outcome(4, DatasetKind::Write, 0.5, 1.5, 2.5),
        ];
        let mut r0 = r0;
        r0.completed = 3;
        r0.rejected = 2;
        let mut r1 = r1;
        r1.completed = 1;
        r1.aborted = 1;
        let counters = ClusterCounters {
            routed: vec![3, 1],
            re_routed: 2,
            drained: 3,
            migrated: 1,
            stolen: 1,
            steals_skipped: 2,
            domain_outages: 1,
            downtime: vec![0.0, 4.5],
            replica_seconds: vec![10.0, 6.0],
            scaling_events: vec![ScalingEvent {
                at: 2.0,
                replica: 1,
                action: crate::autoscale::ScaleAction::Drain,
            }],
            ..ClusterCounters::default()
        };
        let c = ClusterReport::new(
            "least-loaded".into(),
            vec![r0, r1],
            counters,
            &merged,
            0.0,
            &SloSpecs::default(),
        );
        assert_eq!(c.replicas, 2);
        assert_eq!(c.aggregate.measured, 4);
        // counts 3 and 1: mean 2, max 3 -> imbalance 1.5
        assert!((c.imbalance - 1.5).abs() < 1e-12);
        // loss accounting aggregates exactly once across replicas
        assert_eq!(c.aggregate.completed, 4);
        assert_eq!(c.aggregate.rejected, 2);
        assert_eq!(c.aggregate.aborted, 1);
        assert!((c.aggregate.goodput() - 4.0 / 7.0).abs() < 1e-12);
        assert_eq!(c.re_routed, 2);
        assert_eq!(c.drained, 3);
        assert_eq!(c.stolen, 1);
        assert_eq!(c.steals_skipped, 2);
        assert_eq!(c.migrated, 1);
        assert_eq!(c.domain_outages, 1);
        // 4 completions over 16 billed replica-seconds
        assert!((c.total_replica_seconds() - 16.0).abs() < 1e-12);
        assert!((c.goodput_per_replica_second - 0.25).abs() < 1e-12);
        assert!(c.markdown_row().starts_with("| least-loaded | 2 |"));
        assert_eq!(
            c.markdown_row().matches('|').count(),
            ClusterReport::markdown_header()
                .lines()
                .next()
                .unwrap()
                .matches('|')
                .count()
        );
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(j.str_or("router", ""), "least-loaded");
        assert_eq!(j.f64_or("re_routed", -1.0), 2.0);
        assert_eq!(j.f64_or("stolen", -1.0), 1.0);
        assert_eq!(j.f64_or("drained", -1.0), 3.0);
        assert_eq!(j.f64_or("steals_skipped", -1.0), 2.0);
        assert_eq!(j.f64_or("goodput_per_replica_second", -1.0), 0.25);
        let evs = j.get("scaling_events").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].str_or("action", ""), "drain");
        assert_eq!(evs[0].f64_or("replica", -1.0), 1.0);
        assert_eq!(
            j.get("aggregate").unwrap().f64_or("rejected", -1.0),
            2.0
        );
        assert!(j.get("aggregate").unwrap().f64_or("goodput", -1.0) > 0.0);
    }

    #[test]
    fn cluster_kv_hit_rate_is_lookup_weighted() {
        // one hot replica (1000 lookups, 80% hits) + one idle replica
        // (2 lookups, 0 hits): the cluster rate must track the replica
        // that served the probes (~79.8%), not the 40% a naive average of
        // per-replica rates would claim
        let mut hot = RunReport::from_outcomes(&[outcome(
            1,
            DatasetKind::ShareGpt,
            0.0,
            1.0,
            2.0,
        )]);
        hot.kv_prefix_lookups = 1000;
        hot.kv_prefix_hits = 800;
        let mut idle =
            RunReport::from_outcomes(&[outcome(2, DatasetKind::Write, 0.5, 1.5, 2.5)]);
        idle.kv_prefix_lookups = 2;
        idle.kv_prefix_hits = 0;
        let merged = vec![
            outcome(1, DatasetKind::ShareGpt, 0.0, 1.0, 2.0),
            outcome(2, DatasetKind::Write, 0.5, 1.5, 2.5),
        ];
        let c = ClusterReport::new(
            "least-loaded".into(),
            vec![hot, idle],
            ClusterCounters::default(),
            &merged,
            0.0,
            &SloSpecs::default(),
        );
        assert_eq!(c.aggregate.kv_prefix_lookups, 1002);
        assert_eq!(c.aggregate.kv_prefix_hits, 800);
        let rate = c.aggregate.kv_prefix_hit_rate();
        assert!((rate - 800.0 / 1002.0).abs() < 1e-12, "got {rate}");
        assert!(rate > 0.75, "idle replica must not drag the rate to ~0.4");
    }

    #[test]
    fn slo_stats_count_attainment_and_weight_goodput() {
        let specs = SloSpecs::default();
        // interactive targets: ttft 2, ttlt 20
        let mut fast = outcome(1, DatasetKind::ShareGpt, 0.0, 1.0, 5.0);
        fast.slo = SloClass::Interactive;
        let mut slow = outcome(2, DatasetKind::ShareGpt, 0.0, 1.0, 30.0); // misses ttlt
        slow.slo = SloClass::Interactive;
        let mut batch = outcome(3, DatasetKind::Write, 0.0, 1.0, 100.0); // batch ok
        batch.slo = SloClass::Batch;
        let all = vec![fast, slow, batch];
        let rejected = [0u64, 2, 1]; // 2 standard rejections, 1 batch
        let aborted = [0u64; 3];
        let map = slo_class_stats(&specs, &all, &all, &rejected, &aborted);
        let i = &map["interactive"];
        assert_eq!(i.completed, 2);
        assert_eq!(i.attained, 1);
        assert!((i.attainment() - 0.5).abs() < 1e-12);
        let b = &map["batch"];
        assert_eq!(b.completed, 1);
        assert_eq!(b.attained, 1);
        assert_eq!(b.submitted(), 2);
        assert!((b.attainment() - 0.5).abs() < 1e-12);
        let s = &map["standard"];
        assert_eq!(s.completed, 0);
        assert_eq!(s.submitted(), 2);
        assert_eq!(s.attainment(), 0.0);
        let mut r = RunReport::from_outcomes(&all);
        r.slo = map;
        // weighted: attained 4*1 + 0.25*1 = 4.25;
        // submitted 4*2 + 1*2 + 0.25*2 = 10.5
        assert!((r.slo_weighted_goodput() - 4.25 / 10.5).abs() < 1e-12);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let js = j.get("slo").unwrap().get("interactive").unwrap();
        assert_eq!(js.f64_or("attained", -1.0), 1.0);
        assert!(j.f64_or("slo_weighted_goodput", -1.0) > 0.0);
    }

    #[test]
    fn empty_slo_map_is_vacuously_perfect() {
        let r = RunReport::from_outcomes(&[]);
        assert_eq!(r.slo_weighted_goodput(), 1.0);
    }

    #[test]
    fn markdown_row_well_formed() {
        let r = RunReport {
            policy: "fcfs".into(),
            ..RunReport::from_outcomes(&[outcome(1, DatasetKind::Write, 0.0, 1.0, 2.0)])
        };
        let row = r.markdown_row();
        assert!(row.starts_with("| fcfs |"));
        assert_eq!(row.matches('|').count(), RunReport::markdown_header().lines().next().unwrap().matches('|').count());
    }
}
